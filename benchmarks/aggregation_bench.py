"""Round-close benchmark: eager list-of-trees vs the fused close engine.

Times the system's single hottest operation — the round close — both ways,
for EVERY engine-covered method:

* **old**: the seed's eager tree-walk over a list of client adapter trees —
  what the trainer ran per round: ``mean_deviation`` (the §6 metric) + the
  method's eager close (``fedex_aggregate`` + ``apply_residual``; the dense
  ``jnp.linalg.svd`` truncation for fedex_svd; ``per_client_residuals`` /
  ``assign_after_aggregation`` for the Table-5 assignment strategies), one
  dispatch per eager op, dense ΔW_res materialised host-side, and
* **new**: ``core/engine.py``'s close program over ``(C_max, …)``-stacked
  client buffers (one dispatch, divergence metric computed inside via
  factored Grams, W0/stacks donated on accelerators; the svd close truncates
  on the (C·r)² Grams — no dense residual, no dense SVD).

``speedup`` compares equal work (both sides produce new W0 + global factors
+ divergence); ``speedup_vs_close_only`` excludes the divergence from the old
path for the narrower close-only comparison — for ``fedex_svd`` that is the
headline engine-vs-eager-dense-SVD ratio (acceptance: ≥2× at C=8/12-layer).

Scenarios: uniform full participation, example-weighted, 50 % partial
participation (masked lanes), the rank-r' truncated ``fedex_svd`` close, and
the ``keep_local`` / ``reinit`` assignment closes. Note the uniform
``keep_local`` row measures the engine's BITWISE branch (eager operators
composed lane-by-lane inside the jit — unbatchable per-client matmul
chains), so its close-only ratio hovers near 1×; the win there is the fused
divergence + single dispatch (the ``speedup`` column) and the batched
weighted branch. The uniform fedex scenario
also records whether the engine output is bitwise identical to the *jitted*
composition of ``fedex_aggregate + apply_residual`` (it must be — same op
sequence), plus the max |Δ| against the eager path (≤ a few ulp of FMA
contraction; ~1e-5 relative for the svd close — Gram squaring).

A third tier, ``close_vs_c``, sweeps client count C with stacked vs CHUNKED
closes (``FedConfig.close_chunk``): close latency, ingest wall time and the
engine's analytic peak live-device-bytes per mode, asserting the chunked
close breaks the C_max memory wall (peak stays within 1.25× of a stacked
C=chunk close at the largest swept C).

Emits ``BENCH_aggregation.json`` so the perf trajectory is recorded:

  PYTHONPATH=src python -m benchmarks.aggregation_bench [--quick] [--out F]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, env_metadata
from repro.core import aggregation as agg
from repro.core.divergence import mean_deviation
from repro.core.engine import RoundCloseEngine
from repro.util.tree import flatten_with_paths

DEFAULT_OUT = "BENCH_aggregation.json"


def _make_setting(quick: bool):
    """C clients, L stacked layers, 4 adapted projections per layer stack."""
    c, layers, m, n, r = (4, 4, 128, 128, 8) if quick else (8, 12, 256, 256, 8)
    rng = np.random.default_rng(0)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    names = ("q_proj", "k_proj", "v_proj", "o_proj")
    params = {"blocks": {p: {"kernel": mk((layers, m, n))} for p in names}}
    lora_t = {"blocks": {p: {"a": mk((layers, m, r)), "b": mk((layers, r, n))}
                         for p in names}}
    loras = [{"blocks": {p: {"a": mk((layers, m, r)), "b": mk((layers, r, n))}
                         for p in names}} for _ in range(c)]
    meta = {"clients": c, "layers": layers, "m": m, "n": n, "rank": r,
            "projections": len(names)}
    return params, lora_t, loras, meta


def _time(fn, *, reps: int) -> float:
    jax.block_until_ready(fn())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / reps


def _time_min(fn, *, reps: int, batches: int = 3) -> float:
    """Best-of-``batches`` mean-of-``reps`` — the stable estimator the
    obs-overhead comparison needs (a single noisy batch would dominate a
    few-percent delta)."""
    return min(_time(fn, reps=reps) for _ in range(batches))


def _max_diff(tree_a, tree_b) -> float:
    fa, fb = flatten_with_paths(tree_a), flatten_with_paths(tree_b)
    return max(float(jnp.abs(jnp.asarray(fa[k], jnp.float32)
                             - jnp.asarray(fb[k], jnp.float32)).max())
               for k in fa)


def _bitwise(tree_a, tree_b) -> bool:
    fa, fb = flatten_with_paths(tree_a), flatten_with_paths(tree_b)
    return all(bool((np.asarray(fa[k]) == np.asarray(fb[k])).all()) for k in fa)


def _eager_close(method: str, params, subset, sub_w, scale: float,
                 svd_rank: int, client_params=None):
    """The trainer's pre-engine eager close for one method (ex-divergence)."""
    if method == "fedex":
        g, res = agg.fedex_aggregate(subset, sub_w)
        return agg.apply_residual(params, res, scale)
    if method == "fedex_svd":
        g, res = agg.fedex_svd_aggregate(subset, svd_rank, sub_w)
        return agg.apply_residual(params, res, scale)
    if method == "reinit":
        new_loras, residual = agg.assign_after_aggregation(
            "reinit", subset, jax.random.key(0), sub_w)
        return agg.apply_residual(params, residual, scale)
    if method == "keep_local":
        residuals = agg.per_client_residuals(subset, sub_w)
        return [agg.apply_residual(p, r_i, scale)
                for p, r_i in zip(client_params, residuals)]
    raise ValueError(method)


def run_bench(quick: bool = False) -> Dict:
    params, lora_t, loras, meta = _make_setting(quick)
    c = meta["clients"]
    scale = 2.0
    svd_rank = meta["rank"]  # r' = r: the paper's server-truncation regime
    reps = 3 if quick else 10
    rng = np.random.default_rng(1)
    raw_w = rng.uniform(0.5, 4.0, size=c)
    weighted = (raw_w / raw_w.sum()).tolist()
    part_ids = list(range(0, c, 2))  # 50 % participation

    scenarios = {
        "uniform": ("fedex", list(range(c)), None),
        "weighted": ("fedex", list(range(c)), weighted),
        "participation_50pct": ("fedex", part_ids, None),
        "fedex_svd": ("fedex_svd", list(range(c)), None),
        "keep_local": ("keep_local", list(range(c)), None),
        "reinit": ("reinit", list(range(c)), None),
    }

    backend = "jnp" if jax.default_backend() == "cpu" else "auto"
    result = {"config": dict(meta, scale=scale, reps=reps, svd_rank=svd_rank,
                             backend=jax.default_backend()),
              "env": env_metadata(c_max=c, methods=sorted(
                  {m for m, _, _ in scenarios.values()})),
              "scenarios": {}}
    for name, (method, ids, weights) in scenarios.items():
        subset = [loras[i] for i in ids]
        sub_w = None if weights is None else [weights[i] for i in ids]
        # keep_local folds every delivered client's OWN base
        client_params = [params for _ in ids] if method == "keep_local" else None

        def old_close():
            return _eager_close(method, params, subset, sub_w, scale,
                                svd_rank, client_params)

        def old_round():  # the trainer's full per-round host work
            div = mean_deviation(subset)
            return old_close(), div

        old_close_us = _time(old_close, reps=reps)
        old_us = _time(old_round, reps=reps)
        old_params = old_close()

        # donate=False: timing replays the close program on the same stacks,
        # which donated buffers would forbid on accelerators; the streamed
        # writes happen per arrival and are not part of the deadline-critical
        # close being measured.
        engine = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                                  method=method, svd_rank=svd_rank,
                                  backend=backend, donate=False)
        engine.buffers.begin_round({i: i for i in range(c)})
        for i in ids:
            engine.buffers.write(i, loras[i])
        w, mask, uniform = engine.weight_vector(ids, sub_w)
        stacks = engine.buffers.take()
        if method == "keep_local":
            w0_leaves = {
                s.key: jnp.stack([params["blocks"][s.key.split("/")[-1]]
                                  ["kernel"]] * c)
                for s in engine.specs
            }
        else:
            w0_leaves = {s.key: params["blocks"][s.key.split("/")[-1]]["kernel"]
                         for s in engine.specs}

        def new_close():
            return engine._close(w0_leaves, stacks, jnp.asarray(w),
                                 jnp.asarray(mask), uniform=uniform)

        new_us = _time(new_close, reps=reps)
        new_w0, glob, div = new_close()

        row = {
            "method": method,
            "old_us": round(old_us, 1),
            "old_close_only_us": round(old_close_us, 1),
            "new_us": round(new_us, 1),
            "speedup": round(old_us / new_us, 2),
            "speedup_vs_close_only": round(old_close_us / new_us, 2),
            "delivered": len(ids),
            "weights": "examples" if weights else "uniform",
        }
        if method == "keep_local":
            # lane i of the engine's stacked output vs client i's eager fold
            row["max_abs_diff_vs_eager"] = max(
                _max_diff(
                    {k: v[i] for k, v in new_w0.items()},
                    {s.key: old_params[i]["blocks"][s.key.split("/")[-1]]
                     ["kernel"] for s in engine.specs})
                for i in range(len(ids)))
        else:
            new_params = {"blocks": {k.split("/")[-1]: {"kernel": v}
                                     for k, v in new_w0.items()}}
            row["max_abs_diff_vs_eager"] = _max_diff(new_params, old_params)
            if method == "fedex" and uniform:
                jit_close = jax.jit(
                    lambda p, ls: agg.apply_residual(
                        p, agg.fedex_aggregate(ls)[1], scale))
                row["uniform_bitwise_vs_jit"] = _bitwise(
                    new_params, jit_close(params, subset))
        result["scenarios"][name] = row

    result["obs_overhead"] = _obs_overhead(params, lora_t, loras, c, scale,
                                           backend, reps)
    result["close_vs_c"] = _close_vs_c(quick, backend)
    result["hetero"] = _hetero_bench(quick, backend)
    return result


def _hetero_bench(quick: bool, backend: str) -> Dict:
    """Engine ``close_hetero`` vs the eager ``core/hetero.py`` oracle.

    Mixed client ranks r∈{2,4,8} padded to r_max=8 lanes, swept at C=8 and
    C=64 (quick: C=8 only). The eager side is the demoted oracle —
    ``hetero_fedex_aggregate`` (one shared truncation, per-client leading
    slices) plus a per-client ``apply_residual`` fold over a list of trees.
    The engine side streams rank-tagged padded uplinks into the ring and
    closes every lane in one jitted program (rank masks zero the padding,
    Grams keep the dense m×n mean unformed). ``stream_us`` is ingest wall
    time, ``new_us`` the take-to-divergence-resolved close; the per-client
    folded bases must agree with the oracle to float roundoff."""
    from repro.core.hetero import hetero_fedex_aggregate, pad_adapters

    layers, m, n, rmax = 2, 128, 128, 8
    scale = 2.0
    reps = 2 if quick else 5
    cs = (8,) if quick else (8, 64)
    rng = np.random.default_rng(11)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    params = {"blocks": {"q_proj": {"kernel": mk((layers, m, n))}}}
    lora_t = {"blocks": {"q_proj": {"a": mk((layers, m, rmax)),
                                    "b": mk((layers, rmax, n))}}}
    sweep = []
    for c in cs:
        ranks = [(2, 4, 8)[i % 3] for i in range(c)]
        loras = [{"blocks": {"q_proj": {
            "a": mk((layers, m, ranks[i])),
            "b": mk((layers, ranks[i], n))}}} for i in range(c)]
        client_params = [params] * c
        ids = list(range(c))

        def old_close():
            new_loras, residuals = hetero_fedex_aggregate(
                loras, ranks, r_max=rmax)
            return [agg.apply_residual(p, r_i, scale)
                    for p, r_i in zip(client_params, residuals)]

        old_us = _time(old_close, reps=reps)
        old_cp = old_close()

        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="hetero", backend=backend,
                               donate=False, client_ranks=ranks)
        stream_us, close_us = [], []
        new_cp = None
        for rep in range(reps + 1):  # rep 0 = compile warmup
            t0 = time.perf_counter()
            eng.buffers.begin_round({i: i for i in ids}, round_id=rep)
            for i in ids:
                eng.buffers.write(i, pad_adapters(loras[i], rmax),
                                  round_id=rep, rank=ranks[i])
            t1 = time.perf_counter()
            cp, _cl, _g, div = eng.close_hetero(client_params, ids,
                                                round_id=rep)
            jax.block_until_ready(jax.tree.leaves(cp[0]))
            div.resolve()
            t2 = time.perf_counter()
            if rep:
                stream_us.append(1e6 * (t1 - t0))
                close_us.append(1e6 * (t2 - t1))
            new_cp = cp
        new_us = min(close_us)
        diff = max(_max_diff(new_cp[i], old_cp[i]) for i in ids)
        sweep.append({"c": c,
                      "ranks": "2/4/8 cycled",
                      "old_us": round(old_us, 1),
                      "new_us": round(new_us, 1),
                      "stream_us": round(min(stream_us), 1),
                      "speedup": round(old_us / new_us, 2),
                      "max_abs_diff_vs_eager": diff})
    return {"geometry": {"layers": layers, "m": m, "n": n, "r_max": rmax,
                         "projections": 1},
            "sweep": sweep,
            "claim": ("engine ragged close matches the eager oracle's "
                      "per-client folded bases to float roundoff")}


def _close_vs_c(quick: bool, backend: str) -> Dict:
    """Close latency + analytic peak device memory vs client count C,
    stacked vs chunked (the C_max memory wall sweep).

    For each C the same uplink stream is closed both ways: the classic
    stacked ``(C, …)`` close, and the chunked engine (``close_chunk``) whose
    ring folds full chunks eagerly at ingest. ``stream_us`` is the total
    ingest wall time (the chunked mode pays its partial folds HERE, off the
    deadline-critical path), ``close_us`` the take-to-divergence-resolved
    close. Peaks are the engine's analytic live-device-bytes accounting —
    identical formula on every backend (donation-aware), so the CPU
    container models accelerator residency.

    The headline assertion (``memory_ok``): the chunked close at the largest
    swept C stays within 1.25× the peak of a STACKED close at C = chunk —
    i.e. peak close memory is O(chunk), not O(C). A C below the chunk size
    takes the stacked path by the auto contract (its row shows mode
    "stacked(auto)")."""
    cs = (8, 32) if quick else (8, 32, 128, 512)
    chunk = 16 if quick else 64
    layers, m, n, r = 2, 128, 128, 8
    scale = 2.0
    reps = 2 if quick else 3
    cs = tuple(sorted(set(cs) | {chunk}))
    rng = np.random.default_rng(7)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    params = {"blocks": {"q_proj": {"kernel": mk((layers, m, n))}}}
    lora_t = {"blocks": {"q_proj": {"a": mk((layers, m, r)),
                                    "b": mk((layers, r, n))}}}
    c_top = max(cs)
    # ONE host pool of client factors, sliced per C (generation is not the
    # thing under test)
    pool = [{"blocks": {"q_proj": {"a": rng.normal(size=(layers, m, r)
                                                   ).astype(np.float32),
                                   "b": rng.normal(size=(layers, r, n)
                                                   ).astype(np.float32)}}}
            for _ in range(c_top)]

    def _measure(c: int, eng_chunk: int) -> Dict:
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="fedex", backend=backend, donate=False,
                               chunk=eng_chunk)
        ids = list(range(c))
        stream_us, close_us, peak = [], [], 0
        chunked = False
        for rep in range(reps + 1):  # rep 0 = compile warmup
            t0 = time.perf_counter()
            eng.buffers.begin_round({i: i for i in ids}, round_id=rep)
            for i in ids:
                eng.buffers.write(i, pool[i], round_id=rep, weight=1.0)
            t1 = time.perf_counter()
            chunked = eng.buffers.is_chunked(rep)
            _, new_params, div = eng.close(params, ids, round_id=rep)
            jax.block_until_ready(
                new_params["blocks"]["q_proj"]["kernel"])
            div.resolve()
            t2 = time.perf_counter()
            peak = eng.last_peak_bytes
            if rep:
                stream_us.append(1e6 * (t1 - t0))
                close_us.append(1e6 * (t2 - t1))
        return {"stream_us": round(min(stream_us), 1),
                "close_us": round(min(close_us), 1),
                "peak_bytes": int(peak),
                "mode": ("chunked" if chunked else
                         ("stacked(auto)" if eng_chunk else "stacked"))}

    sweep = []
    for c in cs:
        stacked = _measure(c, 0)
        chunked = _measure(c, chunk)
        sweep.append({"c": c,
                      "stacked": stacked, "chunked": chunked,
                      "close_speedup": round(
                          stacked["close_us"] / chunked["close_us"], 2),
                      "peak_ratio_vs_stacked": round(
                          chunked["peak_bytes"] / stacked["peak_bytes"], 3)})
    baseline = next(s for s in sweep if s["c"] == chunk)["stacked"]
    top = next(s for s in sweep if s["c"] == c_top)["chunked"]
    ratio = top["peak_bytes"] / baseline["peak_bytes"]
    return {"chunk": chunk,
            "geometry": {"layers": layers, "m": m, "n": n, "rank": r,
                         "projections": 1},
            "sweep": sweep,
            "baseline_stacked_at_chunk_peak_bytes": baseline["peak_bytes"],
            "top_chunked_peak_bytes": top["peak_bytes"],
            "memory_ratio_vs_stacked_chunk": round(ratio, 3),
            "memory_ok": bool(ratio <= 1.25),
            "claim": (f"chunked close at C={c_top} stays ≤ 1.25× the peak "
                      f"device bytes of a stacked C={chunk} close")}


def _obs_overhead(params, lora_t, loras, c, scale, backend, reps) -> Dict:
    """obs=off vs obs=trace on the engine's instrumented dispatch path.

    Times ``RoundCloseEngine._dispatch`` — the exact code the trainer runs
    per close — for the uniform fedex scenario with the shared NULL recorder
    (obs=off, early-return) and with a live ``Recorder("trace")`` (span +
    compile-cache + histogram bookkeeping around the same program).

    ONE engine, recorder swapped between interleaved best-of batches: a
    second engine would mean a second compile of the same program, and
    compile-to-compile variance (a few %) would drown the few-µs bookkeeping
    being measured. The claim docs/observability.md makes: tracing costs
    < 5 % of a close dispatch."""
    from repro.obs import NULL, Recorder

    ids = list(range(c))
    eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                           method="fedex", svd_rank=0, backend=backend,
                           donate=False)
    eng.buffers.begin_round({i: i for i in range(c)})
    for i in ids:
        eng.buffers.write(i, loras[i])
    w, mask, uniform = eng.weight_vector(ids, None)
    stacks = eng.buffers.take()
    w0_leaves = {s.key: params["blocks"][s.key.split("/")[-1]]["kernel"]
                 for s in eng.specs}

    def dispatch():
        return eng._dispatch(w0_leaves, stacks, w, mask, uniform, None)

    jax.block_until_ready(dispatch())  # compile + warm
    recorders = {"off": NULL, "trace": Recorder("trace")}
    inner = max(reps, 10)
    best = {label: float("inf") for label in recorders}
    for _ in range(8):  # interleaved: machine drift hits both modes alike
        for label, rec in recorders.items():
            eng.rec = rec
            t0 = time.perf_counter()
            for _ in range(inner):
                out = dispatch()
            jax.block_until_ready(out)
            best[label] = min(best[label],
                              1e6 * (time.perf_counter() - t0) / inner)
    eng.rec = NULL
    overhead_pct = 100.0 * (best["trace"] - best["off"]) / best["off"]
    return {"off_us": round(best["off"], 1),
            "trace_us": round(best["trace"], 1),
            "overhead_pct": round(overhead_pct, 2),
            "claim": "obs=trace adds < 5% to the close dispatch"}


def run(quick: bool = False) -> List[str]:
    """Harness entry point (benchmarks/run.py): emit CSV rows + the json."""
    result = run_bench(quick)
    with open(DEFAULT_OUT, "w") as f:
        json.dump(result, f, indent=2)
    rows = []
    for name, s in result["scenarios"].items():
        derived = (f"speedup={s['speedup']};old_us={s['old_us']};"
                   f"delivered={s['delivered']}")
        if "uniform_bitwise_vs_jit" in s:
            derived += f";bitwise_vs_jit={s['uniform_bitwise_vs_jit']}"
        rows.append(csv_row(f"aggregation/{name}", s["new_us"], derived))
    ov = result["obs_overhead"]
    rows.append(csv_row("aggregation/obs_overhead", ov["trace_us"],
                        f"off_us={ov['off_us']};"
                        f"overhead_pct={ov['overhead_pct']}"))
    cv = result["close_vs_c"]
    for s in cv["sweep"]:
        rows.append(csv_row(
            f"aggregation/close_vs_c/{s['c']}", s["chunked"]["close_us"],
            f"stacked_close_us={s['stacked']['close_us']};"
            f"stacked_peak_B={s['stacked']['peak_bytes']};"
            f"chunked_peak_B={s['chunked']['peak_bytes']};"
            f"mode={s['chunked']['mode']}"))
    rows.append(csv_row(
        "aggregation/close_vs_c/memory_wall",
        cv["top_chunked_peak_bytes"],
        f"baseline_B={cv['baseline_stacked_at_chunk_peak_bytes']};"
        f"ratio={cv['memory_ratio_vs_stacked_chunk']};"
        f"memory_ok={cv['memory_ok']}"))
    for s in result["hetero"]["sweep"]:
        rows.append(csv_row(
            f"aggregation/hetero/{s['c']}", s["new_us"],
            f"old_us={s['old_us']};speedup={s['speedup']};"
            f"max_diff={s['max_abs_diff_vs_eager']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    result = run_bench(args.quick)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
