"""Chaos soak: fault rate × method × participation under the defended uplink.

Each grid cell trains a small federated run with a seeded fault plan that
poisons three clients (NaN adapter, Inf adapter, truncated payload — every
DETECTABLE kind) at the cell's activation probability, then:

* **recall** — every injected detectable fault must have been quarantined
  (or dropped); the acceptance bar is 100 % at every cell,
* **precision** — every quarantined uplink must trace back to an injected
  fault (no clean client ever sacrificed; ``max_norm`` is off here so the
  only triggers are the finite/shape/bytes checks),
* **clean-lane exactness** — the cell is re-run under its crash-twin plan
  (same activation coins, faulty uplinks simply absent) and the final global
  adapter + base params must be bitwise identical,
* **rounds survived** — all rounds must complete with a finite global
  adapter (degraded rounds carry the previous global forward and count as
  survived-but-degraded).

A separate interleaved timing pass measures the validation overhead on the
clean path: a full coordinator round (encode → deliver → defended decode →
weighted close) with ``ValidationPolicy(enabled=True)`` vs ``enabled=False``
— docs/architecture.md claims the defended decode adds < 5 %.

Emits ``BENCH_robustness.json``:

  PYTHONPATH=src python -m benchmarks.chaos_soak [--quick] [--out F]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, env_metadata, federated_setting
from repro.configs import FedConfig, LoRAConfig, TrainConfig
from repro.core import FederatedTrainer
from repro.fedsrv import (AdapterCodec, ClientInfo, ClientRegistry,
                          RoundCoordinator, RoundPolicy, StragglerModel,
                          ValidationPolicy, weighted_close)
from repro.fedsrv.faults import DETECTABLE_KINDS

DEFAULT_OUT = "BENCH_robustness.json"
CLIENTS = 5


def _fault_plan(rate: float) -> str:
    """One spec per detectable kind, each pinned to its own client."""
    return ";".join(f"{kind}@{rate:g}(clients={i})"
                    for i, kind in enumerate(DETECTABLE_KINDS))


def _crash_twin(rate: float) -> str:
    return ";".join(f"crash@{rate:g}(clients={i})"
                    for i in range(len(DETECTABLE_KINDS)))


def _run_cell(method: str, participation: float, rate: float, *,
              rounds: int, local_steps: int, plan: str):
    """One soak run; fresh data/loaders every call so twin runs match."""
    cfg, model, loaders, evals = federated_setting(
        clients=CLIENTS, nseq=60, batch=8, seed=0)
    tr = FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
        fed_cfg=FedConfig(num_clients=CLIENTS, rounds=rounds,
                          local_steps=local_steps, method=method,
                          svd_rank=4 if method == "fedex_svd" else 0,
                          participation=participation, weighting="examples",
                          engine="auto", faults=plan),
        train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant",
                              total_steps=rounds * local_steps),
        client_loaders=loaders, eval_batches=evals, seed=0)
    hist = tr.run()
    return tr, hist


def _soak_cell(method: str, participation: float, rate: float, *,
               rounds: int, local_steps: int) -> Dict:
    t0 = time.time()
    tr, hist = _run_cell(method, participation, rate, rounds=rounds,
                         local_steps=local_steps, plan=_fault_plan(rate))

    # detectable injections vs actual quarantines/drops, as (round, client)
    injected = [(e["round"], e["client"]) for e in tr.fault_injector.injected
                if e["kind"] in DETECTABLE_KINDS]
    caught = set()
    for rnd, out in enumerate(tr.outcomes):
        for cid, _reason in out.quarantined:
            caught.add((rnd, cid))
    hits = sum(1 for pair in injected if pair in caught)
    recall = hits / len(injected) if injected else 1.0
    n_quar = sum(len(out.quarantined) for out in tr.outcomes)
    inj_set = set(injected)
    true_pos = sum(1 for rnd, out in enumerate(tr.outcomes)
                   for cid, _reason in out.quarantined
                   if (rnd, cid) in inj_set)
    precision = true_pos / n_quar if n_quar else 1.0

    survived = sum(1 for r in hist if np.isfinite(r.eval_loss))
    degraded = sum(1 for out in tr.outcomes if out.degraded)

    # crash-twin: same coins, the faulty uplinks simply never arrive — the
    # paper's exactness means the clean lanes close identically
    twin, _ = _run_cell(method, participation, rate, rounds=rounds,
                        local_steps=local_steps, plan=_crash_twin(rate))
    la = jax.tree.leaves((tr.global_lora, tr.params))
    lb = jax.tree.leaves((twin.global_lora, twin.params))
    clean_exact = all(np.array_equal(np.asarray(a), np.asarray(b))
                      for a, b in zip(la, lb))

    return {"method": method, "participation": participation,
            "fault_rate": rate, "rounds": rounds,
            "rounds_survived": survived, "degraded_rounds": degraded,
            "injected_detectable": len(injected), "quarantined": n_quar,
            "recall": round(recall, 4), "precision": round(precision, 4),
            "clean_exact": bool(clean_exact),
            "wall_s": round(time.time() - t0, 1)}


def _validation_overhead(quick: bool) -> Dict:
    """Clean-path coordinator round (encode → defended decode → close) with
    validation on vs off, interleaved best-of — the same stable estimator
    aggregation_bench uses for the obs overhead claim.

    Payloads are PAPER-shaped (every adapted projection of paper-tiny, via
    ``adapted_matrices``), not toy single-leaf trees: the validation cost is
    per-leaf Python + one reduction, so a toy payload would overstate it
    against a close that does almost no work."""
    from benchmarks.scenarios_participation import _fleet_loras
    from repro.configs import LoRAConfig, get_config
    from repro.core.comm import adapted_matrices

    rng = np.random.default_rng(0)
    k = 4 if quick else 8
    cfg = get_config("paper-tiny").reduced() if quick \
        else get_config("paper-tiny")
    mats = adapted_matrices(cfg, LoRAConfig(rank=4))
    loras = _fleet_loras(k, mats, rng)

    def one_round(enabled: bool) -> float:
        registry = ClientRegistry(
            [ClientInfo(i, num_examples=100) for i in range(k)])
        coord = RoundCoordinator(
            registry, RoundPolicy(participation=1.0, weighting="uniform"),
            StragglerModel(straggler_prob=0.0, seed=1),
            AdapterCodec("none",
                         validation=ValidationPolicy(enabled=enabled)))
        t0 = time.perf_counter()
        out = coord.run_round(0, lambda c, g, rnd: loras[c.client_id],
                              global_lora=loras[0])
        g, res = weighted_close(out, "fedex")
        jax.block_until_ready(jax.tree.leaves((g, res)))
        return 1e6 * (time.perf_counter() - t0)

    for enabled in (True, False):
        one_round(enabled)  # warm the jit caches for both modes
    reps = 3 if quick else 5
    best = {"on": float("inf"), "off": float("inf")}
    for _ in range(6):  # interleaved: machine drift hits both modes alike
        for label, enabled in (("on", True), ("off", False)):
            walls = [one_round(enabled) for _ in range(reps)]
            best[label] = min(best[label], sum(walls) / reps)
    validation_us = max(0.0, best["on"] - best["off"])

    # the gated overhead is against a full CLEAN federated round (local
    # training + ingest + close) — what a deployment actually pays; the
    # ingest-only ratio is reported alongside as the harsher microbenchmark
    # (per-leaf numpy dispatch vs an orchestration-only round)
    rounds = 2
    t0 = time.time()
    _run_cell("fedex", 1.0, 0.0, rounds=rounds, local_steps=2, plan="")
    round_wall_us = 1e6 * (time.time() - t0) / rounds
    overhead_pct = 100.0 * validation_us / round_wall_us
    return {"ingest_off_us": round(best["off"], 1),
            "ingest_on_us": round(best["on"], 1),
            "ingest_overhead_pct": round(
                100.0 * validation_us / best["off"], 2),
            "validation_us_per_round": round(validation_us, 1),
            "round_wall_us": round(round_wall_us, 1),
            "overhead_pct": round(overhead_pct, 3),
            "claim": "defended validation adds < 5% to a clean round"}


def run_bench(quick: bool = False) -> Dict:
    import logging
    for name in ("federated", "fedsrv"):
        logging.getLogger(name).setLevel(logging.WARNING)

    rates = (0.5,) if quick else (0.25, 0.75)
    methods = ("fedex",) if quick else ("fedex", "fedex_svd", "keep_local")
    parts = (1.0,) if quick else (0.6, 1.0)
    rounds = 2 if quick else 3
    local_steps = 2

    cells = [_soak_cell(m, p, r, rounds=rounds, local_steps=local_steps)
             for m in methods for p in parts for r in rates]
    overhead = _validation_overhead(quick)
    return {
        "config": {"clients": CLIENTS, "rounds": rounds,
                   "local_steps": local_steps, "fault_rates": list(rates),
                   "methods": list(methods), "participation": list(parts),
                   "detectable_kinds": list(DETECTABLE_KINDS)},
        "env": env_metadata(c_max=CLIENTS, suite="chaos_soak"),
        "cells": cells,
        "recall": min(c["recall"] for c in cells),
        "precision": min(c["precision"] for c in cells),
        "clean_exact": all(c["clean_exact"] for c in cells),
        "validation_overhead": overhead,
    }


def run(quick: bool = False) -> List[str]:
    """Harness entry point (benchmarks/run.py): emit CSV rows + the json."""
    result = run_bench(quick)
    with open(DEFAULT_OUT, "w") as f:
        json.dump(result, f, indent=2)
    rows = []
    for c in result["cells"]:
        rows.append(csv_row(
            f"chaos/{c['method']}-p{int(100 * c['participation'])}"
            f"-r{int(100 * c['fault_rate'])}",
            1e6 * c["wall_s"],
            f"recall={c['recall']};precision={c['precision']};"
            f"clean_exact={c['clean_exact']};"
            f"survived={c['rounds_survived']}/{c['rounds']};"
            f"degraded={c['degraded_rounds']}"))
    ov = result["validation_overhead"]
    rows.append(csv_row("chaos/validation_overhead",
                        ov["validation_us_per_round"],
                        f"overhead_pct={ov['overhead_pct']};"
                        f"ingest_overhead_pct={ov['ingest_overhead_pct']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    result = run_bench(args.quick)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
