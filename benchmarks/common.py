"""Shared benchmark plumbing: the synthetic federated setting + timing."""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List

import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.models import build_model

logging.getLogger("federated").setLevel(logging.WARNING)


def federated_setting(*, vocab=16, clients=3, seq=32, alpha=0.3, seed=0,
                      nseq=200, concentration=0.05, batch=16):
    """The paper's 3-client cross-silo setting over a synthetic non-IID corpus."""
    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                              vocab_size=vocab)
    model = build_model(cfg)
    ds = SyntheticLM(vocab=vocab, num_tasks=clients, seed=seed,
                     concentration=concentration)
    seqs, labels = [], []
    for t in range(clients):
        s = ds.sample(task=t, num_sequences=nseq, seq_len=seq, seed=seed + t)
        seqs.append(s)
        labels += [t] * nseq
    seqs = np.concatenate(seqs)
    parts = dirichlet_partition(np.array(labels), clients, alpha=alpha, seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=batch, seed=seed + i)
               for i, p in enumerate(parts)]
    evals = [ds.to_batch(ds.sample(task=t, num_sequences=16, seq_len=seq,
                                   seed=seed + 500 + t)) for t in range(clients)]
    return cfg, model, loaders, evals


def run_method(method: str, *, rounds=5, local_steps=25, rank=8, lr=3e-2,
               assignment="average", svd_rank=0, seed=0, setting_seed=0,
               include_mlp=True, schedule="constant"):
    cfg, model, loaders, evals = federated_setting(seed=setting_seed)
    t0 = time.time()
    tr = FederatedTrainer(
        model=model,
        lora_cfg=LoRAConfig(rank=rank, alpha=2 * rank, include_mlp=include_mlp),
        fed_cfg=FedConfig(num_clients=3, rounds=rounds, local_steps=local_steps,
                          method=method, assignment=assignment,
                          svd_rank=svd_rank),
        train_cfg=TrainConfig(learning_rate=lr, schedule=schedule,
                              total_steps=rounds * local_steps),
        client_loaders=loaders, eval_batches=evals, seed=seed)
    hist = tr.run()
    wall = time.time() - t0
    return {
        "method": method if assignment == "average" else f"fedex/{assignment}",
        "rank": rank,
        "final_eval_loss": hist[-1].eval_loss,
        "final_eval_acc": hist[-1].eval_acc,
        "divergence": hist[-1].divergence_scaled,
        "history": [r.eval_loss for r in hist],
        "divergence_history": [r.divergence_scaled for r in hist],
        "wall_s": wall,
        "us_per_call": 1e6 * wall / (rounds * local_steps * 3),
    }


def env_metadata(**extra) -> Dict:
    """BENCH-json environment stamp: jax version + device identity, so a
    recorded perf number can never be attributed to the wrong hardware.
    ``extra`` merges bench-specific context (C_max, method, …)."""
    import jax

    dev = jax.devices()[0]
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "platform": dev.platform,
            "device_count": jax.device_count(),
            **extra}


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
