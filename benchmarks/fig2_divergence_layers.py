"""Paper Figure 2 (+ Figs 4–5): scaled Frobenius deviation of FedAvg vs ideal
updates, per layer, Q vs V matrices, after the first aggregation, for
local epochs ∈ {3, 10} (here: local steps {5, 20}).

Claims checked: (1) deviation > 0 everywhere, (2) grows with local training,
(3) Q > V on average (the paper's observation 3).
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import csv_row, federated_setting
from repro.configs import LoRAConfig, TrainConfig
from repro.core import init_lora
from repro.core.divergence import deviation_tree, flatten_deviations
from repro.core.federated import make_local_step
from repro.optim import init_adamw
import jax


def client_adapters_after(local_steps: int, *, rank=4, lr=2e-2, seed=0):
    cfg, model, loaders, _ = federated_setting(seed=seed)
    params = model.init(jax.random.key(seed))
    lcfg = LoRAConfig(rank=rank, alpha=2 * rank)  # attention-only: Q/K/V/O
    lora0 = init_lora(jax.random.key(seed + 1), params, cfg, lcfg)
    step = make_local_step(model, lcfg.scale, TrainConfig(learning_rate=lr))
    out = []
    for c in range(3):
        lora, opt = lora0, init_adamw(lora0)
        for _ in range(local_steps):
            lora, opt, _, _ = step(params, lora, opt, loaders[c].next_batch(), lr)
        out.append(lora)
    return out


def run(quick: bool = False) -> List[str]:
    rows = []
    per_steps = {}
    for local_steps in ((5,) if quick else (5, 20)):
        loras = client_adapters_after(local_steps)
        dev = flatten_deviations(deviation_tree(loras), "scaled")
        q = np.asarray(dev["layers/attn/q_proj"])  # (num_layers,)
        v = np.asarray(dev["layers/attn/v_proj"])
        per_steps[local_steps] = (q, v)
        for layer in range(len(q)):
            rows.append(csv_row(
                f"fig2/steps{local_steps}/layer{layer}", 0.0,
                f"q={q[layer]:.3e};v={v[layer]:.3e}"))
        rows.append(csv_row(
            f"fig2/steps{local_steps}/positive_everywhere", 0.0,
            f"holds={bool((q > 0).all() and (v > 0).all())}"))
    if len(per_steps) == 2:
        q5, _ = per_steps[5]
        q20, _ = per_steps[20]
        rows.append(csv_row("fig2/grows_with_local_steps", 0.0,
                            f"holds={bool(q20.mean() > q5.mean())};"
                            f"mean5={q5.mean():.3e};mean20={q20.mean():.3e}"))
    return rows
