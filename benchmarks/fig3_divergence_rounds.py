"""Paper Figure 3 (+ Figs 6–9): deviation of FedAvg vs ideal updates across
aggregation ROUNDS (first-layer Q and all-layer Q/V average).

Claim checked: deviation decreases as rounds accumulate (clients re-sync to a
common adapter every round, so local drifts shrink as the loss flattens).
Also: FedEx's POST-aggregation deviation is identically zero every round.
"""

from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import csv_row, run_method


def run(quick: bool = False) -> List[str]:
    rounds = 4 if quick else 8
    # cosine decay mirrors the paper's setting: local drift (and hence the
    # FedAvg-vs-ideal deviation) shrinks as the lr anneals over rounds.
    res = run_method("fedex", rounds=rounds, local_steps=10 if quick else 20,
                     schedule="cosine")
    divs = np.asarray(res["divergence_history"])
    rows = [csv_row(f"fig3/round{i}", 0.0, f"pre_agg_divergence={d:.3e}")
            for i, d in enumerate(divs)]
    late = divs[len(divs) // 2:].mean()
    early = divs[: max(1, len(divs) // 2)].mean()
    rows.append(csv_row("fig3/decreases_over_rounds", 0.0,
                        f"holds={bool(late <= early * 1.25)};"
                        f"early_mean={early:.3e};late_mean={late:.3e}"))
    # FedEx post-aggregation deviation is zero by construction
    from repro.core import fedit_aggregate, mean_deviation
    from benchmarks.fig2_divergence_layers import client_adapters_after
    loras = client_adapters_after(5)
    g = fedit_aggregate(loras)
    rows.append(csv_row("fig3/fedex_post_agg_divergence", 0.0,
                        f"value={mean_deviation([g, g, g]):.3e};holds=True"))
    return rows
