"""Kernel microbenchmarks: wall time of the jnp reference paths on CPU (the
Pallas kernels themselves target TPU; interpret-mode timing is meaningless,
so we time the production jnp twins and validate the kernels' allclose here),
plus derived arithmetic intensities used in §Perf napkin math.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels import ref
from repro.kernels.fedex_residual import fedex_residual_apply
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.flash_swa import flash_swa


def _time(fn, *args, reps=5):
    # block on the compile call too — otherwise async dispatch lets the first
    # timed iteration absorb compilation and skews small-rep measurements
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / reps


def run(quick: bool = False) -> List[str]:
    rows = []
    rng = np.random.default_rng(0)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)

    # -- lora_matmul ---------------------------------------------------------
    m, k, n, r = (256, 512, 512, 8) if quick else (512, 1024, 1024, 16)
    x, w, a, b = mk((m, k)), mk((k, n)), mk((k, r)), mk((r, n))
    base_flops = 2 * m * k * n
    adapter_flops = 2 * m * r * (k + n)
    us = _time(jax.jit(lambda *t: ref.lora_matmul_ref(*t, 0.5)), x, w, a, b)
    kern = lora_matmul(x, w, a, b, scale=0.5, interpret=True)
    err = float(jnp.abs(kern - ref.lora_matmul_ref(x, w, a, b, 0.5)).max())
    rows.append(csv_row(
        "kernels/lora_matmul", us,
        f"adapter_flop_overhead={adapter_flops/base_flops:.4f};"
        f"interpret_allclose_err={err:.2e}"))

    # -- fedex_residual ------------------------------------------------------
    c, m2, n2, r2 = 3, 512, 512, 8
    w0, a_s, b_s = mk((m2, n2)), mk((c, m2, r2)), mk((c, r2, n2))
    us = _time(jax.jit(lambda *t: ref.fedex_residual_ref(*t, 1.0)), w0, a_s, b_s)
    kern = fedex_residual_apply(w0, a_s, b_s, scale=1.0, interpret=True)
    err = float(jnp.abs(kern - ref.fedex_residual_ref(w0, a_s, b_s, 1.0)).max())
    naive_hbm = 3 * m2 * n2 * 4  # dense residual write + read + W0 update
    fused_hbm = 2 * m2 * n2 * 4 + (c + 1) * (m2 + n2) * r2 * 4
    rows.append(csv_row(
        "kernels/fedex_residual", us,
        f"hbm_traffic_vs_naive={fused_hbm/naive_hbm:.3f};"
        f"interpret_allclose_err={err:.2e}"))

    # -- fedex_residual, weighted/masked (fedsrv ragged rounds) --------------
    wv = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)
    us = _time(jax.jit(lambda *t: ref.fedex_residual_ref(*t, 1.0, weights=wv)),
               w0, a_s, b_s)
    kern = fedex_residual_apply(w0, a_s, b_s, wv, scale=1.0, interpret=True)
    err = float(jnp.abs(kern - ref.fedex_residual_ref(w0, a_s, b_s, 1.0,
                                                      weights=wv)).max())
    rows.append(csv_row(
        "kernels/fedex_residual_weighted", us,
        f"hbm_traffic_vs_naive={fused_hbm/naive_hbm:.3f};"
        f"interpret_allclose_err={err:.2e}"))

    # -- stacked-Gram truncated SVD (the engine's fedex_svd close path) ------
    from repro.core.engine import factored_truncated_residual

    c3, m3, r3, n3 = (4, 256, 8, 256) if quick else (8, 512, 8, 512)
    trunc_rank = r3
    a3, b3 = mk((c3, m3, r3)), mk((c3, r3, n3))
    wv3 = jnp.full((c3,), 1.0 / c3, jnp.float32)
    us = _time(jax.jit(lambda a, b, w: factored_truncated_residual(
        a, b, w, trunc_rank)), a3, b3, wv3)

    def _dense_trunc(a, b, w):  # the eager oracle: dense residual + full SVD
        res = (jnp.einsum("c,cmr,crn->mn", w, a, b)
               - jnp.einsum("c,cmr->mr", w, a) @ jnp.einsum("c,crn->rn", w, b))
        u, s, vt = jnp.linalg.svd(res, full_matrices=False)
        return (u[:, :trunc_rank] * s[:trunc_rank]) @ vt[:trunc_rank]

    dense_us = _time(jax.jit(_dense_trunc), a3, b3, wv3)
    ap, bp = factored_truncated_residual(a3, b3, wv3, trunc_rank)
    err = float(jnp.abs(ap @ bp - _dense_trunc(a3, b3, wv3)).max())
    # the small-matrix path: two (C·r)² Grams + eigh + one (C·r)² SVD vs one
    # dense m×n SVD — O(mn·Cr + (Cr)³) instead of O(mn·min(m,n))
    rows.append(csv_row(
        "kernels/stacked_gram_svd", us,
        f"dense_svd_us={dense_us:.1f};speedup_vs_dense={dense_us / us:.2f};"
        f"gram_dim={c3 * r3};allclose_err={err:.2e}"))

    # -- flash_swa -----------------------------------------------------------
    bh, s, d, win = (4, 512, 64, 128) if quick else (8, 1024, 64, 256)
    q, kk, v = mk((bh, s, d)), mk((bh, s, d)), mk((bh, s, d))
    us = _time(jax.jit(lambda *t: ref.flash_swa_ref(*t, causal=True, window=win)),
               q, kk, v)
    kern = flash_swa(q, kk, v, causal=True, window=win, bq=128, bk=128,
                     interpret=True)
    err = float(jnp.abs(kern - ref.flash_swa_ref(q, kk, v, causal=True,
                                                 window=win)).max())
    # windowed kernel touches O(win) KV per query vs O(S) for dense
    rows.append(csv_row(
        "kernels/flash_swa", us,
        f"kv_touched_fraction={min(1.0, 2*win/s):.3f};"
        f"interpret_allclose_err={err:.2e}"))
    return rows
