"""§Roofline report: reads the dry-run JSONL records (dryrun_single.json)
and emits the per-(arch × shape) three-term roofline rows used in
EXPERIMENTS.md. If the dry-run hasn't been executed, emits a pointer row
instead of failing (the dry-run is a separate 512-device process).
"""

from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import csv_row

CANDIDATES = ("dryrun_single.json", "/root/repo/dryrun_single.json")


def run(quick: bool = False) -> List[str]:
    path = next((p for p in CANDIDATES if os.path.exists(p)), None)
    if path is None:
        return [csv_row("roofline/missing", 0.0,
                        "run: python -m repro.launch.dryrun --all --mesh single "
                        "--out dryrun_single.json")]
    rows = []
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            rows.append(csv_row(name, 0.0, "skipped=" + r["reason"][:60]))
            continue
        if r["status"] != "ok":
            rows.append(csv_row(name, 0.0, "error=" + r.get("error", "?")[:80]))
            continue
        rf = r["roofline"]
        step_us = 1e6 * max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append(csv_row(
            name, step_us,
            f"compute_s={rf['compute_s']:.3e};memory_s={rf['memory_s']:.3e};"
            f"collective_s={rf['collective_s']:.3e};dominant={rf['dominant']};"
            f"useful_flops_ratio={(rf.get('useful_flops_ratio') or 0):.3f}"))
    return rows
