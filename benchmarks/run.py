"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks budgets for CI.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table6,...]
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    aggregation_bench,
    chaos_soak,
    fig2_divergence_layers,
    fig3_divergence_rounds,
    kernels_bench,
    roofline_report,
    scenarios_participation,
    table5_assignment,
    table6_comm,
    table9_rank_sweep,
    tables_convergence,
)

SUITES = {
    "tables1-4": tables_convergence,
    "table5": table5_assignment,
    "table6": table6_comm,
    "table9": table9_rank_sweep,
    "fig2": fig2_divergence_layers,
    "fig3": fig3_divergence_rounds,
    "kernels": kernels_bench,
    "aggregation": aggregation_bench,
    "roofline": roofline_report,
    "participation": scenarios_participation,
    "chaos": chaos_soak,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated suite names")
    args = ap.parse_args()

    wanted = [s.strip() for s in args.only.split(",") if s.strip()] or list(SUITES)
    print("name,us_per_call,derived")
    from benchmarks.common import env_metadata
    env = env_metadata()
    print("env/_metadata,0.0," + ";".join(f"{k}={v}" for k, v in env.items()))
    failures = 0
    for name in wanted:
        mod = SUITES[name]
        t0 = time.time()
        try:
            for row in mod.run(quick=args.quick):
                print(row)
        except Exception as e:  # report, keep the harness going
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{str(e)[:120]}")
        print(f"{name}/_suite_wall,{1e6 * (time.time() - t0):.0f},ok",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
