"""Participation-regime sweep: fedsrv coordinator vs analytic comm accounting.

For a synthetic fleet (k clients, unequal shard sizes), sweeps the round
participation fraction and reports, per fraction:

* delivered-client count and weighted-exactness error of the folded residual
  (must stay at fp32 noise — the paper's guarantee under partial
  participation),
* measured uplink params from the transport BytesLedger vs the closed-form
  ``core/comm.py::round_comm_params(participation_fraction=·)`` — the two
  accountings must agree exactly,
* wall time per simulated round (host-side orchestration overhead).
"""

from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import LoRAConfig, get_config
from repro.core import apply_residual, product_mean
from repro.core.comm import adapted_matrices, round_comm_params
from repro.fedsrv import (AdapterCodec, ClientInfo, ClientRegistry,
                          RoundCoordinator, RoundPolicy, StragglerModel,
                          weighted_close)

RANK = 4


def _fleet_loras(k: int, mats, rng) -> dict:
    """Per-client adapter trees matching the model's adapted matrices."""
    out = {}
    for i in range(k):
        tree = {}
        for ms in mats:
            layer, name = ms.name.split("/")
            tree.setdefault(layer, {})[name] = {
                "a": jnp.asarray(rng.normal(size=(ms.m, RANK)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(RANK, ms.n)), jnp.float32)}
        out[i] = tree
    return out


def run(quick: bool = False) -> List[str]:
    import logging
    logging.getLogger("fedsrv").setLevel(logging.WARNING)  # keep CSV clean

    rows: List[str] = []
    cfg = get_config("paper-tiny").reduced() if quick else get_config("paper-tiny")
    lcfg = LoRAConfig(rank=RANK)
    mats = adapted_matrices(cfg, lcfg)
    k = 8 if quick else 20
    rng = np.random.default_rng(0)
    loras = _fleet_loras(k, mats, rng)

    for frac in (0.1, 0.3, 0.5, 1.0):
        registry = ClientRegistry(
            [ClientInfo(i, num_examples=int(rng.integers(40, 500)))
             for i in range(k)], seed=1)
        coord = RoundCoordinator(
            registry, RoundPolicy(participation=frac, weighting="examples"),
            StragglerModel(straggler_prob=0.15, seed=2), AdapterCodec("none"))
        t0 = time.time()
        outcome = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                                  global_lora=loras[0])
        g, res = weighted_close(outcome, "fedex")
        wall_us = 1e6 * (time.time() - t0)

        # exactness of the weighted fold over the delivered subset
        ideal = product_mean([d.lora for d in outcome.delivered],
                             outcome.weights)
        err = 0.0
        for layer in ideal:
            for name in ideal[layer]:
                w_eff = (res[layer][name]
                         + jnp.matmul(g[layer][name]["a"], g[layer][name]["b"]))
                err = max(err, float(jnp.max(jnp.abs(
                    w_eff - ideal[layer][name]))))

        analytic = round_comm_params("fedex", mats, RANK, k,
                                     participation_fraction=frac)
        measured = coord.ledger.round_totals(0)
        match = measured["uplink_params"] == analytic["uplink"]
        rows.append(csv_row(
            f"participation/f{int(frac * 100)}", wall_us,
            f"delivered={len(outcome.delivered)};exact_err={err:.2e};"
            f"uplink_measured={measured['uplink_params']};"
            f"uplink_analytic={analytic['uplink']};ledger_match={match}"))
    return rows
