"""Paper Table 5: assignment strategies for (Aᵢ, Bᵢ) after exact aggregation.

All three are exact; the paper finds 'average' (FedEx) converges best,
'reinit' worst (the adapters lose their optimizer-aligned basis every round).
"""

from __future__ import annotations

from typing import List

from benchmarks.common import csv_row, run_method

STRATEGIES = ("average", "keep_local", "reinit")


def run(quick: bool = False) -> List[str]:
    rounds = 3 if quick else 6
    steps = 10 if quick else 25
    seeds = (0,) if quick else (0, 1, 2)
    rows = []
    results = {}
    for strategy in STRATEGIES:
        runs = [run_method("fedex", assignment=strategy, rounds=rounds,
                           local_steps=steps, seed=s, setting_seed=s)
                for s in seeds]
        loss = sum(r["final_eval_loss"] for r in runs) / len(runs)
        acc = sum(r["final_eval_acc"] for r in runs) / len(runs)
        results[strategy] = loss
        rows.append(csv_row(
            f"table5/{strategy}", runs[0]["us_per_call"],
            f"eval_loss={loss:.4f};eval_acc={acc:.4f}"))
    rows.append(csv_row(
        "table5/average_beats_reinit", 0.0,
        f"holds={results['average'] <= results['reinit'] + 0.02};"
        f"average={results['average']:.4f};reinit={results['reinit']:.4f}"))
    return rows
