"""Paper Table 6: communication-cost ratios vs FedEx-LoRA.

Exact parameter accounting (core/comm.py) for RoBERTa-base, RoBERTa-large and
GPT-2 at rank r=4, k=3 clients, 5 rounds — the paper's setting. The paper's
qualitative claims checked: full-FT ≫ FedEx; FedIT/FFA marginally below 1.
"""

from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import csv_row
from repro.configs import LoRAConfig, get_config
from repro.configs.base import ModelConfig
from repro.core.comm import comm_table

ROBERTA_BASE = ModelConfig(
    name="roberta-base", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=50_265,
    norm="layernorm", act="gelu", rope=False)
ROBERTA_LARGE = ModelConfig(
    name="roberta-large", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=50_265,
    norm="layernorm", act="gelu", rope=False)


def run(quick: bool = False) -> List[str]:
    rows = []
    lcfg = LoRAConfig(rank=4)
    for cfg in (ROBERTA_BASE, ROBERTA_LARGE, get_config("paper-gpt2")):
        table = comm_table(cfg, lcfg, k=3, rounds=5)
        ratios = {m: table[m]["ratio_to_fedex"] for m in table}
        rows.append(csv_row(
            f"table6/{cfg.name}", 0.0,
            f"full_ft={ratios['full_ft']:.3f};fedex=1.000;"
            f"fedit={ratios['fedit']:.3f};ffa={ratios['ffa']:.3f};"
            f"fedex_svd_r4={ratios['fedex_svd']:.3f}"))
        ok = (ratios["full_ft"] > 2.0 and ratios["fedit"] < 1.0
              and ratios["ffa"] < ratios["fedit"])
        rows.append(csv_row(f"table6/{cfg.name}/orderings", 0.0, f"holds={ok}"))
        # beyond-paper: cross-device regime — FedEx traffic vs participation
        # fraction (k=20 fleet; fedsrv samples ⌈f·k⌉ clients per round).
        full = comm_table(cfg, lcfg, k=20, rounds=5,
                          participation_fraction=1.0)["fedex"]["params"]
        parts = []
        for frac in (0.1, 0.5, 1.0):
            t = comm_table(cfg, lcfg, k=20, rounds=5,
                           participation_fraction=frac)
            parts.append(f"p{int(frac * 100)}={t['fedex']['params'] / full:.3f}")
        rows.append(csv_row(f"table6/{cfg.name}/participation", 0.0,
                            ";".join(parts)))
    return rows
