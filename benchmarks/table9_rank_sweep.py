"""Paper Table 9 (Appendix C): effect of varying LoRA rank.

FedEx vs FedIT vs FFA at r ∈ {1, 4, 8}; the claim checked is that FedEx stays
≥ FedIT at every rank (paper: across all rank configurations).
"""

from __future__ import annotations

from typing import List

from benchmarks.common import csv_row, run_method

RANKS = (1, 4, 8)


def run(quick: bool = False) -> List[str]:
    rounds = 2 if quick else 5
    steps = 10 if quick else 25
    ranks = (1, 8) if quick else RANKS
    rows = []
    wins = 0
    seeds = (0,) if quick else (0, 1)
    for r in ranks:
        res = {}
        for m in ("fedex", "fedit", "ffa"):
            runs = [run_method(m, rank=r, rounds=rounds, local_steps=steps,
                               seed=s, setting_seed=s) for s in seeds]
            res[m] = {
                "final_eval_loss": sum(x["final_eval_loss"] for x in runs) / len(runs),
                "us_per_call": runs[0]["us_per_call"],
            }
        wins += res["fedex"]["final_eval_loss"] <= res["fedit"]["final_eval_loss"] + 0.02
        rows.append(csv_row(
            f"table9/r{r}", res["fedex"]["us_per_call"],
            ";".join(f"{m}={res[m]['final_eval_loss']:.4f}" for m in res)))
    rows.append(csv_row("table9/fedex_ge_fedit_all_ranks", 0.0,
                        f"wins={wins}/{len(ranks)}"))
    return rows
