"""Paper Tables 1–4 analog: federated LoRA method comparison.

GLUE/E2E/GSM8K are offline-unavailable; the claim validated is the ORDERING
Centralized ≈ FedEx ≤ FedIT ≤ FFA (eval loss; lower better) on non-IID
synthetic federated LM tasks, plus the exact-aggregation property itself
(divergence column: FedEx post-aggregation deviation ≡ 0; FedIT > 0).
"""

from __future__ import annotations

from typing import List

from benchmarks.common import csv_row, run_method

METHODS = ("centralized", "fedex", "fedit", "ffa")


def run(quick: bool = False) -> List[str]:
    rounds = 3 if quick else 6
    steps = 10 if quick else 25
    rows: List[str] = []
    results = {}
    # 3 random runs as in the paper (§5: "average of 3 different random runs")
    seeds = [0] if quick else [0, 1, 2]
    for method in METHODS:
        runs = [run_method(method, rounds=rounds, local_steps=steps,
                           seed=s, setting_seed=s) for s in seeds]
        loss = sum(r["final_eval_loss"] for r in runs) / len(runs)
        acc = sum(r["final_eval_acc"] for r in runs) / len(runs)
        div = sum(r["divergence"] for r in runs) / len(runs)
        us = sum(r["us_per_call"] for r in runs) / len(runs)
        results[method] = loss
        rows.append(csv_row(
            f"table1-4/{method}", us,
            f"eval_loss={loss:.4f};eval_acc={acc:.4f};pre_agg_divergence={div:.3e}"))
    # the paper's headline ordering, as a derived pass/fail
    ok_order = results["fedex"] <= results["fedit"] + 0.02
    rows.append(csv_row("table1-4/ordering_fedex_le_fedit", 0.0,
                        f"holds={ok_order};fedex={results['fedex']:.4f};"
                        f"fedit={results['fedit']:.4f}"))
    return rows
