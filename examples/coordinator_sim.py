"""fedsrv coordinator scenario demo — sync, deadline-drop, async-buffer,
truncated-svd and assignment-strategy closes through the fused engine.

Federated runs of the tiny paper model under the event-driven coordinator
(src/repro/fedsrv/), each printing the per-round outcome (sampled/delivered/
dropped clients, weights), WHICH close path ran (the core/engine.py fused
engine vs the eager list path — every scenario here exercises the engine via
``FedConfig.engine``), and the measured comm ledger, plus a direct
weighted-exactness check on synthetic adapters.

With ``--trace`` / ``--metrics-out`` every scenario records through ONE
shared obs recorder (repro.obs) under its own run label, so a single trace /
metrics stream holds all scenarios side by side — ``scripts/obs_report.py``
summarizes it and ``--check`` proves the overlap invariant on it (this is
CI's obs smoke step, with ``--quick``).

  PYTHONPATH=src python examples/coordinator_sim.py        # ~1–2 min CPU
  PYTHONPATH=src python examples/coordinator_sim.py --quick \
      --trace /tmp/trace.json --metrics-out /tmp/metrics.jsonl
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer, apply_residual, fedex_aggregate, product_mean
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.fedsrv import (AdapterCodec, ClientInfo, ClientRegistry,
                          RoundCoordinator, RoundPolicy, StragglerModel,
                          weighted_close)
from repro.models import build_model

VOCAB = 64
CLIENTS = 5


def build_data(seed=0):
    ds = SyntheticLM(vocab=VOCAB, num_tasks=CLIENTS, seed=seed)
    seqs, labels = [], []
    for t in range(CLIENTS):
        # deliberately unequal shard sizes → non-uniform example weights
        n = 40 + 25 * t
        seqs.append(ds.sample(task=t, num_sequences=n, seq_len=32, seed=seed + t))
        labels += [t] * n
    seqs = np.concatenate(seqs)
    parts = dirichlet_partition(np.array(labels), CLIENTS, alpha=0.5, seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=8, seed=seed + i)
               for i, p in enumerate(parts)]
    evals = [ds.to_batch(ds.sample(task=t, num_sequences=8, seq_len=32,
                                   seed=seed + 100 + t)) for t in range(CLIENTS)]
    return loaders, evals


def run_scenario(title: str, fed_cfg: FedConfig, loaders, evals, model,
                 recorder=None):
    print(f"\n=== {title} ===")
    t0 = time.time()
    if recorder is not None:
        # one shared recorder across scenarios; the run label namespaces
        # this scenario's rounds/spans (round 0 of scenario 2 never merges
        # into round 0 of scenario 1)
        recorder.set_run(title.split(":")[0].replace(" ", "-"))
    trainer = FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
        fed_cfg=fed_cfg,
        train_cfg=TrainConfig(learning_rate=5e-3, schedule="constant",
                              total_steps=fed_cfg.rounds * fed_cfg.local_steps),
        client_loaders=loaders, eval_batches=evals, seed=0,
        recorder=recorder)
    if trainer.engine is not None:
        print(f"  close path: fused engine (method={trainer.engine.method} "
              f"backend={trainer.engine.backend} "
              f"ring depth={trainer.engine.buffers.depth})")
    else:
        print("  close path: eager list-of-trees")
    history = trainer.run()
    for rec, out in zip(history, trainer.outcomes):
        w = ("uniform" if out.weights is None
             else "[" + ", ".join(f"{x:.2f}" for x in out.weights) + "]")
        print(f"  round {rec.round}: sampled={out.sampled} "
              f"delivered={out.client_ids} dropout={out.dropped_out} "
              f"deadline_drop={out.dropped_deadline} weights={w} "
              f"eval_loss={rec.eval_loss:.4f} "
              f"close_t={out.closed_at:.2f}s")
    print("  comm ledger (measured):")
    for line in trainer.ledger.summary_lines():
        print("    " + line)
    print(f"  [{time.time() - t0:.1f}s]")


def exactness_check():
    """Direct coordinator round on synthetic adapters: the folded weighted
    residual reproduces W0 + scale·Σwᵢaᵢbᵢ over the delivered subset."""
    print("\n=== weighted exactness (synthetic adapters) ===")
    rng = np.random.default_rng(0)
    k, m, r, n = 6, 32, 4, 24
    registry = ClientRegistry(
        [ClientInfo(i, num_examples=int(rng.integers(50, 400))) for i in range(k)])
    coord = RoundCoordinator(
        registry,
        RoundPolicy(participation=0.6, weighting="examples"),
        StragglerModel(straggler_prob=0.2, seed=3),
        AdapterCodec("none"))
    loras = {i: {"q_proj": {
        "a": jnp.asarray(rng.normal(size=(m, r)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(r, n)), jnp.float32)}}
        for i in range(k)}
    outcome = coord.run_round(0, lambda c, g, rnd: loras[c.client_id],
                              global_lora=loras[0])
    g, res = weighted_close(outcome, "fedex")
    w0 = jnp.zeros((m, n))
    scale = 2.0
    ideal = product_mean([d.lora for d in outcome.delivered], outcome.weights)
    w_eff = (apply_residual({"q_proj": {"kernel": w0}}, res, scale)
             ["q_proj"]["kernel"]
             + scale * jnp.matmul(g["q_proj"]["a"], g["q_proj"]["b"]))
    w_ideal = w0 + scale * ideal["q_proj"]
    err = float(jnp.max(jnp.abs(w_eff - w_ideal)))
    print(f"  delivered={outcome.client_ids} weights="
          + "[" + ", ".join(f"{x:.3f}" for x in outcome.weights) + "]")
    print(f"  max |W_eff − W_ideal| = {err:.2e}  (fp32 exact ≤ 1e-5)")
    assert err < 1e-5


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON covering every "
                         "scenario (implies obs=trace)")
    ap.add_argument("--metrics-out", default="",
                    help="write the obs metrics JSONL stream here "
                         "(scripts/obs_report.py reads it)")
    ap.add_argument("--quick", action="store_true",
                    help="scenarios 1 + 3 only, 2 rounds each (the CI obs "
                         "smoke configuration)")
    args = ap.parse_args()

    rec = None
    if args.trace or args.metrics_out:
        from repro.obs import make_recorder
        rec = make_recorder("trace" if args.trace else "basic")

    t_start = time.time()
    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                              vocab_size=VOCAB)
    model = build_model(cfg)
    loaders, evals = build_data()

    # engine="auto" on every scenario: all closes run through the fused
    # single-dispatch engine (core/engine.py), not the eager list path
    rounds = 2 if args.quick else 3
    base = dict(num_clients=CLIENTS, rounds=rounds, local_steps=3,
                method="fedex", weighting="examples", engine="auto")
    run_scenario("scenario 1: sync, 60% participation, example weights",
                 FedConfig(**base, participation=0.6), loaders, evals, model,
                 recorder=rec)
    if not args.quick:
        run_scenario("scenario 2: deadline drops stragglers (quorum 2)",
                     FedConfig(**base, straggler_prob=0.4,
                               straggler_factor=8.0, dropout_prob=0.1,
                               round_deadline=2.5, min_quorum=2),
                     loaders, evals, model, recorder=rec)
    # depth-3 ring: FedBuff commits may pipeline two stack sets deep while
    # a third streams — the configuration the obs overlap check runs on
    run_scenario("scenario 3: async FedBuff buffer=2, int8 uplink, "
                 "depth-3 ring",
                 FedConfig(**base, participation=0.6, async_buffer=2,
                           straggler_prob=0.3, straggler_factor=6.0,
                           quantize_uplink="int8", ring_depth=3,
                           ring_max_lag=2),
                 loaders, evals, model, recorder=rec)
    if not args.quick:
        run_scenario("scenario 4: fedex_svd rank-4 truncated close (factored "
                     "Gram SVD in the engine — no dense residual)",
                     FedConfig(**{**base, "method": "fedex_svd"}, svd_rank=4,
                               participation=0.8), loaders, evals, model,
                     recorder=rec)
        run_scenario("scenario 5: keep_local assignment (per-client bases, "
                     "engine per-lane folds)",
                     FedConfig(**{**base, "weighting": "uniform"},
                               assignment="keep_local"), loaders, evals,
                     model, recorder=rec)
    exactness_check()
    if rec is not None:
        rec.set_run(None)
        print()
        for line in rec.summary_lines():
            print(line)
        if args.trace:
            rec.write_trace(args.trace)
            print(f"trace → {args.trace} (Perfetto / chrome://tracing)")
        if args.metrics_out:
            rec.write_metrics(args.metrics_out)
            print(f"metrics JSONL → {args.metrics_out} "
                  "(scripts/obs_report.py)")
    print(f"\ntotal wall time: {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
