"""fedsrv coordinator scenario demo — sync, deadline-drop, async-buffer,
truncated-svd and assignment-strategy closes through the fused engine.

Federated runs of the tiny paper model under the event-driven coordinator
(src/repro/fedsrv/), each printing the per-round outcome (sampled/delivered/
dropped clients, weights), WHICH close path ran (the core/engine.py fused
engine vs the eager list path — every scenario here exercises the engine via
``FedConfig.engine``), and the measured comm ledger, plus a direct
weighted-exactness check on synthetic adapters.

With ``--trace`` / ``--metrics-out`` every scenario records through ONE
shared obs recorder (repro.obs) under its own run label, so a single trace /
metrics stream holds all scenarios side by side — ``scripts/obs_report.py``
summarizes it and ``--check`` proves the overlap invariant on it (this is
CI's obs smoke step, with ``--quick``).

  PYTHONPATH=src python examples/coordinator_sim.py        # ~1–2 min CPU
  PYTHONPATH=src python examples/coordinator_sim.py --quick \
      --trace /tmp/trace.json --metrics-out /tmp/metrics.jsonl
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer, apply_residual, fedex_aggregate, product_mean
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.fedsrv import (AdapterCodec, ClientInfo, ClientRegistry,
                          FaultPlan, RoundCoordinator, RoundPolicy,
                          StragglerModel, weighted_close)
from repro.models import build_model

VOCAB = 64
CLIENTS = 5

# default chaos plan: a NaN-poisoned adapter, a truncated payload and a
# replayed uplink — every kind the defended ingest path must neutralise,
# and every one of them crash-twin safe (the faulty client contributes
# nothing to the close, exactly as if it had crashed)
DEFAULT_CHAOS_PLAN = "nan@1(clients=1);truncate@1(clients=2);replay@1(clients=3,offset=1)"

# kinds whose faulty uplink is fully excluded from the close (quarantined
# or dropped), so replacing them with ``crash`` yields a bitwise twin
_TWIN_SAFE = {"nan", "inf", "truncate", "replay", "crash"}


def build_data(seed=0):
    ds = SyntheticLM(vocab=VOCAB, num_tasks=CLIENTS, seed=seed)
    seqs, labels = [], []
    for t in range(CLIENTS):
        # deliberately unequal shard sizes → non-uniform example weights
        n = 40 + 25 * t
        seqs.append(ds.sample(task=t, num_sequences=n, seq_len=32, seed=seed + t))
        labels += [t] * n
    seqs = np.concatenate(seqs)
    parts = dirichlet_partition(np.array(labels), CLIENTS, alpha=0.5, seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=8, seed=seed + i)
               for i, p in enumerate(parts)]
    evals = [ds.to_batch(ds.sample(task=t, num_sequences=8, seq_len=32,
                                   seed=seed + 100 + t)) for t in range(CLIENTS)]
    return loaders, evals


def run_scenario(title: str, fed_cfg: FedConfig, loaders, evals, model,
                 recorder=None):
    print(f"\n=== {title} ===")
    t0 = time.time()
    if recorder is not None:
        # one shared recorder across scenarios; the run label namespaces
        # this scenario's rounds/spans (round 0 of scenario 2 never merges
        # into round 0 of scenario 1)
        recorder.set_run(title.split(":")[0].replace(" ", "-"))
    trainer = FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
        fed_cfg=fed_cfg,
        train_cfg=TrainConfig(learning_rate=5e-3, schedule="constant",
                              total_steps=fed_cfg.rounds * fed_cfg.local_steps),
        client_loaders=loaders, eval_batches=evals, seed=0,
        recorder=recorder)
    if trainer.engine is not None:
        print(f"  close path: fused engine (method={trainer.engine.method} "
              f"backend={trainer.engine.backend} "
              f"ring depth={trainer.engine.buffers.depth})")
    else:
        print("  close path: eager list-of-trees")
    history = trainer.run()
    for rec, out in zip(history, trainer.outcomes):
        w = ("uniform" if out.weights is None
             else "[" + ", ".join(f"{x:.2f}" for x in out.weights) + "]")
        print(f"  round {rec.round}: sampled={out.sampled} "
              f"delivered={out.client_ids} dropout={out.dropped_out} "
              f"deadline_drop={out.dropped_deadline} weights={w} "
              f"eval_loss={rec.eval_loss:.4f} "
              f"close_t={out.closed_at:.2f}s")
    print("  comm ledger (measured):")
    for line in trainer.ledger.summary_lines():
        print("    " + line)
    print(f"  [{time.time() - t0:.1f}s]")


def crash_twin(plan_text: str):
    """Rewrite a fault plan so every spec crashes the client instead.

    Returns ``None`` when a spec's kind is not twin-safe (e.g. ``scale`` or
    ``duplicate``, whose faulty bytes may still reach the close).  The fault
    *activation* coin only depends on (seed, round, client, spec index), so
    the twin crashes exactly the uplinks the original plan corrupts.
    """
    plan = FaultPlan.parse(plan_text)
    clauses = []
    for spec in plan.specs:
        if spec.kind not in _TWIN_SAFE:
            return None
        sel = []
        if spec.clients is not None:
            sel.append("clients=" + "+".join(str(c) for c in spec.clients))
        if spec.rounds is not None:
            sel.append("rounds=" + "+".join(str(r) for r in spec.rounds))
        clause = f"crash@{spec.prob:g}"
        if sel:
            clause += "(" + ",".join(sel) + ")"
        clauses.append(clause)
    return ";".join(clauses)


def run_chaos(faults: str, model, recorder, rounds: int):
    """Chaos scenario: run a fault plan through the defended uplink path,
    then its crash-twin (same seed, faulty clients simply absent), and
    stamp ``clean_exact`` per round — 1 iff the round's close is bitwise
    identical between the two runs (clean-lane exactness).  This is the
    witness ``scripts/obs_report.py --check --chaos`` asserts."""
    print("\n=== chaos: fault plan vs crash-twin (clean-lane exactness) ===")
    print(f"  plan: {faults}")
    t0 = time.time()

    def make(plan, rec_):
        # fresh loaders per run: both twins must see identical data-cursor
        # state, untouched by the earlier scenarios
        loaders, evals = build_data()
        if rec_ is not None:
            rec_.set_run("chaos")
        return FederatedTrainer(
            model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=FedConfig(num_clients=CLIENTS, rounds=rounds,
                              local_steps=3, method="fedex",
                              weighting="examples", engine="auto",
                              participation=1.0, faults=plan),
            train_cfg=TrainConfig(learning_rate=5e-3, schedule="constant",
                                  total_steps=rounds * 3),
            client_loaders=loaders, eval_batches=evals, seed=0,
            recorder=rec_)

    faulty = make(faults, recorder)
    hist = faulty.run()
    for rec_, out in zip(hist, faulty.outcomes):
        print(f"  round {rec_.round}: delivered={out.client_ids} "
              f"quarantined={out.quarantined} degraded={out.degraded} "
              f"eval_loss={rec_.eval_loss:.4f}")

    twin_plan = crash_twin(faults)
    if twin_plan is None:
        print("  plan has non-twin-safe kinds — skipping exactness stamps")
        return
    print(f"  twin: {twin_plan}")
    twin = make(twin_plan, None)
    twin_hist = twin.run()

    leaves_f = jax.tree.leaves((faulty.global_lora, faulty.params))
    leaves_t = jax.tree.leaves((twin.global_lora, twin.params))
    final_ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(leaves_f, leaves_t))
    all_ok = final_ok
    for r in range(rounds):
        # eval loss is a function of the round's closed global adapter, so
        # bitwise-equal losses witness bitwise-equal closes round by round
        ok = final_ok and hist[r].eval_loss == twin_hist[r].eval_loss
        all_ok = all_ok and ok
        if recorder is not None:
            recorder.round_set(r, clean_exact=int(ok))
        print(f"  round {r}: clean_exact={int(ok)} "
              f"(eval {hist[r].eval_loss:.6f} vs {twin_hist[r].eval_loss:.6f})")
    print(f"  final global adapter + params bitwise equal: {final_ok}")
    print(f"  [{time.time() - t0:.1f}s]")
    assert all_ok, "faulty-run close diverged from its crash-twin"


def run_chaos_hetero(faults: str, model, recorder, rounds: int):
    """Ragged-rank chaos scenario: the same fault plan vs its crash-twin,
    but with ``method=hetero`` and mixed client ranks — quarantining a
    RAGGED lane must exclude it exactly (per-client bases and rank-r_i
    adapters bitwise identical to the twin).  Stamps ``clean_exact`` per
    round under the ``chaos-hetero`` run label; ``scripts/obs_report.py
    --check --chaos`` asserts every stamp."""
    ranks = (4, 2, 1, 3, 2)  # r_max=4; the default plan faults ragged lanes
    print("\n=== chaos-hetero: ragged-rank fault plan vs crash-twin ===")
    print(f"  plan: {faults}  client_ranks: {ranks}")
    t0 = time.time()

    def make(plan, rec_):
        loaders, evals = build_data()
        if rec_ is not None:
            rec_.set_run("chaos-hetero")
        return FederatedTrainer(
            model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=FedConfig(num_clients=CLIENTS, rounds=rounds,
                              local_steps=3, method="hetero",
                              client_ranks=ranks, engine="auto",
                              participation=1.0, faults=plan),
            train_cfg=TrainConfig(learning_rate=5e-3, schedule="constant",
                                  total_steps=rounds * 3),
            client_loaders=loaders, eval_batches=evals, seed=0,
            recorder=rec_)

    faulty = make(faults, recorder)
    hist = faulty.run()
    q = sorted({e.client_id for e in faulty.ledger.entries
                if e.direction == "quarantined"})
    d = sorted({e.client_id for e in faulty.ledger.entries
                if e.direction == "dropped"})
    print(f"  quarantined clients: {q}  dropped clients: {d}")

    twin_plan = crash_twin(faults)
    if twin_plan is None:
        print("  plan has non-twin-safe kinds — skipping exactness stamps")
        return
    print(f"  twin: {twin_plan}")
    twin = make(twin_plan, None)
    twin_hist = twin.run()

    leaves_f = jax.tree.leaves((faulty.global_lora, faulty.client_params,
                                faulty._client_lora))
    leaves_t = jax.tree.leaves((twin.global_lora, twin.client_params,
                                twin._client_lora))
    final_ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(leaves_f, leaves_t))
    all_ok = final_ok
    for r in range(rounds):
        ok = final_ok and hist[r].eval_loss == twin_hist[r].eval_loss
        all_ok = all_ok and ok
        if recorder is not None:
            recorder.round_set(r, clean_exact=int(ok))
        print(f"  round {r}: clean_exact={int(ok)} "
              f"(eval {hist[r].eval_loss:.6f} vs {twin_hist[r].eval_loss:.6f})")
    print(f"  final global + per-client bases/adapters bitwise equal: "
          f"{final_ok}")
    print(f"  [{time.time() - t0:.1f}s]")
    assert all_ok, "ragged-lane close diverged from its crash-twin"


def large_c_smoke():
    """Large-C chunked close smoke (CI's memory-wall witness): a C=256 round
    streamed through the CHUNKED engine (close_chunk=32) must (a) keep the
    analytic peak live device bytes of its close BELOW a stacked C=32 close
    of the same geometry — peak is O(chunk), not O(C) — and (b) produce the
    same fold as the eager oracle W0 + scale·(Σwᵢaᵢbᵢ − āb̄)."""
    print("\n=== large-C chunked close (C=256, chunk=32) ===")
    from repro.core.engine import RoundCloseEngine

    t0 = time.time()
    rng = np.random.default_rng(0)
    layers, m, r, n = 2, 64, 4, 64
    c_big, c_small, chunk = 256, 32, 32
    params = {"q_proj": {"kernel": jnp.asarray(
        rng.normal(size=(layers, m, n)), jnp.float32)}}
    mk = lambda: {"q_proj": {
        "a": jnp.asarray(rng.normal(size=(layers, m, r)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(layers, r, n)), jnp.float32)}}
    tmpl = mk()
    loras = [mk() for _ in range(c_big)]
    scale = 2.0

    def close_with(c, eng_chunk):
        eng = RoundCloseEngine(params, tmpl, c_max=c, scale=scale,
                               method="fedex", backend="jnp", chunk=eng_chunk)
        eng.buffers.begin_round({i: i for i in range(c)}, round_id=0)
        for i in range(c):
            eng.buffers.write(i, loras[i], round_id=0, weight=1.0)
        chunked = eng.buffers.is_chunked(0)
        g, new_params, div = eng.close(params, list(range(c)), round_id=0)
        div.resolve()
        return eng.last_peak_bytes, chunked, g, new_params

    stacked_peak, _, _, _ = close_with(c_small, 0)
    chunked_peak, chunked, g, new_params = close_with(c_big, chunk)
    assert chunked, "C=256 with chunk=32 must take the chunked close"
    print(f"  peak close bytes: chunked C={c_big} = {chunked_peak:,} "
          f"vs stacked C={c_small} = {stacked_peak:,} "
          f"(ratio {chunked_peak / stacked_peak:.3f})")
    assert chunked_peak < stacked_peak, (
        f"chunked C={c_big} close peaked at {chunked_peak} B — not below "
        f"the stacked C={c_small} baseline {stacked_peak} B")

    # eager oracle over the full 256-client list: ā b̄ and the dense residual
    ga, res = fedex_aggregate(loras)
    oracle_w0 = params["q_proj"]["kernel"] + scale * res["q_proj"]
    err_w0 = float(jnp.max(jnp.abs(new_params["q_proj"]["kernel"] - oracle_w0)))
    err_g = max(float(jnp.max(jnp.abs(g["q_proj"][f] - ga["q_proj"][f])))
                for f in ("a", "b"))
    print(f"  max |W0 − eager oracle| = {err_w0:.2e}, "
          f"max |global factors − fedavg| = {err_g:.2e}")
    assert err_w0 < 1e-4 and err_g < 1e-5, (
        f"chunked C={c_big} close diverged from the eager oracle")
    print(f"  [{time.time() - t0:.1f}s]")


def exactness_check():
    """Direct coordinator round on synthetic adapters: the folded weighted
    residual reproduces W0 + scale·Σwᵢaᵢbᵢ over the delivered subset."""
    print("\n=== weighted exactness (synthetic adapters) ===")
    rng = np.random.default_rng(0)
    k, m, r, n = 6, 32, 4, 24
    registry = ClientRegistry(
        [ClientInfo(i, num_examples=int(rng.integers(50, 400))) for i in range(k)])
    coord = RoundCoordinator(
        registry,
        RoundPolicy(participation=0.6, weighting="examples"),
        StragglerModel(straggler_prob=0.2, seed=3),
        AdapterCodec("none"))
    loras = {i: {"q_proj": {
        "a": jnp.asarray(rng.normal(size=(m, r)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(r, n)), jnp.float32)}}
        for i in range(k)}
    outcome = coord.run_round(0, lambda c, g, rnd: loras[c.client_id],
                              global_lora=loras[0])
    g, res = weighted_close(outcome, "fedex")
    w0 = jnp.zeros((m, n))
    scale = 2.0
    ideal = product_mean([d.lora for d in outcome.delivered], outcome.weights)
    w_eff = (apply_residual({"q_proj": {"kernel": w0}}, res, scale)
             ["q_proj"]["kernel"]
             + scale * jnp.matmul(g["q_proj"]["a"], g["q_proj"]["b"]))
    w_ideal = w0 + scale * ideal["q_proj"]
    err = float(jnp.max(jnp.abs(w_eff - w_ideal)))
    print(f"  delivered={outcome.client_ids} weights="
          + "[" + ", ".join(f"{x:.3f}" for x in outcome.weights) + "]")
    print(f"  max |W_eff − W_ideal| = {err:.2e}  (fp32 exact ≤ 1e-5)")
    assert err < 1e-5


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON covering every "
                         "scenario (implies obs=trace)")
    ap.add_argument("--metrics-out", default="",
                    help="write the obs metrics JSONL stream here "
                         "(scripts/obs_report.py reads it)")
    ap.add_argument("--quick", action="store_true",
                    help="scenarios 1 + 3 only, 2 rounds each (the CI obs "
                         "smoke configuration)")
    ap.add_argument("--large-c", action="store_true",
                    help="run the C=256 chunked-close memory-wall smoke "
                         "(peak bytes below a stacked C=32 close + eager-"
                         "oracle agreement); CI runs this with --quick")
    ap.add_argument("--faults", nargs="?", const=DEFAULT_CHAOS_PLAN,
                    default="",
                    help="also run the chaos scenario under this fault plan "
                         "(bare flag → the default NaN/truncate/replay plan) "
                         "and stamp per-round clean_exact witnesses for "
                         "scripts/obs_report.py --check --chaos")
    args = ap.parse_args()

    rec = None
    if args.trace or args.metrics_out:
        from repro.obs import make_recorder
        rec = make_recorder("trace" if args.trace else "basic")

    t_start = time.time()
    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                              vocab_size=VOCAB)
    model = build_model(cfg)
    loaders, evals = build_data()

    # engine="auto" on every scenario: all closes run through the fused
    # single-dispatch engine (core/engine.py), not the eager list path
    rounds = 2 if args.quick else 3
    base = dict(num_clients=CLIENTS, rounds=rounds, local_steps=3,
                method="fedex", weighting="examples", engine="auto")
    run_scenario("scenario 1: sync, 60% participation, example weights",
                 FedConfig(**base, participation=0.6), loaders, evals, model,
                 recorder=rec)
    if not args.quick:
        run_scenario("scenario 2: deadline drops stragglers (quorum 2)",
                     FedConfig(**base, straggler_prob=0.4,
                               straggler_factor=8.0, dropout_prob=0.1,
                               round_deadline=2.5, min_quorum=2),
                     loaders, evals, model, recorder=rec)
    # depth-3 ring: FedBuff commits may pipeline two stack sets deep while
    # a third streams — the configuration the obs overlap check runs on
    run_scenario("scenario 3: async FedBuff buffer=2, int8 uplink, "
                 "depth-3 ring",
                 FedConfig(**base, participation=0.6, async_buffer=2,
                           straggler_prob=0.3, straggler_factor=6.0,
                           quantize_uplink="int8", ring_depth=3,
                           ring_max_lag=2),
                 loaders, evals, model, recorder=rec)
    if not args.quick:
        run_scenario("scenario 4: fedex_svd rank-4 truncated close (factored "
                     "Gram SVD in the engine — no dense residual)",
                     FedConfig(**{**base, "method": "fedex_svd"}, svd_rank=4,
                               participation=0.8), loaders, evals, model,
                     recorder=rec)
        run_scenario("scenario 5: keep_local assignment (per-client bases, "
                     "engine per-lane folds)",
                     FedConfig(**{**base, "weighting": "uniform"},
                               assignment="keep_local"), loaders, evals,
                     model, recorder=rec)
    if args.faults:
        run_chaos(args.faults, model, rec, rounds=2 if args.quick else 3)
        run_chaos_hetero(args.faults, model, rec,
                         rounds=2 if args.quick else 3)
    exactness_check()
    if args.large_c:
        large_c_smoke()
    if rec is not None:
        rec.set_run(None)
        print()
        for line in rec.summary_lines():
            print(line)
        if args.trace:
            rec.write_trace(args.trace)
            print(f"trace → {args.trace} (Perfetto / chrome://tracing)")
        if args.metrics_out:
            rec.write_metrics(args.metrics_out)
            print(f"metrics JSONL → {args.metrics_out} "
                  "(scripts/obs_report.py)")
    print(f"\ntotal wall time: {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
