"""Reproduce the paper's §6 deviation analysis (Figures 2–3) as CSV.

Trains 3 clients locally from a common adapter init, then prints the scaled
Frobenius norm of (FedAvg-of-factors − ideal-mean-of-products) per layer for
Q/V matrices at two local-training budgets, plus the round trajectory with a
decaying lr — the paper's three observations, numerically.

  PYTHONPATH=src python examples/divergence_analysis.py
"""

import numpy as np

from benchmarks.common import run_method
from benchmarks.fig2_divergence_layers import client_adapters_after
from repro.core import fedit_aggregate, mean_deviation
from repro.core.divergence import deviation_tree, flatten_deviations

print("== Figure 2 analog: per-layer deviation after ONE aggregation step ==")
print("layer,steps5_q,steps5_v,steps20_q,steps20_v")
per = {}
for steps in (5, 20):
    loras = client_adapters_after(steps)
    dev = flatten_deviations(deviation_tree(loras), "scaled")
    per[steps] = (np.asarray(dev["layers/attn/q_proj"]),
                  np.asarray(dev["layers/attn/v_proj"]))
for layer in range(len(per[5][0])):
    print(f"{layer},{per[5][0][layer]:.3e},{per[5][1][layer]:.3e},"
          f"{per[20][0][layer]:.3e},{per[20][1][layer]:.3e}")
print(f"\nobservation 2 (grows with local epochs): "
      f"{per[5][0].mean():.3e} -> {per[20][0].mean():.3e}  "
      f"holds={per[20][0].mean() > per[5][0].mean()}")

print("\n== Figure 3 analog: deviation across rounds (cosine lr) ==")
res = run_method("fedex", rounds=8, local_steps=20, schedule="cosine")
print("round,pre_agg_divergence")
for i, d in enumerate(res["divergence_history"]):
    print(f"{i},{d:.3e}")

print("\n== FedEx post-aggregation deviation (should be ~0) ==")
loras = client_adapters_after(5)
g = fedit_aggregate(loras)
print(f"post-agg deviation: {mean_deviation([g, g, g]):.3e}")
