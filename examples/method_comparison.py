"""End-to-end driver: the paper's method comparison (Tables 1–4 analog).

Trains a ~tiny decoder for a few hundred total steps per method on the
3-client non-IID synthetic setting and reports final eval loss/acc + the
pre-aggregation divergence — Centralized / FedEx / FedIT / FFA, as in the
paper's main tables.

  PYTHONPATH=src python examples/method_comparison.py [--rounds 6] [--steps 25]
"""

import argparse

from benchmarks.common import run_method


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--steps", type=int, default=25)
    args = ap.parse_args()

    print(f"{'method':<14} {'eval_loss':>9} {'eval_acc':>9} {'divergence':>11}")
    for method in ("centralized", "fedex", "fedit", "ffa"):
        r = run_method(method, rounds=args.rounds, local_steps=args.steps)
        print(f"{method:<14} {r['final_eval_loss']:>9.4f} "
              f"{r['final_eval_acc']:>9.4f} {r['divergence']:>11.3e}")
    print("\nFedEx's post-aggregation divergence is identically 0 (exact);")
    print("the divergence column reports pre-aggregation client drift.")


if __name__ == "__main__":
    main()
