"""Multi-pod dry-run example: lower + compile one (arch × shape) on the
production meshes and print the roofline terms.

This is a thin veneer over repro.launch.dryrun (which must own the process —
jax locks the device count at first init, so run this as a FRESH process):

  PYTHONPATH=src python examples/multi_pod_dryrun.py --arch gemma3-12b --shape long_500k
"""

import argparse
import json
import subprocess
import sys
import os
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    args = ap.parse_args()

    out = tempfile.mktemp(suffix=".json")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--mesh", args.mesh, "--out", out]
    subprocess.run(cmd, env=env, check=True)
    print("\n=== roofline terms ===")
    for line in open(out):
        rec = json.loads(line)
        if rec["status"] != "ok":
            print(f"{rec['arch']} × {rec['shape']} × {rec['mesh']}: {rec['status']}")
            continue
        rf = rec["roofline"]
        print(f"{rec['arch']} × {rec['shape']} × {rec['mesh']}:")
        print(f"  compute    {rf['compute_s']:.3e} s")
        print(f"  memory     {rf['memory_s']:.3e} s")
        print(f"  collective {rf['collective_s']:.3e} s   ← dominant: {rf['dominant']}")
        print(f"  useful-FLOPs ratio: {rf.get('useful_flops_ratio') or 0:.3f}")
    os.remove(out)


if __name__ == "__main__":
    main()
