"""Quickstart: FedEx-LoRA in ~60 lines.

Three clients fine-tune LoRA adapters on non-IID synthetic data; the server
aggregates with the paper's exact-aggregation rule (residual folded into W0)
and we verify Eq. 7–9 numerically at the end.

  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import (FederatedTrainer, fedex_aggregate, merge_lora,
                        product_mean, apply_residual)
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.models import build_model
from repro.util.tree import flatten_with_paths

# ---- model: a tiny llama-style decoder (the math is size-independent) -------
cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32", vocab_size=16)
model = build_model(cfg)

# ---- data: 3 clients, Dirichlet non-IID task mixture -------------------------
ds = SyntheticLM(vocab=16, num_tasks=3, seed=0, concentration=0.05)
seqs, labels = [], []
for t in range(3):
    s = ds.sample(task=t, num_sequences=150, seq_len=32, seed=t)
    seqs.append(s)
    labels += [t] * 150
seqs, labels = np.concatenate(seqs), np.array(labels)
parts = dirichlet_partition(labels, 3, alpha=0.3, seed=0)
loaders = [ClientLoader(seqs[p], batch_size=16, seed=i) for i, p in enumerate(parts)]
evals = [ds.to_batch(ds.sample(task=t, num_sequences=16, seq_len=32, seed=100 + t))
         for t in range(3)]

# ---- federated fine-tuning with exact aggregation ----------------------------
trainer = FederatedTrainer(
    model=model,
    lora_cfg=LoRAConfig(rank=8, alpha=16, include_mlp=True),
    fed_cfg=FedConfig(num_clients=3, rounds=3, local_steps=20, method="fedex"),
    train_cfg=TrainConfig(learning_rate=3e-2, schedule="constant"),
    client_loaders=loaders, eval_batches=evals, seed=0)
history = trainer.run()
print(f"\neval loss: {history[0].eval_loss:.4f} → {history[-1].eval_loss:.4f} "
      f"(uniform = {np.log(16):.4f})")

# ---- verify the paper's exactness claim (Eq. 7–9) on live adapters -----------
params0 = model.init(jax.random.key(0))
client_loras = [trainer.global_lora] * 3  # identical post-aggregation
# perturb to simulate fresh local training
client_loras = [jax.tree.map(
    lambda x, i=i: x + 0.01 * jax.random.normal(jax.random.key(i), x.shape), l)
    for i, l in enumerate(client_loras)]
g, res = fedex_aggregate(client_loras)
scale = trainer.scale
w_fedex = merge_lora(apply_residual(params0, res, scale), g, scale)
w_ideal = apply_residual(params0, product_mean(client_loras), scale)
err = max(float(jnp.abs(a - b).max()) for a, b in zip(
    flatten_with_paths(w_fedex).values(), flatten_with_paths(w_ideal).values()))
print(f"FedEx aggregation vs ideal FedAvg of products: max |Δ| = {err:.2e}")
assert err < 1e-5, "exact aggregation violated!"
print("Eq. 7–9 verified: aggregation is EXACT.")
