"""Serve a federated-fine-tuned model with batched requests.

Runs a short FedEx-LoRA training, merges the aggregated adapters into the
base (core.merge_lora — mathematically identical to serving with adapters),
then answers a batch of prompts with prefill + greedy decode through the KV
cache machinery (the same code paths the decode_32k / long_500k dry-run
shapes exercise at production scale).

  PYTHONPATH=src python examples/serve_federated.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer, merge_lora
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model

cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32", vocab_size=16)
model = build_model(cfg)

ds = SyntheticLM(vocab=16, num_tasks=3, seed=0, concentration=0.05)
seqs = np.concatenate([ds.sample(task=t, num_sequences=100, seq_len=32, seed=t)
                       for t in range(3)])
labels = np.repeat(np.arange(3), 100)
parts = dirichlet_partition(labels, 3, alpha=0.3, seed=0)
loaders = [ClientLoader(seqs[p], batch_size=16, seed=i) for i, p in enumerate(parts)]

trainer = FederatedTrainer(
    model=model, lora_cfg=LoRAConfig(rank=8, alpha=16, include_mlp=True),
    # engine="auto": rounds close through the fused single-dispatch engine
    # (core/engine.py) — streamed (C_max, …) stacks, no eager list path
    fed_cfg=FedConfig(num_clients=3, rounds=2, local_steps=15, method="fedex",
                      engine="auto"),
    train_cfg=TrainConfig(learning_rate=3e-2, schedule="constant"),
    client_loaders=loaders, seed=0)
assert trainer.engine is not None, "fedex/average must take the fused path"
print(f"close path: fused engine (method={trainer.engine.method} "
      f"backend={trainer.engine.backend})")
trainer.run()

# ---- merge + serve -----------------------------------------------------------
served_params = merge_lora(trainer.params, trainer.global_lora, trainer.scale)
lcfg = LoRAConfig(rank=8)
prefill = jax.jit(make_prefill_step(model, lcfg))
decode = jax.jit(make_decode_step(model, lcfg))

batch_size, prompt_len, gen_steps = 4, 16, 12
prompts = ds.sample(task=0, num_sequences=batch_size, seq_len=prompt_len, seed=77)
batch = {"tokens": jnp.asarray(prompts[:, :prompt_len], jnp.int32)}
cache = model.init_cache(batch_size, prompt_len + gen_steps + 1)

logits, cache = prefill(served_params, None, batch, cache)
tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
out = [tok]
for i in range(gen_steps):
    tok, _, cache = decode(served_params, None, tok, cache,
                           jnp.asarray(prompt_len + i, jnp.int32))
    out.append(tok)
gen = np.asarray(jnp.concatenate(out, axis=1))
for b in range(batch_size):
    print(f"prompt {prompts[b, :prompt_len].tolist()} → generated {gen[b].tolist()}")

# sanity: generations follow the task-0 Markov chain more than uniform chance
trans = ds.transitions[0]
probs = [trans[a, b] for row in np.concatenate([prompts[:, prompt_len - 1:prompt_len], gen], 1)
         for a, b in zip(row[:-1], row[1:])]
print(f"\nmean transition prob of generated tokens: {np.mean(probs):.3f} "
      f"(uniform would be {1 / 16:.3f})")
