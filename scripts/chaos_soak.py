#!/usr/bin/env python
"""Chaos soak entry point — fault rate × method × participation sweep.

Thin wrapper so the robustness sweep lives next to the other operational
scripts; the implementation (and the ``BENCH_robustness.json`` schema) is
``benchmarks/chaos_soak.py``.

  PYTHONPATH=src python scripts/chaos_soak.py [--quick] [--out F]
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.chaos_soak import main  # noqa: E402

if __name__ == "__main__":
    main()
