#!/usr/bin/env python
"""Docs vs package consistency check (CI `docs` job).

Guards README.md / ROADMAP.md / docs/*.md against rot:

* fenced code blocks — every ``python -m <module>`` invocation, every
  ``import repro…`` / ``from repro… import names`` statement, and every
  ``python path/to/file.py`` must resolve against the live package (modules
  via importlib, imported names via getattr);
* prose — every backticked ``foo/bar.py`` path token must exist, either
  repo-relative or under ``src/repro/`` (module docs conventionally drop the
  ``src/repro/`` prefix);
* markdown links — relative link targets must exist (anchors stripped);
  http(s) links are left to humans (no network in the check).

Exit code 1 with a per-finding report when anything dangles.

  PYTHONPATH=src python scripts/check_docs.py
"""

from __future__ import annotations

import importlib
import importlib.util
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))  # benchmarks.* / examples are repo-rooted

DOC_FILES = [REPO / "README.md", REPO / "ROADMAP.md"]
DOC_FILES += sorted((REPO / "docs").glob("*.md"))

FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)
RUN_MODULE_RE = re.compile(r"python\s+-m\s+([\w.]+)")
RUN_FILE_RE = re.compile(r"python\s+([\w./-]+\.py)")
IMPORT_RE = re.compile(r"^\s*import\s+(repro[\w.]*)", re.MULTILINE)
# names: either a parenthesized (possibly multi-line) group, or the rest of
# the line — [\w, \t] must NOT match newlines or the following source line
# would be parsed as an imported name
FROM_IMPORT_RE = re.compile(
    r"^\s*from\s+(repro[\w.]*)\s+import\s+(?:\(([^)]*)\)|([\w, \t]+))",
    re.MULTILINE)
PY_PATH_RE = re.compile(r"`([\w.\-]+(?:/[\w.\-]+)+\.py)")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def module_exists(name: str) -> bool:
    try:
        return importlib.util.find_spec(name) is not None
    except (ImportError, ModuleNotFoundError, ValueError):
        return False


def path_exists(token: str) -> bool:
    """Repo-relative, or under src/repro/ (docs drop the prefix)."""
    candidates = [REPO / token, REPO / "src" / "repro" / token,
                  REPO / "src" / token]
    return any(c.is_file() for c in candidates)


def check_fences(doc: Path, text: str, errors: list) -> None:
    for lang, body in FENCE_RE.findall(text):
        if lang == "mermaid":
            continue
        for mod in RUN_MODULE_RE.findall(body):
            if mod == "pytest":
                continue
            if not module_exists(mod):
                errors.append(f"{doc.name}: fenced `python -m {mod}` — "
                              "module not found")
        for f in RUN_FILE_RE.findall(body):
            if not path_exists(f):
                errors.append(f"{doc.name}: fenced `python {f}` — "
                              "file not found")
        for mod in IMPORT_RE.findall(body):
            if not module_exists(mod):
                errors.append(f"{doc.name}: fenced `import {mod}` — "
                              "module not found")
        for mod, paren_names, line_names in FROM_IMPORT_RE.findall(body):
            names = paren_names or line_names
            if not module_exists(mod):
                errors.append(f"{doc.name}: fenced `from {mod} import …` — "
                              "module not found")
                continue
            m = importlib.import_module(mod)
            for name in filter(None, (n.strip() for n in names.split(","))):
                name = name.split(" as ")[0].strip()  # 'x as y' checks x
                if not hasattr(m, name):
                    errors.append(f"{doc.name}: fenced `from {mod} import "
                                  f"{name}` — name not found")


def check_paths(doc: Path, text: str, errors: list) -> None:
    for token in PY_PATH_RE.findall(text):
        if not path_exists(token):
            errors.append(f"{doc.name}: dead module reference `{token}`")


def check_links(doc: Path, text: str, errors: list) -> None:
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not (doc.parent / rel).exists() and not (REPO / rel).exists():
            errors.append(f"{doc.name}: dead link `{target}`")


def main() -> int:
    errors: list = []
    checked = 0
    for doc in DOC_FILES:
        if not doc.is_file():
            errors.append(f"missing doc file: {doc.relative_to(REPO)}")
            continue
        text = doc.read_text()
        check_fences(doc, text, errors)
        check_paths(doc, text, errors)
        check_links(doc, text, errors)
        checked += 1
    if errors:
        print(f"docs check FAILED ({len(errors)} finding(s) "
              f"across {checked} file(s)):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"docs check OK: {checked} file(s), no dead module refs or links")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
