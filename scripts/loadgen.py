#!/usr/bin/env python
"""Load-generator benchmark for the HTTP federation service.

Hammers a ``train.py --mode serve`` server with threaded simulated clients
POSTing wire-framed adapter deltas, then verifies the rounds closed EXACTLY:
a clean in-process twin (same arch/rank/seed → same ``init_global_state``,
same ``RoundCloseEngine``) replays the identical deltas and the merged
global adapter pulled over HTTP must match it bitwise — and the server's
W0 digest must match the twin's folded base weights, which is the residual
fold's witness (avg-of-adapters alone cannot distinguish exact FedEx from
naive FedAvg; the folded W0 can).

Emits ``BENCH_serving.json``: per-round close dispatch/block latency under
concurrent ingest, POST latency percentiles, ingest-bytes/s, HTTP framing
overhead vs payload bytes (ledger reconciliation), rejection counts, parity
verdicts.

Usage (spawns its own server subprocess):

  PYTHONPATH=src python scripts/loadgen.py --quick --spawn
  PYTHONPATH=src python scripts/loadgen.py --spawn --clients 96 --threads 32

or against an already-running server started with MATCHING flags
(--arch/--vocab/--rank/--alpha/--seed/--clients/--rounds/--quantize):

  PYTHONPATH=src python -m repro.launch.train --mode serve --arch paper-tiny \\
      --vocab 64 --clients 8 --rounds 2 &
  PYTHONPATH=src python scripts/loadgen.py --quick --server http://127.0.0.1:8077
"""

from __future__ import annotations

import argparse
import json
import os
import queue
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

# repo-root invocation: scripts/ is not a package, src/ may not be on path;
# benchmarks.common (env_metadata) lives at the repo root
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir)
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.fedsrv.client import FedClient  # noqa: E402
from repro.fedsrv.transport import (AdapterCodec, StaleUplinkError,  # noqa: E402
                                    TransportError)
from repro.util.tree import flatten_with_paths  # noqa: E402


# ---------------------------------------------------------------------------
def synthetic_delta(template_shapes: Dict[str, tuple], seed: int, rnd: int,
                    cid: int) -> Dict[str, np.ndarray]:
    """Deterministic per-(seed, round, client) adapter delta — both the HTTP
    clients and the clean twin derive the same trees from the key alone, so
    parity needs no cross-process traffic beyond (seed, shapes)."""
    rng = np.random.default_rng([seed, rnd, cid, 17])
    return {p: (0.05 * rng.standard_normal(s)).astype(np.float32)
            for p, s in template_shapes.items()}


def ragged_shapes(shapes: Dict[str, tuple], r: int) -> Dict[str, tuple]:
    """Template shapes at one client's true LoRA rank r: factor leaves get
    their rank axis narrowed (a is (…, m, r), b is (…, r, n)); everything
    else keeps the registered shape."""
    out = {}
    for p, s in shapes.items():
        leaf = p.rsplit("/", 1)[-1]
        if leaf == "a":
            s = s[:-1] + (r,)
        elif leaf == "b":
            s = s[:-2] + (r, s[-1])
        out[p] = s
    return out


def hetero_ranks(clients: int, r_max: int) -> List[int]:
    """The --hetero rank pattern: cycle r_max, r_max/2, r_max/4 across the
    fleet (clipped to ≥1) — deterministic, so server flags and the clean
    twin derive the same fleet from (clients, rank) alone."""
    cycle = [r_max, max(1, r_max // 2), max(1, r_max // 4)]
    return [cycle[c % len(cycle)] for c in range(clients)]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _spawn_server(args, port: int, trace: str, metrics: str):
    cmd = [sys.executable, "-m", "repro.launch.train", "--mode", "serve",
           "--arch", args.arch, "--vocab", str(args.vocab),
           "--rank", str(args.rank), "--alpha", str(args.alpha),
           "--clients", str(args.clients), "--rounds", str(args.rounds),
           "--seed", str(args.seed), "--method", args.method,
           "--svd-rank", str(args.svd_rank),
           "--quantize-uplink", args.quantize,
           "--close-chunk", str(args.close_chunk),
           "--max-concurrent", str(args.max_concurrent),
           "--quota", str(args.quota),
           "--port", str(port), "--host", "127.0.0.1",
           "--obs", "trace", "--trace", trace, "--metrics-out", metrics]
    if args.hetero:
        cmd += ["--client-ranks", ",".join(
            str(r) for r in hetero_ranks(args.clients, args.rank))]
    if args.token:
        cmd += ["--serve-token", args.token]
    if args.deadline:
        cmd += ["--deadline", str(args.deadline),
                "--min-quorum", str(args.min_quorum)]
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", "")] if p)
    print(f"[loadgen] spawning server on :{port} …", flush=True)
    return subprocess.Popen(cmd, env=env)


def _wait_healthy(client: FedClient, proc, timeout_s: float = 180.0) -> None:
    t0 = time.monotonic()
    while True:
        if proc is not None and proc.poll() is not None:
            raise RuntimeError(
                f"server exited early (rc={proc.returncode})")
        try:
            h = client.health()
            print(f"[loadgen] server healthy: {h}", flush=True)
            return
        except Exception:
            if time.monotonic() - t0 > timeout_s:
                raise RuntimeError(
                    f"server not healthy after {timeout_s:.0f}s")
            time.sleep(0.25)


# ---------------------------------------------------------------------------
def drive_round(url: str, args, shapes: Dict[str, tuple], rnd: int
                ) -> Dict[str, Any]:
    """Fan one round's POSTs across a worker pool; returns latency + outcome
    counts. ``--duplicates`` re-POSTs a fraction of accepted deltas so the
    409 replay/duplicate path is exercised under the same pressure."""
    jobs: "queue.Queue[int]" = queue.Queue()
    for cid in range(args.clients):
        jobs.put(cid)
    lat_ms: List[float] = []
    outcomes = {"accepted": 0, "stale": 0, "rejected": 0, "failed": 0,
                "dup_409": 0}
    lock = threading.Lock()
    t_first = [None]
    t_closed = [None]

    def worker(wid: int) -> None:
        client = FedClient(url, 0, token=args.token, quantize=args.quantize,
                           retries=6, backoff=0.05)
        while True:
            try:
                cid = jobs.get_nowait()
            except queue.Empty:
                return
            client.client_id = cid  # one pooled connection, many identities
            r_c = None
            cid_shapes = shapes
            if args.hetero:
                r_c = hetero_ranks(args.clients, args.rank)[cid]
                cid_shapes = ragged_shapes(shapes, r_c)
            tree = synthetic_delta(cid_shapes, args.seed, rnd, cid)
            t0 = time.perf_counter()
            try:
                resp = client.submit_delta(tree, round_id=rnd, rank=r_c)
                dt = (time.perf_counter() - t0) * 1e3
                with lock:
                    lat_ms.append(dt)
                    outcomes["accepted"] += 1
                    if t_first[0] is None:
                        t_first[0] = t0
                    if resp.get("closed"):
                        t_closed[0] = time.perf_counter()
                if args.duplicates > 0 \
                        and cid % max(1, int(1 / args.duplicates)) == 0:
                    try:
                        client.submit_delta(tree, round_id=rnd, rank=r_c)
                    except StaleUplinkError:
                        with lock:
                            outcomes["dup_409"] += 1
            except StaleUplinkError:
                with lock:
                    outcomes["stale"] += 1
            except TransportError:
                with lock:
                    outcomes["rejected"] += 1
            except Exception as e:  # noqa: BLE001 — survey, don't crash
                print(f"[loadgen] client {cid} failed: {e}", flush=True)
                with lock:
                    outcomes["failed"] += 1

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(min(args.threads, args.clients))]
    t_round0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t_round0
    lat = np.asarray(sorted(lat_ms)) if lat_ms else np.asarray([0.0])
    return {
        "round": rnd,
        "wall_s": round(wall_s, 4),
        "posts": outcomes,
        "post_latency_ms": {
            "p50": round(float(np.percentile(lat, 50)), 3),
            "p95": round(float(np.percentile(lat, 95)), 3),
            "max": round(float(lat.max()), 3),
        },
        # the accepted POST that tripped the close carries the dispatch
        # inline — first-post→close-ack is the round's ingest+close wall time
        "ingest_to_close_ms": None if t_closed[0] is None or t_first[0] is None
        else round((t_closed[0] - t_first[0]) * 1e3, 3),
    }


# ---------------------------------------------------------------------------
def run_twin(args, model, lora_cfg, shapes: Dict[str, tuple]):
    """Clean in-process twin: same init, same engine, same deltas fed through
    an encode→decode codec round-trip (so a quantized uplink aggregates
    as-transmitted on both sides). Returns (final_global, final_params,
    engine) after replaying every round."""
    import jax

    from repro.core.engine import RoundCloseEngine
    from repro.fedsrv.server import init_global_state

    params, global_lora = init_global_state(model, lora_cfg, seed=args.seed)
    if args.hetero:
        ranks = hetero_ranks(args.clients, args.rank)
        engine = RoundCloseEngine(
            params, global_lora, c_max=args.clients, scale=lora_cfg.scale,
            method="hetero", backend="auto", depth=2,
            chunk=args.close_chunk, client_ranks=ranks)
        codec = AdapterCodec(args.quantize)
        codec.register_spec(global_lora)
        client_params = [params] * args.clients
        for rnd in range(args.rounds):
            engine.buffers.begin_round({c: c for c in range(args.clients)},
                                       round_id=rnd)
            for cid in range(args.clients):
                # same ragged encode→pad-at-decode round-trip the server runs
                payload = codec.encode(
                    synthetic_delta(ragged_shapes(shapes, ranks[cid]),
                                    args.seed, rnd, cid),
                    round_id=rnd, client_id=cid, rank=ranks[cid])
                codec.decode_into(payload, engine.buffers)
            new_cp, _loras, global_lora, div = engine.close_hetero(
                client_params, list(range(args.clients)), round_id=rnd)
            client_params = [new_cp[c] for c in range(args.clients)]
            div.resolve()
        return global_lora, client_params, engine
    eng_method = "fedex_svd" if (args.method == "fedex_svd"
                                 and args.svd_rank) else "fedex"
    engine = RoundCloseEngine(
        params, global_lora, c_max=args.clients, scale=lora_cfg.scale,
        method=eng_method, svd_rank=args.svd_rank, backend="auto",
        depth=2, chunk=args.close_chunk)
    codec = AdapterCodec(args.quantize)
    codec.register_spec(global_lora)
    for rnd in range(args.rounds):
        engine.buffers.begin_round({c: c for c in range(args.clients)},
                                   round_id=rnd)
        for cid in range(args.clients):
            payload = codec.encode(
                synthetic_delta(shapes, args.seed, rnd, cid),
                round_id=rnd, client_id=cid)
            codec.decode_into(payload, engine.buffers)
        global_lora, params, div = engine.close(
            params, list(range(args.clients)), round_id=rnd)
        div.resolve()
    return global_lora, params, engine


def _bitwise(a, b) -> bool:
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    return set(fa) == set(fb) and all(
        np.array_equal(np.asarray(fa[k]), np.asarray(fb[k])) for k in fa)


def wrong_rank_probe(url: str, args, shapes: Dict[str, tuple]) -> bool:
    """POST a delta declaring an out-of-range LoRA rank (r_max + 1): the
    defended decode must bounce it 422 ``reason="rank"`` BEFORE any scatter,
    leaving the lane open for the client's real delta later in the round."""
    client = FedClient(url, 0, token=args.token, quantize=args.quantize)
    tree = synthetic_delta(shapes, args.seed, 0, 0)
    try:
        client.submit_delta(tree, round_id=0, rank=args.rank + 1)
    except StaleUplinkError:
        print("[loadgen] wrong-rank probe: UNEXPECTED 409/410", flush=True)
        return False
    except TransportError as e:
        ok = e.reason == "rank"
        print(f"[loadgen] wrong-rank probe: rejected reason={e.reason!r} "
              f"({'ok' if ok else 'UNEXPECTED'})", flush=True)
        return ok
    print("[loadgen] wrong-rank probe: server ACCEPTED an out-of-range rank",
          flush=True)
    return False


# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--server", default="",
                    help="URL of a running server (omit with --spawn)")
    ap.add_argument("--spawn", action="store_true",
                    help="boot a train.py --mode serve subprocess, drive it, "
                         "reap it")
    ap.add_argument("--quick", action="store_true",
                    help="CI shape: 8 clients × 2 rounds, 8 threads")
    ap.add_argument("--clients", type=int, default=96)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--threads", type=int, default=32)
    ap.add_argument("--arch", default="paper-tiny")
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="fedex",
                    choices=("fedex", "fedex_svd"))
    ap.add_argument("--hetero", action="store_true",
                    help="ragged-rank fleet: --method hetero with the cyclic "
                         "client-rank pattern of hetero_ranks(); uplinks "
                         "travel at each client's true rank, the close is "
                         "verified bitwise vs an in-process hetero twin "
                         "(chained per-client W0 digest), and a wrong-rank "
                         "POST must bounce 422 reason='rank'")
    ap.add_argument("--svd-rank", type=int, default=0)
    ap.add_argument("--quantize", default="none",
                    choices=("none", "fp16", "int8"))
    ap.add_argument("--close-chunk", type=int, default=0)
    ap.add_argument("--max-concurrent", type=int, default=16)
    ap.add_argument("--quota", type=int, default=4)
    ap.add_argument("--token", default="")
    ap.add_argument("--deadline", type=float, default=0.0)
    ap.add_argument("--min-quorum", type=int, default=0)
    ap.add_argument("--duplicates", type=float, default=0.0,
                    help="fraction of clients that re-POST their delta "
                         "(exercises the 409 duplicate path under load)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the clean-twin parity replay")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--trace", default="serve_trace.json",
                    help="(--spawn) server trace output path")
    ap.add_argument("--metrics-out", default="serve_metrics.jsonl",
                    help="(--spawn) server metrics JSONL output path")
    args = ap.parse_args()

    if args.quick:
        args.clients, args.rounds, args.threads = 8, 2, 8
        if args.duplicates == 0.0:
            args.duplicates = 0.25
    if args.hetero:
        args.method = "hetero"   # the spawn cmd + twin both key off this
    if not args.spawn and not args.server:
        ap.error("need --server URL or --spawn")

    # model/template build (shared with the twin; cheap for paper-tiny)
    from dataclasses import replace as dc_replace

    from repro.configs import LoRAConfig, get_config
    from repro.fedsrv.server import (hetero_w0_digest, init_global_state,
                                     w0_digest)
    from repro.models import build_model

    cfg = dc_replace(get_config(args.arch), vocab_size=args.vocab,
                     dtype="float32")
    model = build_model(cfg)
    lora_cfg = LoRAConfig(rank=args.rank, alpha=args.alpha)
    _, template = init_global_state(model, lora_cfg, seed=args.seed)
    shapes = {p: tuple(np.shape(x))
              for p, x in flatten_with_paths(template).items()}

    proc = None
    if args.spawn:
        port = _free_port()
        url = f"http://127.0.0.1:{port}"
        proc = _spawn_server(args, port, args.trace, args.metrics_out)
    else:
        url = args.server.rstrip("/")

    probe = FedClient(url, client_id=-1, token=args.token)
    try:
        _wait_healthy(probe, proc)
        probe_422_ok = None
        if args.hetero:
            # before any real round-0 delta: the quarantine must not scatter,
            # so client 0's genuine uplink still lands afterwards
            probe_422_ok = wrong_rank_probe(url, args, shapes)
        t_bench0 = time.perf_counter()
        rounds_out = []
        total_payload_bytes = 0
        for rnd in range(args.rounds):
            # wait for the server to be ON this round (previous close done)
            while True:
                h = probe.health()
                if h["round"] >= rnd or h["status"] == "done":
                    break
                time.sleep(0.02)
            r = drive_round(url, args, shapes, rnd)
            rounds_out.append(r)
            print(f"[loadgen] round {rnd}: {r['posts']} "
                  f"p95={r['post_latency_ms']['p95']}ms", flush=True)
        bench_wall_s = time.perf_counter() - t_bench0

        # pull the final merged adapter + server-side metrics
        pull = probe.pull_latest()
        server_metrics = probe.metrics()
        pull_ok = pull.version == args.rounds
        print(f"[loadgen] pull_latest ok: version={pull.version} "
              f"digest={pull.w0_digest[:12]}…", flush=True)

        parity: Dict[str, Any] = {"checked": not args.no_verify}
        if not args.no_verify:
            twin_global, twin_params, twin_engine = run_twin(
                args, model, lora_cfg, shapes)
            parity["adapter_bitwise"] = _bitwise(pull.lora, twin_global)
            # hetero folds a DIFFERENT residual into every client's base, so
            # the witness is the chained per-client digest
            twin_digest = hetero_w0_digest(twin_engine.specs, twin_params) \
                if args.hetero else w0_digest(twin_engine.specs, twin_params)
            parity["w0_digest_match"] = twin_digest == pull.w0_digest
            print(f"[loadgen] clean-twin parity: {parity}", flush=True)
    finally:
        if proc is not None:
            # the server exits on its own after serving all rounds; give it
            # a moment to flush trace/metrics, then make sure it is gone
            try:
                proc.wait(timeout=60)
            except subprocess.TimeoutExpired:
                proc.terminate()
                proc.wait(timeout=10)

    ledger = server_metrics.get("ledger", {})
    counters = server_metrics.get("counters", {})
    gauges = server_metrics.get("gauges", {})
    payload_dirs = ("uplink", "quarantined", "dropped")
    payload_bytes = sum(ledger.get(f"{d}_bytes", 0) for d in payload_dirs)
    total_payload_bytes = payload_bytes
    # per-round engine latencies from the server's own round records
    close_lat = [
        {"round": r.get("round"),
         "close_dispatch_us": r.get("close_dispatch_us"),
         "close_block_us": r.get("close_block_us"),
         "divergence": r.get("divergence")}
        for r in server_metrics.get("rounds", [])
        if r.get("close_dispatch_us") is not None]

    from benchmarks.common import env_metadata

    bench = {
        "bench": "serving",
        "env": env_metadata(clients=args.clients, rounds=args.rounds,
                            threads=args.threads, quantize=args.quantize,
                            close_chunk=args.close_chunk,
                            max_concurrent=args.max_concurrent),
        "wall_s": round(bench_wall_s, 3),
        "rounds": rounds_out,
        "close_latency": close_lat,
        "ingest_bytes_per_s": gauges.get("uplink.ingest_bytes_per_s"),
        "http": {
            "requests": counters.get("uplink.http_requests"),
            "bytes_total": counters.get("uplink.http_bytes"),
            "payload_bytes": payload_bytes,
            "overhead_bytes": counters.get("uplink.http_overhead_bytes"),
            "rejected": {k.split("[")[1].rstrip("]"): v
                         for k, v in counters.items()
                         if k.startswith("uplink.http_rejected[")},
        },
        "ledger": ledger,
        "pull_latest_ok": pull_ok,
        "parity": parity,
    }
    if args.hetero:
        bench["hetero"] = {
            "client_ranks": hetero_ranks(args.clients, args.rank),
            "wrong_rank_422": probe_422_ok,
            "quarantined_rank": counters.get("uplink.quarantined[rank]"),
        }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    print(f"[loadgen] wrote {args.out}")

    ok = pull_ok and (args.no_verify or (parity.get("adapter_bitwise")
                                         and parity.get("w0_digest_match")))
    if args.hetero:
        ok = ok and bool(probe_422_ok)
    if not ok:
        print("[loadgen] FAILED: parity or pull_latest check did not hold",
              file=sys.stderr)
        sys.exit(1)
    print(f"[loadgen] OK: {args.rounds} round(s) closed exactly over HTTP "
          f"({total_payload_bytes} payload B, "
          f"{bench['http']['overhead_bytes']} overhead B)")


if __name__ == "__main__":
    main()
