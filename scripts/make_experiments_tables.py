"""Generate the §Dry-run / §Roofline markdown tables from the dry-run JSONs.

  PYTHONPATH=src python scripts/make_experiments_tables.py
"""

import json
import sys


def load(path):
    try:
        return [json.loads(l) for l in open(path) if l.strip()]
    except FileNotFoundError:
        return []


def fmt_bytes(b):
    if b is None:
        return "?"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs, title):
    print(f"\n### {title}\n")
    print("| arch | shape | status | compile_s | peak_bytes/dev | dominant |")
    print("|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "ok":
            peak = (r.get("memory", {}) or {}).get("peak_bytes")
            dom = r["roofline"]["dominant"]
            print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
                  f"{fmt_bytes(peak)} | {dom} |")
        elif r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | skipped | — | — | — |")
        else:
            print(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — |")


def roofline_table(recs, base=None):
    base_map = {}
    if base:
        base_map = {(r["arch"], r["shape"]): r for r in base if r["status"] == "ok"}
    print("\n| arch | shape | compute_s | memory_s | collective_s | dominant | useful | Δdominant vs baseline |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        dom_val = rf[f"{dom}_s"]
        delta = ""
        b = base_map.get((r["arch"], r["shape"]))
        if b:
            bf = b["roofline"]
            bdom_val = max(bf["compute_s"], bf["memory_s"], bf["collective_s"])
            if dom_val > 0:
                delta = f"{bdom_val / dom_val:.1f}×"
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
              f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | {dom} | "
              f"{(rf.get('useful_flops_ratio') or 0):.3f} | {delta} |")


if __name__ == "__main__":
    single = load("dryrun_single.json")
    multi = load("dryrun_multi.json")
    base_s = load("dryrun_baseline_single.json")
    dryrun_table(single, "Single-pod (16×16 = 256 chips)")
    dryrun_table(multi, "Multi-pod (2×16×16 = 512 chips)")
    print("\n### Roofline (single-pod, optimized; Δ vs paper-faithful baseline)")
    roofline_table(single, base_s)
    print("\n### Roofline (single-pod, paper-faithful BASELINE)")
    roofline_table(base_s)
