#!/usr/bin/env python
"""Summarize an obs metrics JSONL stream (and optionally check a trace).

Reads the JSONL written by ``Recorder.write_metrics`` (launch/train.py
``--metrics-out``, examples/coordinator_sim.py ``--metrics-out``) and prints
a per-round table: client counts (sampled / delivered / stragglers /
dropouts), close latency split into dispatch vs block-until-ready,
chunked-close stats (chunked flag / eager partial folds / analytic peak
close bytes), ring occupancy / evictions / stale drops, ledger bytes,
divergence, compile-cache misses and the measured-vs-analytic comm
reconciliation flag. Counter/gauge/histogram snapshots (including the
``uplink.ingest_bytes_per_s`` throughput gauge and the
``close.partial_folds`` / ``close.chunk_flush_us`` chunked-fold metrics)
print below the table.

``--check`` turns the report into an assertion pass (CI's obs smoke step):

* the stream has ``meta`` + ``counters`` records and ≥ 1 round record;
* every CLOSED round record (one carrying ``close_dispatch_us``) also
  carries its block time, divergence, ring stats and ledger bytes;
* no ``comm_match = 0`` (a round where the measured BytesLedger disagreed
  with core/comm.py's closed form);
* with spans in the stream (obs=trace): the Chrome trace (``--trace``) is
  structurally valid, and the OVERLAP INVARIANT holds — for consecutive
  closed rounds N, N+1 of the same run, round N+1's ``ring.write`` (or, in
  chunked-close mode, ``close.partial_fold``) spans intersect round N's
  close window [``close.dispatch`` start, ``divergence.resolve`` end]. This
  is the trace-level proof that the ring streams the next round's uplinks —
  and eagerly folds its full chunks — while the previous close is in
  flight.

  PYTHONPATH=src python scripts/obs_report.py metrics.jsonl
  PYTHONPATH=src python scripts/obs_report.py metrics.jsonl --trace trace.json --check
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple


def load_stream(path: str) -> List[Dict[str, Any]]:
    recs = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i + 1}: bad JSON line: {e}")
    return recs


def split_stream(recs: List[Dict[str, Any]]):
    meta = next((r for r in recs if r.get("type") == "meta"), None)
    counters = next((r for r in recs if r.get("type") == "counters"), None)
    rounds = [r for r in recs if r.get("type") == "round"]
    spans = [r for r in recs if r.get("type") == "span"]
    events = [r for r in recs if r.get("type") == "event"]
    return meta, counters, rounds, spans, events


# -- per-round table ---------------------------------------------------------

_COLS = [
    ("round", "round"), ("run", "run"), ("sampled", "smp"),
    ("delivered", "dlv"), ("stragglers", "strg"), ("dropped_out", "drop"),
    ("deadline_drops", "late"), ("quarantined", "quar"),
    ("degraded", "degr"), ("close_dispatch_us", "dispatch_us"),
    ("close_block_us", "block_us"), ("chunked", "chnk"),
    ("partial_folds", "pfold"), ("peak_bytes", "peak_B"),
    ("ring_occupancy", "occ"),
    ("ring_evictions", "evict"), ("stale_drops", "stale"),
    ("uplink_bytes", "up_B"), ("downlink_bytes", "down_B"),
    ("divergence", "divergence"), ("compile_miss", "miss"),
    ("comm_match", "comm"),
]


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.3g}"
    return str(v)


def round_table(rounds: List[Dict[str, Any]]) -> List[str]:
    header = [short for _, short in _COLS]
    body = [[_fmt(r.get(key)) for key, _ in _COLS] for r in rounds]
    widths = [max(len(h), *(len(row[i]) for row in body)) if body else len(h)
              for i, h in enumerate(header)]
    lines = [" ".join(h.rjust(w) for h, w in zip(header, widths))]
    for row in body:
        lines.append(" ".join(c.rjust(w) for c, w in zip(row, widths)))
    return lines


# -- the overlap invariant ---------------------------------------------------

def _closed_rounds(spans: List[Dict[str, Any]]
                   ) -> Dict[Tuple[Any, Any], Dict[str, float]]:
    """(run, round) → close window from span timestamps: the window opens at
    ``close.dispatch`` start and shuts at ``divergence.resolve`` end."""
    windows: Dict[Tuple[Any, Any], Dict[str, float]] = {}
    for s in spans:
        rid = s.get("args", {}).get("round")
        if rid is None:
            continue
        key = (s.get("run"), rid)
        if s["name"] == "close.dispatch":
            w = windows.setdefault(key, {})
            w["start"] = min(w.get("start", float("inf")), s["ts_us"])
        elif s["name"] == "divergence.resolve":
            w = windows.setdefault(key, {})
            w["end"] = max(w.get("end", 0.0), s["ts_us"] + s["dur_us"])
    return {k: w for k, w in windows.items()
            if "start" in w and "end" in w}


# witnesses of round N+1 progressing: raw uplink landings AND (chunked-close
# mode) the eager partial folds they trigger
_OVERLAP_WITNESSES = ("ring.write", "close.partial_fold")


def check_overlap(spans: List[Dict[str, Any]]) -> Tuple[List[str], List[str]]:
    """Verify the overlap invariant; returns (proven lines, failures).

    Only consecutive closed-round pairs (N, N+1) of the SAME run where round
    N+1 actually produced witness spans (``ring.write``, or the chunked
    ring's eager ``close.partial_fold``) are checked — a run's last round
    has no successor and non-engine paths write no ring spans.
    """
    windows = _closed_rounds(spans)
    writes: Dict[Tuple[Any, Any],
                 List[Tuple[float, float, str]]] = defaultdict(list)
    for s in spans:
        if s["name"] not in _OVERLAP_WITNESSES:
            continue
        rid = s.get("args", {}).get("round")
        if rid is not None:
            writes[(s.get("run"), rid)].append(
                (s["ts_us"], s["ts_us"] + s["dur_us"], s["name"]))

    proven, failures = [], []
    for (run, rid), w in sorted(windows.items(),
                                key=lambda kw: (str(kw[0][0]), kw[0][1])):
        nxt = (run, rid + 1)
        if nxt not in windows or nxt not in writes:
            continue
        lo, hi = w["start"], w["end"]
        hit_names = sorted({name for (a, b, name) in writes[nxt]
                            if a < hi and b > lo})
        hits = sum(1 for (a, b, _) in writes[nxt] if a < hi and b > lo)
        tag = f"run={run} round={rid}→{rid + 1}"
        if hits:
            proven.append(f"  {tag}: {hits}/{len(writes[nxt])} "
                          f"{'/'.join(hit_names)} span(s) overlap the close "
                          f"window [{lo:.0f}, {hi:.0f}]us")
        else:
            failures.append(
                f"{tag}: none of round {rid + 1}'s {len(writes[nxt])} "
                f"{'/'.join(_OVERLAP_WITNESSES)} spans intersect round "
                f"{rid}'s close window [{lo:.0f}, {hi:.0f}]us — the ring "
                "did not overlap the close")
    return proven, failures


# -- trace JSON structure ----------------------------------------------------

def check_trace_file(path: str) -> List[str]:
    """Structural validation of a Chrome trace-event JSON export."""
    problems = []
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"trace {path}: unreadable ({e})"]
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"trace {path}: no traceEvents"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            problems.append(f"trace event {i}: missing ph/name: {ev!r}")
            continue
        if ev["ph"] == "X" and not (isinstance(ev.get("ts"), (int, float))
                                    and isinstance(ev.get("dur"), (int, float))):
            problems.append(f"trace event {i} ({ev['name']}): X-phase event "
                            "without numeric ts/dur")
    if not any(ev.get("ph") == "X" for ev in events):
        problems.append(f"trace {path}: no complete (ph=X) span events")
    return problems


# -- --check -----------------------------------------------------------------

# every CLOSED round record must carry these (a record is "closed" when the
# engine stamped its dispatch time on it)
_CLOSED_REQUIRED = ("close_block_us", "divergence", "ring_evictions",
                    "stale_drops", "uplink_bytes", "downlink_bytes")


def run_chaos_checks(rounds: List[Dict[str, Any]]) -> List[str]:
    """``--chaos`` assertions for a fault-injected stream:

    * ≥ 1 round stamped ``global_finite`` and ALL stamps are 1 — no poisoned
      uplink leaked a non-finite value into the served global adapter;
    * ≥ 1 round stamped ``clean_exact`` and ALL stamps are 1 — the chaos
      scenario's close is bitwise identical to its crash-twin run with the
      faulty clients absent (clean-lane exactness, stamped by
      examples/coordinator_sim.py's chaos scenario).
    """
    failures: List[str] = []
    for key, what in (("global_finite",
                       "a non-finite value reached the global adapter"),
                      ("clean_exact",
                       "the quarantined close diverged from its clean twin")):
        stamped = [r for r in rounds if key in r]
        if not stamped:
            failures.append(f"--chaos: no round record carries {key} — the "
                            "chaos scenario never ran")
            continue
        for r in stamped:
            if r.get(key) != 1:
                failures.append(f"round {r.get('round')} "
                                f"(run={r.get('run')}): {key}=0 — {what}")
    return failures


def run_checks(meta, counters, rounds, spans, trace_path: Optional[str],
               chaos: bool = False) -> List[str]:
    failures: List[str] = []
    if meta is None:
        failures.append("stream has no meta record")
    if counters is None:
        failures.append("stream has no counters record")
    if not rounds:
        failures.append("stream has no round records")
    closed = [r for r in rounds if "close_dispatch_us" in r]
    if rounds and not closed:
        failures.append("no round record carries close_dispatch_us — "
                        "no engine close was ever traced")
    for r in closed:
        missing = [k for k in _CLOSED_REQUIRED if k not in r]
        if missing:
            failures.append(f"round {r.get('round')} (run={r.get('run')}) "
                            f"closed but missing {missing}")
    mismatched = [r for r in rounds if r.get("comm_match") == 0]
    for r in mismatched:
        failures.append(f"round {r.get('round')} (run={r.get('run')}): "
                        "measured ledger ≠ core/comm.py closed form")
    if spans:
        proven, overlap_failures = check_overlap(spans)
        failures += overlap_failures
        if not proven and not overlap_failures:
            failures.append("spans present but no consecutive closed-round "
                            "pair with ring.write spans — nothing proves "
                            "the overlap invariant")
        if trace_path:
            failures += check_trace_file(trace_path)
    elif trace_path:
        failures.append("--trace given but the metrics stream has no spans "
                        "(was the run obs=basic?)")
    if chaos:
        failures += run_chaos_checks(rounds)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("metrics", help="metrics JSONL (Recorder.write_metrics)")
    ap.add_argument("--trace", default="",
                    help="Chrome trace JSON to validate alongside (--check)")
    ap.add_argument("--check", action="store_true",
                    help="assert required fields, comm reconciliation and "
                         "the overlap invariant; exit 1 on any failure")
    ap.add_argument("--chaos", action="store_true",
                    help="with --check: also assert the fault-injection "
                         "witnesses (global_finite / clean_exact round "
                         "stamps all 1)")
    args = ap.parse_args(argv)

    recs = load_stream(args.metrics)
    meta, counters, rounds, spans, events = split_stream(recs)

    if meta:
        env = {k: v for k, v in meta.items() if k != "type"}
        print("env:", " ".join(f"{k}={v}" for k, v in env.items()))
    print(f"stream: {len(rounds)} round(s), {len(spans)} span(s), "
          f"{len(events)} event(s)")
    if rounds:
        print()
        for line in round_table(rounds):
            print(line)
    if counters:
        print()
        for name in sorted(counters.get("counters", {})):
            print(f"counter {name} = {counters['counters'][name]}")
        for name in sorted(counters.get("gauges", {})):
            print(f"gauge   {name} = {counters['gauges'][name]}")
        for name, s in sorted(counters.get("histograms", {}).items()):
            if s.get("count"):
                print(f"hist    {name}: n={s['count']} mean={s['mean']:.1f} "
                      f"min={s['min']:.1f} max={s['max']:.1f}")
    if spans:
        proven, overlap_failures = check_overlap(spans)
        print()
        if proven:
            print("overlap invariant (next round's ring.write ∩ close window):")
            for line in proven:
                print(line)
        for line in overlap_failures:
            print("OVERLAP FAILURE:", line)

    if not args.check:
        return 0
    failures = run_checks(meta, counters, rounds, spans,
                          args.trace or None, chaos=args.chaos)
    print()
    if failures:
        print(f"CHECK FAILED ({len(failures)} problem(s)):")
        for f in failures:
            print("  -", f)
        return 1
    print("CHECK OK: round records complete, comm reconciled"
          + (", overlap invariant proven, trace valid" if spans else "")
          + (", chaos witnesses hold" if args.chaos else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
