"""FedEx-LoRA: exact federated LoRA aggregation as a multi-pod JAX framework.

See README.md / DESIGN.md. Public entry points:

    repro.configs      — model/shape/LoRA/federated config registry
    repro.models       — build_model(cfg) for all 6 architecture families
    repro.core         — the paper's aggregation math + federated driver
    repro.kernels      — Pallas TPU kernels (lora_matmul, fedex_residual, flash_swa)
    repro.sharding     — 2D training + weight-stationary serving layouts
    repro.launch       — dryrun / train / serve drivers, mesh, HLO analysis
"""

__version__ = "1.0.0"
