from repro.checkpoint.checkpoint import (ROUND_STATE_FILE, load_checkpoint,
                                         round_state_path, save_checkpoint)

__all__ = ["ROUND_STATE_FILE", "load_checkpoint", "round_state_path",
           "save_checkpoint"]
