"""Flat-path ``.npz`` checkpointing for pytrees + federated round state.

No external deps (orbax unavailable offline): trees are flattened to
``path → array`` with '/'-joined keys and stored via numpy. Scalars/metadata
ride along in a JSON sidecar entry.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.util.tree import flatten_with_paths, unflatten_from_paths

_META_KEY = "__meta__"

# single rolling round-state file per run directory: each boundary snapshot
# atomically replaces the previous one (crash mid-save leaves the old file)
ROUND_STATE_FILE = "round_state.npz"


def round_state_path(directory: str) -> str:
    """Canonical round-boundary snapshot path inside a checkpoint dir."""
    return os.path.join(directory, ROUND_STATE_FILE)


def save_checkpoint(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    flat = flatten_with_paths(tree)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            arr = arr.astype(np.float32)
        arrays[k.replace("=", "_")] = arr
    payload = {"meta": meta or {}, "bf16_keys": dtypes}
    arrays[_META_KEY] = np.frombuffer(json.dumps(payload).encode(), dtype=np.uint8)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_checkpoint(path: str) -> tuple[Any, Dict]:
    with np.load(path, allow_pickle=False) as z:
        payload = json.loads(bytes(z[_META_KEY].tobytes()).decode())
        bf16 = payload.get("bf16_keys", {})
        flat = {}
        for k in z.files:
            if k == _META_KEY:
                continue
            arr = z[k]
            if k in bf16:
                arr = jnp.asarray(arr, jnp.bfloat16)
            else:
                arr = jnp.asarray(arr)
            flat[k] = arr
    return unflatten_from_paths(flat), payload.get("meta", {})
