"""Config registry: ``get_config(name)`` / ``list_configs()`` / shapes."""

from repro.configs.base import (
    FedConfig,
    LoRAConfig,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    config_dict,
    validate_fed_lora,
)
from repro.configs.shapes import SHAPES, get_shape
from repro.util.registry import Registry

CONFIGS: Registry = Registry("model-configs")

from repro.configs import (  # noqa: E402
    deepseek_v2_236b,
    gemma3_12b,
    granite_8b,
    internvl2_76b,
    mixtral_8x22b,
    paper_models,
    qwen2_5_3b,
    starcoder2_15b,
    whisper_medium,
    xlstm_1_3b,
    zamba2_7b,
)

# The ten assigned architectures.
ASSIGNED = {
    "whisper-medium": whisper_medium.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "granite-8b": granite_8b.CONFIG,
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "gemma3-12b": gemma3_12b.CONFIG,
    "internvl2-76b": internvl2_76b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "xlstm-1.3b": xlstm_1_3b.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
}

_ALL = dict(ASSIGNED)
_ALL.update(
    {
        "paper-gpt2": paper_models.GPT2_SMALL,
        "paper-llama3.2-3b": paper_models.LLAMA32_3B,
        "paper-tiny": paper_models.TINY,
    }
)

for _name, _cfg in _ALL.items():
    CONFIGS.register(_name)(_cfg)


def get_config(name: str) -> ModelConfig:
    """Look up a model config; ``<name>-smoke`` returns the reduced variant."""
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    return CONFIGS.get(name)


def list_configs():
    return CONFIGS.names()


__all__ = [
    "ASSIGNED",
    "CONFIGS",
    "FedConfig",
    "LoRAConfig",
    "ModelConfig",
    "SHAPES",
    "ShapeConfig",
    "TrainConfig",
    "config_dict",
    "get_config",
    "get_shape",
    "list_configs",
]
