"""Config dataclasses for the model zoo, input shapes, LoRA and federated runs.

Every assigned architecture is expressed as a :class:`ModelConfig`. The single
dataclass covers the six architecture families (dense / moe / ssm / hybrid /
encdec / vlm) — family-specific fields default to "off" values so dense configs
stay small. ``reduced()`` derives the CPU smoke-test variant mandated by the
assignment (≤2 layers, d_model ≤ 512, ≤4 experts).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation for the config (paper / model card)

    # --- attention ----------------------------------------------------------
    head_dim: int = 0  # 0 → d_model // num_heads
    rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0  # >0 → SWA with this window on ALL attn layers
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global
    local_window: int = 0  # window used by "local" layers
    max_position_embeddings: int = 131_072
    learned_pos_embeddings: bool = False  # whisper-style

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden; 0 → d_ff
    first_k_dense: int = 0  # leading dense layers (deepseek)
    dense_d_ff: int = 0  # d_ff for those leading dense layers
    router_aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25

    # --- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0  # zamba2: shared attention block every N mamba layers

    # --- xLSTM ---------------------------------------------------------------
    slstm_every: int = 0  # one sLSTM block per period of this many blocks

    # --- encoder-decoder (whisper) -------------------------------------------
    enc_layers: int = 0
    enc_seq_len: int = 0  # frames emitted by the (stubbed) audio frontend

    # --- vlm -----------------------------------------------------------------
    vision_tokens: int = 0  # patch embeddings emitted by the (stubbed) ViT

    # --- misc ----------------------------------------------------------------
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k tokens is sub-quadratic / windowed (DESIGN §4)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 or self.local_global_ratio > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (whisper is enc-dec)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts, small vocab."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv_heads = max(1, min(self.num_kv_heads, num_heads))
        kw: Dict = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else 0,
            max_position_embeddings=4096,
        )
        if self.is_moe:
            kw.update(
                num_experts=min(self.num_experts, 4),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 256),
                first_k_dense=min(self.first_k_dense, 1),
                dense_d_ff=min(self.dense_d_ff, 256) if self.dense_d_ff else 0,
            )
        if self.mla:
            kw.update(kv_lora_rank=32, q_lora_rank=64, qk_rope_head_dim=16,
                      qk_nope_head_dim=32, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32)
        if self.attn_every:
            kw.update(attn_every=1, num_layers=2)
        if self.slstm_every:
            kw.update(slstm_every=2, num_layers=2)
        if self.enc_layers:
            kw.update(enc_layers=2, enc_seq_len=64)
        if self.vision_tokens:
            kw.update(vision_tokens=16)
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.local_global_ratio:
            # keep exactly one (1 local + 1 global) period
            kw.update(local_global_ratio=1, local_window=64, num_layers=2)
        elif self.local_window:
            kw.update(local_window=64)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 4
    alpha: float = 8.0
    target_modules: Tuple[str, ...] = ("q_proj", "k_proj", "v_proj", "o_proj")
    include_mlp: bool = False  # also adapt FFN / expert projections
    lora_experts: bool = False  # per-expert adapters on MoE expert matrices
    dropout: float = 0.0  # kept for config parity; applied host-side in train

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


@dataclass(frozen=True)
class FedConfig:
    """Federated run settings (paper: 3-client cross-silo, FedAvg-style rounds)."""

    num_clients: int = 3
    rounds: int = 5
    local_steps: int = 10  # steps per client per round ("local epochs" analog)
    method: str = "fedex"  # fedex | fedit | ffa | fedex_svd | hetero | centralized
    svd_rank: int = 0  # fedex_svd: truncation rank r' (0 → k*r, i.e. exact)
    assignment: str = "average"  # average | keep_local | reinit  (Table 5)
    dirichlet_alpha: float = 0.5  # non-IID split concentration
    seed: int = 0
    # differential privacy on uploads (paper §7 future work; core/privacy.py):
    dp_clip: float = 0.0  # 0 → off; else L2 clip on the adapter delta
    dp_noise_multiplier: float = 0.0  # Gaussian σ = multiplier · clip
    # heterogeneous client ranks (paper §6 open problem; core/hetero.py +
    # core/engine.py method="hetero"): client i trains a rank-rᵢ adapter,
    # padded to r_max = lora.rank at the server; ``method="hetero"`` with an
    # empty tuple defaults every client to lora.rank (uniform hetero).
    client_ranks: Tuple[int, ...] = ()  # non-empty → the hetero close
    # per-client local step counts (mesh mode masks scan iterations past a
    # client's budget); empty → every client runs ``local_steps``
    client_local_steps: Tuple[int, ...] = ()
    # --- fedsrv coordinator (partial participation / stragglers / async) ---
    participation: float = 1.0  # fraction of clients sampled per round
    min_quorum: int = 0  # deliveries needed before the deadline cuts (0 → 1)
    round_deadline: float = 0.0  # sim-seconds; 0 → wait for every non-dropout
    weighting: str = "uniform"  # uniform | examples (wᵢ = nᵢ/Σnⱼ)
    mean_latency: float = 1.0  # straggler model: fleet-baseline sim-seconds
    latency_jitter: float = 0.25  # lognormal σ on client latency
    dropout_prob: float = 0.0  # P(client accepts round, never reports)
    straggler_prob: float = 0.0  # P(latency × straggler_factor)
    straggler_factor: float = 5.0
    async_buffer: int = 0  # >0 → FedBuff-style commits of this buffer size
    staleness_alpha: float = 0.5  # async: weight ∝ (1+staleness)^(−α)
    quantize_uplink: str = "none"  # none | fp16 | int8 adapter uplink codec
    # --- fused round-close engine (core/engine.py) ---
    # "auto" → single-dispatch stacked-client close for every engine-covered
    # method (fedex/average, fedex_svd, keep_local, reinit): Pallas kernels
    # on TPU, jitted jnp twin elsewhere; "jnp"/"pallas" force a backend;
    # "off" → the legacy eager list-of-trees close.
    engine: str = "auto"
    # RoundBuffers ring depth: how many rounds' uplink stacks may be in
    # flight at once (2 = classic double buffering; >2 lets FedBuff commits
    # pipeline deeper). With an async buffer, rounds lagging ring_max_lag or
    # more commit versions are EVICTED from a full ring rather than wedging
    # it (stale uplinks for them are dropped).
    ring_depth: int = 2
    ring_max_lag: int = 1
    # chunked streaming round closes (core/engine.py chunked ring mode):
    # 0 → the classic stacked (C_max, …) close; N ≥ 1 → uplinks accumulate
    # in fixed-size N-client chunks, each full chunk folding eagerly on the
    # device while later uplinks keep streaming, so peak close memory is
    # O(chunk) instead of O(C). Auto semantics: a round whose candidate set
    # fits in one chunk still takes the stacked close, preserving the
    # stacked path's bitwise contract for small rounds.
    close_chunk: int = 0
    # observability mode (repro.obs): "off" → shared zero-overhead no-op
    # recorder, "basic" → metrics + per-round records, "trace" → spans too
    # (Chrome trace-event export). The launcher's --trace/--metrics-out
    # flags imply trace/basic respectively.
    obs: str = "off"
    # --- fault injection + defended uplink (fedsrv/faults.py) ---
    # fault plan DSL, e.g. "nan@0.1;truncate@1(clients=2,rounds=0+1)" — ""
    # disables injection entirely. Seeded from `seed` via per-purpose rng
    # streams, so a plan replays bitwise regardless of participation.
    faults: str = ""
    # validate every decoded uplink against the registered adapter spec
    # (finite check, per-leaf shape/dtype, optional ∞-norm ceiling). Bad
    # uplinks are QUARANTINED: lane weight-masked to zero, close exact over
    # the survivors.
    uplink_validation: bool = True
    uplink_max_norm: float = 0.0  # 0 → no norm-outlier rejection
    uplink_retries: int = 2  # transient decode failures: bounded retries
    retry_backoff: float = 0.05  # sim-seconds; backoff · 2^attempt
    # --- crash-safe round state (checkpoint/) ---
    checkpoint_dir: str = ""  # "" → no round-state snapshots
    checkpoint_every: int = 1  # snapshot every N round boundaries

    def __post_init__(self):
        if self.method not in ("fedex", "fedit", "ffa", "fedex_svd",
                               "hetero", "centralized"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.client_ranks:
            if len(self.client_ranks) != self.num_clients:
                raise ValueError(
                    f"client_ranks has {len(self.client_ranks)} entries for "
                    f"{self.num_clients} clients")
            if any(r < 1 for r in self.client_ranks):
                raise ValueError(
                    f"client_ranks must be ≥ 1, got {self.client_ranks}")
        if self.client_local_steps:
            if len(self.client_local_steps) != self.num_clients:
                raise ValueError(
                    f"client_local_steps has {len(self.client_local_steps)} "
                    f"entries for {self.num_clients} clients")
            if any(not 1 <= s <= self.local_steps
                   for s in self.client_local_steps):
                raise ValueError(
                    f"client_local_steps must lie in [1, local_steps="
                    f"{self.local_steps}], got {self.client_local_steps}")
        if self.assignment not in ("average", "keep_local", "reinit"):
            raise ValueError(f"unknown assignment {self.assignment!r}")
        if self.engine not in ("auto", "jnp", "pallas", "off"):
            raise ValueError(f"unknown engine {self.engine!r} "
                             "(auto | jnp | pallas | off)")
        if self.svd_rank < 0:
            raise ValueError(
                f"svd_rank must be ≥ 0, got {self.svd_rank} "
                "(0 → exact aggregation, r' ≥ 1 → rank-r' truncation)")
        if self.weighting not in ("uniform", "examples"):
            raise ValueError(f"unknown weighting {self.weighting!r}")
        if self.ring_depth < 1:
            raise ValueError(f"ring_depth must be ≥ 1, got {self.ring_depth}")
        if self.ring_max_lag < 1:
            raise ValueError(
                f"ring_max_lag must be ≥ 1, got {self.ring_max_lag} "
                "(a commit may always lag up to its own version)")
        if self.close_chunk < 0:
            raise ValueError(
                f"close_chunk must be ≥ 0, got {self.close_chunk} "
                "(0 → stacked closes, N ≥ 1 → N-client streaming chunks)")
        if self.obs not in ("off", "basic", "trace"):
            raise ValueError(f"unknown obs mode {self.obs!r} "
                             "(off | basic | trace)")
        if self.uplink_retries < 0:
            raise ValueError(
                f"uplink_retries must be ≥ 0, got {self.uplink_retries}")
        if self.uplink_max_norm < 0:
            raise ValueError(
                f"uplink_max_norm must be ≥ 0, got {self.uplink_max_norm}")
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be ≥ 1, got {self.checkpoint_every}")
        if self.faults:
            # parse up front so a bad plan fails at config time, not round 40
            # (runtime import: configs must stay importable without fedsrv)
            from repro.fedsrv.faults import FaultPlan
            FaultPlan.parse(self.faults, seed=self.seed)


@dataclass(frozen=True)
class ServeConfig:
    """HTTP federation service surface (fedsrv/server.py).

    Only the SOCKET-facing knobs live here — everything federation-semantic
    (clients, rounds, quorum, deadline, weighting, codec, engine backend)
    stays in :class:`FedConfig`, so a served deployment and an in-process
    simulation are configured by the same dataclass and close identically.
    """

    host: str = "127.0.0.1"
    port: int = 8077  # 0 → ephemeral (the bound port is reported at startup)
    # bounded concurrent-uplink admission (backpressure): POSTs beyond this
    # many in-flight decodes get 429 + Retry-After instead of piling decoded
    # payloads into memory
    max_concurrent: int = 16
    # per-(client, round) POST budget — a client re-POSTing past this gets
    # 429 (quota); ≥ 2 leaves room for one honest retry after a 5xx
    quota_per_round: int = 4
    # shared bearer-token auth stub: "" disables auth; otherwise every POST
    # must carry "Authorization: Bearer <token>"
    token: str = ""

    def __post_init__(self):
        if not 0 <= self.port <= 65535:
            raise ValueError(f"port must be in [0, 65535], got {self.port}")
        if self.max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be ≥ 1, got {self.max_concurrent}")
        if self.quota_per_round < 1:
            raise ValueError(
                f"quota_per_round must be ≥ 1, got {self.quota_per_round}")


def validate_fed_lora(fed: "FedConfig", lora: "LoRAConfig") -> None:
    """Cross-config validation needing both dataclasses (call at launch).

    The fedex_svd truncation rank r' is bounded by the residual's rank:
    ΔW_res = Σwᵢaᵢ(bᵢ − b̄) has at most k·r nonzero singular values, so any
    r' > k·r transmits pure padding — reject it up front instead of letting
    ``fedex_svd_aggregate`` fall through to a silently-degenerate dense SVD.
    ``svd_rank = 0`` keeps the documented "exact" meaning (the plain fedex
    close; nothing is truncated).
    """
    if fed.method == "fedex_svd" and fed.svd_rank > fed.num_clients * lora.rank:
        raise ValueError(
            f"svd_rank={fed.svd_rank} exceeds the residual rank bound "
            f"k·r = {fed.num_clients}·{lora.rank} = "
            f"{fed.num_clients * lora.rank}; use 0 for the exact close")
    if fed.client_ranks and max(fed.client_ranks) > lora.rank:
        raise ValueError(
            f"client_ranks max {max(fed.client_ranks)} exceeds the r_max "
            f"template lora.rank={lora.rank}; ragged uplinks are padded to "
            "lora.rank, never truncated")


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_ratio: float = 0.02
    schedule: str = "cosine"  # cosine | linear | constant
    total_steps: int = 1000
    batch_size: int = 8
    seq_len: int = 128
    microbatch: int = 0  # 0 → no grad accumulation
    seed: int = 0


def config_dict(cfg) -> Dict:
    return dataclasses.asdict(cfg)
