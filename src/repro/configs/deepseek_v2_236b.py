"""deepseek-v2-236b [moe]: MLA kv_lora=512, 2 shared + 160 routed top-6. [arXiv:2405.04434]

Multi-head Latent Attention: KV compressed to kv_lora_rank=512 (+ decoupled RoPE
key of dim 64); queries via q_lora_rank=1536. First layer is dense (d_ff=12288);
remaining layers are MoE with per-expert hidden 1536.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: kv heads == heads post-decompression
    d_ff=1536,
    vocab_size=102_400,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_k_dense=1,
    dense_d_ff=12_288,
    rope=True,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=131_072,
)
