"""gemma3-12b [dense]: 5:1 local:global attention, 128k ctx. [hf:google/gemma-3-1b-pt]

head_dim=256 (decoupled from d_model), local layers use a 1024-token sliding
window; every 6th layer is global.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    rope=True,
    rope_theta=1_000_000.0,
    local_global_ratio=5,
    local_window=1024,
    norm="rmsnorm",
    act="gelu",
    max_position_embeddings=131_072,
    tie_embeddings=True,
)
