"""granite-8b [dense]: llama-arch code model, GQA kv=8. [arXiv:2405.04324]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=49_152,
    rope=True,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=8_192,
)
