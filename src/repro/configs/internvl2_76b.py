"""internvl2-76b [vlm]: InternViT (STUBBED) + Llama-3-70B-style LM. [arXiv:2404.16821]

The vision encoder + MLP projector is a stub: ``input_specs`` provides
``vision_tokens`` precomputed patch embeddings of shape (batch, 256, d_model)
which the LM consumes by prefix-concatenation with the token embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    vision_tokens=256,
    rope=True,
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=32_768,
)
