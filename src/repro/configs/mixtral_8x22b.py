"""mixtral-8x22b [moe]: 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=32_768,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=16_384,
    rope=True,
    rope_theta=1_000_000.0,
    sliding_window=4_096,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=65_536,
)
