"""The paper's own evaluation models, expressed in our config system.

FedEx-LoRA evaluates on RoBERTa-base/large (NLU), GPT-2 (NLG), and
Mistral-7B / Gemma-2 9B / Llama-3.2 3B (instruction tuning). We include
decoder-only equivalents for GPT-2 and Llama-3.2 3B as first-class configs so
the paper's federated experiments can be run end-to-end in this framework, plus
a tiny variant used by examples/tests (the paper's math is size-independent).
"""

from repro.configs.base import ModelConfig

GPT2_SMALL = ModelConfig(
    name="paper-gpt2",
    family="dense",
    source="arXiv:1905.00537 (GPT-2 124M, paper §5.3)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=50_257,
    rope=False,
    learned_pos_embeddings=True,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    max_position_embeddings=1024,
    tie_embeddings=True,
)

LLAMA32_3B = ModelConfig(
    name="paper-llama3.2-3b",
    family="dense",
    source="arXiv:2407.21783 (paper §5.1 commonsense)",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    rope=True,
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=131_072,
    tie_embeddings=True,
)

# Tiny decoder used by examples, federated-convergence benchmarks and tests:
# the aggregation math the paper proves is size-independent.
TINY = ModelConfig(
    name="paper-tiny",
    family="dense",
    source="framework-internal (paper math is size-independent)",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    rope=True,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=2048,
    tie_embeddings=True,
)
