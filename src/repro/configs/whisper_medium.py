"""whisper-medium [audio]: enc-dec, conv/mel frontend STUBBED. [arXiv:2212.04356]

24 encoder + 24 decoder layers, d_model=1024, 16 heads (MHA, kv=16), d_ff=4096,
vocab=51865, learned positional embeddings, LayerNorm + GELU. The mel-spectrogram
+ conv feature extractor is a stub: ``input_specs`` feeds precomputed frame
embeddings of shape (batch, enc_seq_len, d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    source="arXiv:2212.04356",
    num_layers=24,  # decoder layers
    enc_layers=24,
    enc_seq_len=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    rope=False,
    learned_pos_embeddings=True,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    max_position_embeddings=32_768,
    tie_embeddings=True,
)
