"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks (7:1 ratio), no separate FFN. [arXiv:2405.04517]

48 blocks, d_model=2048, 4 heads. Period of 8: 7 mLSTM (matrix-memory, parallel
linear-attention-style) + 1 sLSTM (scalar-memory recurrence via lax.scan).
d_ff=0 — projection up/down lives inside the blocks (expand factor 2).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=8,
    ssm_expand=2,
    rope=False,
    norm="layernorm",
    act="gelu",
    max_position_embeddings=1_048_576,
    tie_embeddings=True,
)
