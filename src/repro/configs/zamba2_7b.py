"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

81 Mamba2 layers with ONE parameter-shared attention+MLP block applied
periodically (every 6 mamba layers here). ssm_state=64, GQA kv=32 on the
shared block.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope=True,
    norm="rmsnorm",
    act="silu",
    max_position_embeddings=1_048_576,
)
