"""FedEx-LoRA core: exact federated aggregation of LoRA adapters (paper §4)."""

from repro.core.aggregation import (
    apply_residual,
    apply_residual_fused,
    assign_after_aggregation,
    fedex_aggregate,
    fedex_residual,
    fedex_svd_aggregate,
    fedit_aggregate,
    ffa_aggregate,
    map_factors,
    normalize_weights,
    per_client_residuals,
    product_mean,
    tree_mean,
)
from repro.core.decompose import (
    factored_residual_params,
    reconstruct,
    residual_factors,
    truncated_residual_params,
    truncated_svd_product,
)
from repro.core.divergence import deviation_tree, flatten_deviations, mean_deviation
from repro.core.engine import (DeferredDivergence, RoundBuffers,
                               RoundCloseEngine, make_close_fn)
from repro.core.federated import FederatedTrainer, make_eval_fn, make_local_step
from repro.core.lora import init_lora, lora_param_count, merge_lora, resolve_targets

__all__ = [
    "DeferredDivergence",
    "FederatedTrainer",
    "RoundBuffers",
    "RoundCloseEngine",
    "apply_residual",
    "apply_residual_fused",
    "assign_after_aggregation",
    "deviation_tree",
    "factored_residual_params",
    "fedex_aggregate",
    "fedex_residual",
    "fedex_svd_aggregate",
    "fedit_aggregate",
    "ffa_aggregate",
    "flatten_deviations",
    "init_lora",
    "lora_param_count",
    "make_close_fn",
    "make_eval_fn",
    "make_local_step",
    "map_factors",
    "mean_deviation",
    "merge_lora",
    "normalize_weights",
    "per_client_residuals",
    "product_mean",
    "reconstruct",
    "residual_factors",
    "resolve_targets",
    "tree_mean",
    "truncated_residual_params",
    "truncated_svd_product",
]
