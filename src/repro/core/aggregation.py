"""Federated aggregation operators — the paper's contribution (§4).

All operators act on *lists of client adapter trees* (k trees of identical
structure; every factor leaf is ``a: (..., d_in, r)`` / ``b: (..., r, d_out)``
possibly with leading stacked-layer axes — ``jnp.matmul`` batches over them).

* ``fedit``      — FedIT/FedAvg of factors (inexact; Eq. 3–4).
* ``fedex``      — factor averages + residual  ΔW_res = mean(aᵢ bᵢ) − ā b̄
                   (Eq. 11–12). Folding scale·ΔW_res into W0 makes aggregation
                   EXACT (Eq. 7–9).
* ``fedex_svd``  — FedEx with Eckart–Young-optimal rank-r' truncation of the
                   residual (Eq. 15–16) for server-controlled communication.
* ``ffa``        — FFA-LoRA: a frozen at init, b averaged (exact by
                   construction, fewer trainable params).
* assignment strategies (§6, Table 5): ``average`` (FedEx), ``keep_local``,
  ``reinit`` — all exact, different post-aggregation (aᵢ, bᵢ).

Every operator accepts optional per-client ``weights`` (e.g. example counts
``wᵢ = nᵢ/Σnⱼ`` over the round's *participating subset* — fedsrv/). The
residual identity ``Σwᵢ aᵢbᵢ = ā b̄ + ΔW_res`` with ``ā = Σwᵢaᵢ`` stays exact
for any normalized weights: ΔW_res is *defined* as the difference. ``weights
= None`` (or uniform) takes the historical ``sum/k`` path bit-for-bit.

Which path runs where
---------------------
THIS module is the eager, op-by-op **ground truth** — lists of client trees,
one jnp op per step, trivially auditable against the paper's equations. The
production round close for EVERY engine-covered method — ``fedex``/average,
``fedex_svd``, and the §6 assignment strategies ``reinit`` and
``keep_local`` — runs through ``core/engine.py``: ONE jitted program over
``(C_max, …)``-stacked client buffers (streamed in by fedsrv/transport as
deliveries arrive) that computes the weighted factor means, the
method-specific residual fold and the §6 divergence in a single dispatch —
via these same operators (jnp backend) or the kernels/fedex_residual family
(weighted residual + signed product_fold + perclient_fold) and
kernels/factor_mean Pallas kernels (TPU backend, no dense m×n residual in
HBM). Method-by-method:

* ``fedex`` — engine hot path; ``fedit``/``ffa`` remain eager (a plain
  factor mean, nothing to fuse).
* ``fedex_svd`` — the engine computes the Eckart–Young rank-r' residual on
  the FACTORED form (``engine.factored_truncated_residual``: two (C·r)² Gram
  eigendecompositions + a small SVD — the dense m×n residual that
  ``fedex_svd_aggregate`` hands to ``jnp.linalg.svd`` here is never formed)
  and folds A'@B' in the same dispatch. ``fedex_svd_aggregate`` stays the
  dense eager oracle; engine matches it to ~1e-5 relative (Gram squaring).
* ``reinit`` — the engine folds the full ideal update Σwᵢaᵢbᵢ (the signed
  product kernel) and redraws adapters via :func:`reinit_adapters` — the
  SAME deterministic fold-in this module's eager path uses, so both paths
  produce bitwise-identical adapters.
* ``keep_local`` — the engine folds every delivered client's residual
  Σwⱼaⱼbⱼ − aᵢbᵢ into that client's OWN base in one pass over
  (C_max, …)-stacked per-lane W0 buffers; :func:`per_client_residuals` here
  is the eager oracle only.

The mesh-collective twin of ``fedex`` (a masked WEIGHTED psum-mean over a
sharded client axis inside one pjit'd program — partial participation and
non-uniform weights enter only through the weight vector) lives in
launch/mesh_train.py, reached via ``launch/train.py --mode mesh``.

The C_max padding contract: engine stacks are always ``(C_max, …)``; a
round's candidates get lanes in client-id order and non-delivered lanes keep
weight 0 (the participation mask), so ragged quorums / weighted rounds reuse
one compiled program. The engine's uniform full-participation
fedex/reinit/keep_local closes are bitwise identical to the *jitted*
composition of these operators; the eager path here differs from any fused
program by ≤2 ulp (XLA FMA contraction). Double-buffer rotation rules
(engine.RoundBuffers): each round's stacks are freshly allocated because the
close program CONSUMES (donates) its round's set; at most ``depth`` rounds
may be open at once — round N+1's uplinks stream into a new set while round
N's close is in flight, and ``take()`` pops rounds strictly FIFO.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

Weights = Optional[Sequence[float]]


def normalize_weights(weights: Weights, k: int) -> Optional[List[float]]:
    """Validate + normalize client weights to sum 1.

    Returns ``None`` for the uniform case (including ``weights=None`` and any
    all-equal vector) so callers can take the historical ``sum/k`` path, which
    keeps uniform aggregation bitwise identical to the unweighted operators.
    """
    if weights is None:
        return None
    w = [float(x) for x in weights]
    if len(w) != k:
        raise ValueError(f"got {len(w)} weights for {k} clients")
    if any(x < 0 for x in w):
        raise ValueError(f"negative client weight in {w}")
    total = sum(w)
    if total <= 0:
        raise ValueError(f"client weights sum to {total}; need > 0")
    w = [x / total for x in w]
    if all(x == w[0] for x in w):
        return None  # uniform → legacy path
    return w


# --------------------------------------------------------------------------
# tree utilities
# --------------------------------------------------------------------------

def tree_mean(trees: List[Params], weights: Weights = None) -> Params:
    k = len(trees)
    w = normalize_weights(weights, k)
    if w is None:
        return jax.tree.map(
            lambda *xs: sum(x.astype(jnp.float32) for x in xs) / k, *trees)
    return jax.tree.map(
        lambda *xs: sum(wi * x.astype(jnp.float32) for wi, x in zip(w, xs)),
        *trees)


def _is_factor(node: Any) -> bool:
    return isinstance(node, dict) and set(node.keys()) >= {"a", "b"}


def map_factors(fn, *trees: Params) -> Params:
    """Apply ``fn(*factor_dicts) → value`` at every {a, b} node."""

    def walk(*nodes):
        if _is_factor(nodes[0]):
            return fn(*nodes)
        if isinstance(nodes[0], dict):
            return {k: walk(*[n[k] for n in nodes]) for k in nodes[0]}
        return nodes[0]

    return walk(*trees)


# --------------------------------------------------------------------------
# aggregation operators
# --------------------------------------------------------------------------

def fedit_aggregate(client_loras: List[Params], weights: Weights = None) -> Params:
    """FedAvg of A and B independently (Eq. 3). Inexact (Eq. 4)."""
    return tree_mean(client_loras, weights)


def product_mean(client_loras: List[Params], weights: Weights = None) -> Params:
    """Ideal update per factor: Σwᵢ aᵢ @ bᵢ (full-rank tree; uniform default)."""
    k = len(client_loras)
    w = normalize_weights(weights, k)

    def fn(*factors):
        prods = (jnp.matmul(f["a"].astype(jnp.float32), f["b"].astype(jnp.float32))
                 for f in factors)
        if w is None:
            return sum(prods) / k
        return sum(wi * p for wi, p in zip(w, prods))

    return map_factors(fn, *client_loras)


def fedex_residual(client_loras: List[Params],
                   global_lora: Optional[Params] = None,
                   weights: Weights = None) -> Params:
    """ΔW_res = Σwᵢ aᵢbᵢ − ā b̄ per factor (Eq. 12; uniform wᵢ=1/k), f32."""
    if global_lora is None:
        global_lora = fedit_aggregate(client_loras, weights)
    k = len(client_loras)
    w = normalize_weights(weights, k)

    def fn(g, *factors):
        prods = (jnp.matmul(f["a"].astype(jnp.float32),
                            f["b"].astype(jnp.float32)) for f in factors)
        if w is None:
            mean_prod = sum(prods) / k
        else:
            mean_prod = sum(wi * p for wi, p in zip(w, prods))
        prod_mean = jnp.matmul(g["a"].astype(jnp.float32), g["b"].astype(jnp.float32))
        return mean_prod - prod_mean

    return map_factors(fn, global_lora, *client_loras)


def fedex_aggregate(client_loras: List[Params], weights: Weights = None
                    ) -> Tuple[Params, Params]:
    """Returns (global_lora, residual_tree). Eq. 11–12, weighted per §fedsrv."""
    global_lora = fedit_aggregate(client_loras, weights)
    residual = fedex_residual(client_loras, global_lora, weights)
    return global_lora, residual


def _factor_rank(tree: Params) -> int:
    """Rank r of the first {a, b} factor node found in an adapter tree."""
    found: List[int] = []

    def fn(factor):
        if not found:
            found.append(int(factor["a"].shape[-1]))
        return None

    map_factors(fn, tree)
    if not found:
        raise ValueError("no adapter factors found — empty lora tree?")
    return found[0]


def fedex_svd_aggregate(client_loras: List[Params], svd_rank: int,
                        weights: Weights = None) -> Tuple[Params, Params]:
    """FedEx with rank-r' truncated residual (Eq. 15–16, Eckart–Young optimal).

    ``svd_rank`` must satisfy 1 ≤ r' ≤ k·r (the residual's rank bound —
    ΔW_res = Σwᵢaᵢ(bᵢ − b̄) has at most k·r nonzero singular values).
    Anything outside raises: r' ≤ 0 used to silently truncate the residual
    to rank 0 (``u[:, :0]`` → an all-zero "residual" — an inexact close
    masquerading as FedEx), and r' > k·r silently transmitted pure padding.
    The config-level meaning of ``FedConfig.svd_rank = 0`` ("exact") is
    resolved by the CALLER to the plain fedex close, never down here.
    """
    k = len(client_loras)
    r = _factor_rank(client_loras[0])
    if svd_rank < 1:
        raise ValueError(
            f"fedex_svd_aggregate needs svd_rank ≥ 1, got {svd_rank} "
            "(svd_rank=0 means 'exact' at the config level — callers "
            "resolve that to fedex_aggregate, which never truncates)")
    if svd_rank > k * r:
        raise ValueError(
            f"svd_rank={svd_rank} exceeds the residual rank bound "
            f"k·r = {k}·{r} = {k * r}; ranks past it only pad the transmit")
    global_lora, residual = fedex_aggregate(client_loras, weights)

    def trunc(r):
        if r.ndim == 2:
            u, s, vt = jnp.linalg.svd(r, full_matrices=False)
            return (u[:, :svd_rank] * s[:svd_rank]) @ vt[:svd_rank]
        # stacked leading axes: vmap over them
        return jax.vmap(trunc)(r)

    residual_trunc = jax.tree.map(trunc, residual)
    return global_lora, residual_trunc


def ffa_aggregate(client_loras: List[Params], weights: Weights = None) -> Params:
    """FFA-LoRA: a is frozen (identical across clients) → average b only.
    Averaging a too is a no-op but keeps the code uniform; aggregation is
    exact (for any weights) because Σwᵢ a bᵢ = a Σwᵢbᵢ."""
    return tree_mean(client_loras, weights)


# --------------------------------------------------------------------------
# assignment strategies (Table 5)
# --------------------------------------------------------------------------

def assign_after_aggregation(
    strategy: str,
    client_loras: List[Params],
    rng: Optional[jax.Array] = None,
    weights: Weights = None,
) -> Tuple[List[Params], Params]:
    """Returns (per-client new adapters, residual to fold into W0).

    Every strategy is EXACT: residual is chosen so that for each client
    ``W0 + scale·(residual + aᵢ_new bᵢ_new) = W0 + scale·Σwⱼ aⱼbⱼ``.
    """
    k = len(client_loras)
    ideal = product_mean(client_loras, weights)

    if strategy == "average":  # FedEx-LoRA
        global_lora, residual = fedex_aggregate(client_loras, weights)
        return [global_lora] * k, residual

    if strategy == "keep_local":
        # clients keep their own adapters; per-client offset folded server-side.
        # A single SHARED residual keeps one global W0: we use the mean offset,
        # i.e. residual = mean(aᵢbᵢ) − mean over clients of their own product —
        # which is 0; instead the paper's variant gives each client
        # W0 + mean(ab) − aᵢbᵢ. We return per-client adapters and the mean
        # residual so the caller can apply per-client offsets where supported.
        # residual returned is for client 0's view; federated.py handles
        # per-client residuals for this strategy.
        return list(client_loras), per_client_residuals(client_loras, weights)[0]

    if strategy == "reinit":
        if rng is None:
            rng = jax.random.key(0)
        new = reinit_adapters(client_loras[0], rng)
        # b = 0 → product 0 → the FULL ideal update goes into the residual.
        return [new] * k, ideal

    raise ValueError(f"unknown assignment strategy {strategy!r}")


def reinit_adapters(template: Params, rng: jax.Array) -> Params:
    """Fresh adapters for the reinit strategy: a ~ N(0, 0.02), b = 0.

    The fold-in key is a stable per-leaf counter over the (deterministic,
    insertion-ordered) factor traversal — NOT hash(str(shape)), which varies
    across processes under PYTHONHASHSEED. Shared by
    :func:`assign_after_aggregation` and the engine's reinit close so both
    paths draw bitwise-identical adapters from the same rng.
    """
    counter = [0]

    def reinit(factor):
        counter[0] += 1
        a = jax.random.normal(
            jax.random.fold_in(rng, counter[0]),
            factor["a"].shape, jnp.float32) * 0.02
        return {"a": a, "b": jnp.zeros_like(factor["b"])}

    return map_factors(reinit, template)


def per_client_residuals(client_loras: List[Params],
                         weights: Weights = None) -> List[Params]:
    """keep_local residuals, EAGER ORACLE: residual_i = Σwⱼaⱼbⱼ − aᵢ bᵢ.

    One dense residual tree per client, materialised host-side — kept as the
    auditable ground truth for tests and the ``engine="off"`` path. The
    production keep_local close runs through ``core/engine.py`` (one jitted
    pass over (C_max, …)-stacked per-lane W0 buffers; the
    ``kernels/fedex_residual.perclient_fold`` kernel on TPU) and never
    builds this list.
    """
    ideal = product_mean(client_loras, weights)
    out = []
    for i in range(len(client_loras)):
        def fn(factor, ideal_leaf):
            own = jnp.matmul(factor["a"].astype(jnp.float32),
                             factor["b"].astype(jnp.float32))
            return ideal_leaf - own
        # walk is keyed on the FACTOR tree (first arg); the ideal tree has
        # plain array leaves at the factor positions.
        out.append(map_factors(fn, client_loras[i], ideal))
    return out


# --------------------------------------------------------------------------
# residual fold-in
# --------------------------------------------------------------------------

def apply_residual_fused(params: Params, client_loras: List[Params],
                         scale: float, *, weights: Weights = None,
                         interpret: Optional[bool] = None) -> Params:
    """W0 ← W0 + scale·ΔW_res via the Pallas fedex_residual kernel.

    The TPU path of Eq. 12+14: client factors stream through VMEM and the
    dense m×n residual is never materialised in HBM (kernels/fedex_residual).
    Semantically identical to ``apply_residual(params, fedex_residual(...))``
    — asserted by tests/test_kernels.py and test_federated.py. Accepts the
    same optional per-client ``weights`` as the jnp operators (the kernel's
    scalar-prefetch weighted path). NOTE: the round-close hot path no longer
    stacks lists here — core/engine.py streams deliveries into preallocated
    stacks and closes in one jitted program; this helper remains for one-shot
    folds over materialised client lists (examples, hetero adapters).
    """
    w = normalize_weights(weights, len(client_loras))
    wvec = None if w is None else jnp.asarray(w, jnp.float32)
    from repro.kernels import fedex_fold

    def walk(p: Any, nodes: List[Any]) -> Any:
        if _is_factor(nodes[0]):
            a_stack = jnp.stack([n["a"] for n in nodes])  # (C, ..., m, r)
            b_stack = jnp.stack([n["b"] for n in nodes])
            if a_stack.ndim > 3:  # stacked layers: move client axis inside
                perm = tuple(range(1, a_stack.ndim - 2)) + (0, a_stack.ndim - 2,
                                                            a_stack.ndim - 1)
                a_stack = a_stack.transpose(perm)
                b_stack = b_stack.transpose(perm)
            if isinstance(p, dict) and "kernel" in p:
                new_k = fedex_fold(p["kernel"], a_stack, b_stack, scale,
                                   weights=wvec, interpret=interpret)
                return dict(p, kernel=new_k.astype(p["kernel"].dtype))
            return (fedex_fold(p, a_stack, b_stack, scale, weights=wvec,
                               interpret=interpret)).astype(p.dtype)
        if isinstance(nodes[0], dict):
            out = dict(p) if isinstance(p, dict) else p
            for key in nodes[0]:
                if isinstance(p, dict) and key in p:
                    out[key] = walk(p[key], [n[key] for n in nodes])
            return out
        return p

    return walk(params, list(client_loras))


def apply_residual(params: Params, residual: Params, scale: float) -> Params:
    """W0 ← W0 + scale·ΔW_res at every adapted kernel (Eq. 14).

    ``residual`` mirrors the adapter-tree structure with dense ΔW leaves; the
    Pallas twin (kernels/fedex_residual) computes the same quantity fused and
    tiled on TPU — this is the jnp reference path.
    """

    def walk(p: Any, r: Any) -> Any:
        if r is None:
            return p
        if isinstance(p, dict):
            out = dict(p)
            for key, rv in r.items():
                if key not in p:
                    continue
                pv = p[key]
                if isinstance(rv, jnp.ndarray):
                    if isinstance(pv, dict) and "kernel" in pv:
                        out[key] = dict(pv, kernel=(pv["kernel"].astype(jnp.float32)
                                                    + scale * rv).astype(pv["kernel"].dtype))
                    else:  # raw tensor target (MoE experts)
                        out[key] = (pv.astype(jnp.float32) + scale * rv).astype(pv.dtype)
                elif isinstance(rv, dict):
                    out[key] = walk(pv, rv)
            return out
        return p

    return walk(params, residual)


