"""Communication accounting (paper §6, Table 6).

Counts parameters transmitted per aggregation round for each method, given the
set of adapted matrices. Uplink (clients → server) is identical for all LoRA
methods: k · Σ (m·r + r·n). Downlink differs:

* FedIT:      Σ (m·r + r·n) broadcast to k clients
* FFA-LoRA:   Σ (r·n) — only b (a frozen) [trainable side only]
* FedEx-LoRA: FedIT downlink + factored residual (rank ≤ (k+1)r; see
              core/decompose.py) — the "marginal overhead" of Table 6
* FedEx-SVD:  FedIT downlink + truncated rank-r' residual factors
* full FT:    Σ m·n both directions
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.decompose import factored_residual_params, truncated_residual_params


@dataclass(frozen=True)
class MatrixSpec:
    name: str
    m: int
    n: int


def adapted_matrices(cfg, lora_cfg) -> List[MatrixSpec]:
    """The matrices that carry adapters for a decoder-style config (per layer),
    expanded over layers. Attention q/k/v/o by default, MLP if configured."""
    hd = cfg.resolved_head_dim
    per_layer = [
        MatrixSpec("q_proj", cfg.d_model, cfg.num_heads * hd),
        MatrixSpec("k_proj", cfg.d_model, cfg.num_kv_heads * hd),
        MatrixSpec("v_proj", cfg.d_model, cfg.num_kv_heads * hd),
        MatrixSpec("o_proj", cfg.num_heads * hd, cfg.d_model),
    ]
    if lora_cfg.include_mlp and cfg.d_ff:
        per_layer += [
            MatrixSpec("up_proj", cfg.d_model, cfg.d_ff),
            MatrixSpec("gate_proj", cfg.d_model, cfg.d_ff),
            MatrixSpec("down_proj", cfg.d_ff, cfg.d_model),
        ]
    out = []
    for layer in range(cfg.num_layers):
        for ms in per_layer:
            out.append(MatrixSpec(f"layer{layer}/{ms.name}", ms.m, ms.n))
    return out


def participating_clients(k: int, participation_fraction: float,
                          min_clients: int = 1) -> int:
    """⌈fraction·k⌉ clamped to [min_clients, k] — matches fedsrv's round
    sampler (pass min_clients = the coordinator's min_quorum to stay aligned
    when the quorum floor exceeds the sampled fraction)."""
    if not 0.0 < participation_fraction <= 1.0:
        raise ValueError(f"participation_fraction must be in (0, 1], "
                         f"got {participation_fraction}")
    return min(k, max(1, min_clients, math.ceil(participation_fraction * k)))


def round_comm_params(method: str, mats: List[MatrixSpec], r: int, k: int,
                      svd_rank: int = 0,
                      participation_fraction: float = 1.0,
                      min_clients: int = 1,
                      participants: Optional[int] = None) -> Dict[str, int]:
    """Parameters communicated in ONE aggregation round.

    With partial participation only the k_p = ⌈fraction·k⌉ sampled clients
    exchange traffic, and the FedEx factored residual's rank bound tightens
    to (k_p+1)·r — the analytic twin of fedsrv's measured BytesLedger.

    ``participants`` pins k_p to an OBSERVED delivered-client count (dropout
    and deadline drops make the realized count differ from the ceil-fraction
    estimate) — this is what the obs layer passes when reconciling the
    measured ledger against this closed form.
    """
    if participants is not None:
        if not 1 <= participants <= k:
            raise ValueError(f"participants must be in [1, {k}], "
                             f"got {participants}")
        k_p = int(participants)
    else:
        k_p = participating_clients(k, participation_fraction, min_clients)
    adapters = sum(ms.m * r + r * ms.n for ms in mats)
    full = sum(ms.m * ms.n for ms in mats)

    if method == "full_ft":
        up = k_p * full
        down = k_p * full
    elif method == "fedit":
        up = k_p * adapters
        down = k_p * adapters
    elif method == "ffa":
        b_only = sum(r * ms.n for ms in mats)
        up = k_p * b_only
        down = k_p * b_only
    elif method == "fedex":
        up = k_p * adapters
        residual = sum(factored_residual_params(ms.m, ms.n, r, k_p) for ms in mats)
        down = k_p * (adapters + residual)
    elif method == "fedex_svd":
        up = k_p * adapters
        residual = sum(truncated_residual_params(ms.m, ms.n, svd_rank or r)
                       for ms in mats)
        down = k_p * (adapters + residual)
    else:
        raise ValueError(f"unknown method {method!r}")
    return {"uplink": up, "downlink": down, "total": up + down}


def comm_table(cfg, lora_cfg, k: int, rounds: int, svd_rank: int = 0,
               participation_fraction: float = 1.0
               ) -> Dict[str, Dict[str, float]]:
    """Table-6 style: per-method totals over ``rounds`` + ratio to FedEx."""
    mats = adapted_matrices(cfg, lora_cfg)
    methods = ["full_ft", "fedex", "fedit", "ffa", "fedex_svd"]
    totals = {m: rounds * round_comm_params(
        m, mats, lora_cfg.rank, k, svd_rank,
        participation_fraction=participation_fraction)["total"]
        for m in methods}
    base = totals["fedex"]
    return {m: {"params": totals[m], "ratio_to_fedex": totals[m] / base}
            for m in methods}
