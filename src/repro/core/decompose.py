"""Residual decomposition — the paper's communication protocol (§4.2).

``ΔW_res = mean_i(aᵢ bᵢ) − ā b̄`` has rank ≤ (k+1)·r by construction, so the
server NEVER ships the dense m×n matrix. Two codecs:

* ``residual_factors`` — exact factored form: concatenate the client factors
  into ``L: (m, (k+1)r)``, ``R: ((k+1)r, n)`` with ΔW_res = L @ R. This is the
  "Gram–Schmidt orthogonalisation" protocol of the paper, implemented as the
  cheaper QR-free concatenation (orthogonalising is only needed to REVEAL the
  rank; transmitting L, R is already rank-bounded and lossless).
* ``truncated_svd_product`` — rank-r' truncation computed WITHOUT forming the
  dense residual: QR of L (m×p, p = (k+1)r), SVD of the small (p × n) matrix
  R_q @ R. By Eckart–Young (Eq. 15–16) the result is the optimal rank-r'
  approximation. Cost O(m p² + p² n) instead of O(m n min(m,n)).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def residual_factors(client_factors: List[Params], weights=None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact low-rank factorisation of one matrix's residual.

    client_factors: list of {"a": (m, r), "b": (r, n)} (our layout: a=left).
    Returns (L (m, (k+1)r), R ((k+1)r, n)) with L @ R == ΔW_res. With
    non-uniform ``weights`` (fedsrv rounds) the same form stays lossless:
    ΔW_res = Σwᵢaᵢbᵢ − āb̄ with ā = Σwᵢaᵢ, so L carries wᵢ·aᵢ columns.
    """
    from repro.core.aggregation import normalize_weights

    k = len(client_factors)
    w = normalize_weights(weights, k)
    if w is None:
        w = [1.0 / k] * k
    a_bar = sum(wi * f["a"].astype(jnp.float32)
                for wi, f in zip(w, client_factors))
    b_bar = sum(wi * f["b"].astype(jnp.float32)
                for wi, f in zip(w, client_factors))
    lefts = [wi * f["a"].astype(jnp.float32)
             for wi, f in zip(w, client_factors)] + [-a_bar]
    rights = [f["b"].astype(jnp.float32) for f in client_factors] + [b_bar]
    L = jnp.concatenate(lefts, axis=-1)
    R = jnp.concatenate(rights, axis=-2)
    return L, R


def truncated_svd_product(L: jnp.ndarray, R: jnp.ndarray, rank: int
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Optimal rank-``rank`` approximation of ``L @ R`` without densifying.

    Returns (U (m, rank), s (rank,), Vt (rank, n)) with L@R ≈ U diag(s) Vt.
    """
    q, r_small = jnp.linalg.qr(L)          # q: (m, p), r_small: (p, p)
    mid = r_small @ R                      # (p, n)
    u_mid, s, vt = jnp.linalg.svd(mid, full_matrices=False)
    u = q @ u_mid
    return u[:, :rank], s[:rank], vt[:rank]


def reconstruct(u: jnp.ndarray, s: jnp.ndarray, vt: jnp.ndarray) -> jnp.ndarray:
    return (u * s) @ vt


def factored_residual_params(m: int, n: int, r: int, k: int) -> int:
    """Parameters transmitted for one matrix's exact factored residual."""
    p = (k + 1) * r
    return m * p + p * n


def truncated_residual_params(m: int, n: int, rank: int) -> int:
    return m * rank + rank + rank * n
