"""Deviation analysis (paper §6, Figures 2–9): scaled Frobenius norm of the
divergence between FedAvg-of-factors (FedIT) updates and ideal LoRA updates.

deviation(path) = ‖ mean_i(aᵢbᵢ) − ā b̄ ‖_F / sqrt(m·n)   (scaled by size)
relative(path) = ‖ mean_i(aᵢbᵢ) − ā b̄ ‖_F / ‖ mean_i(aᵢbᵢ) ‖_F

FedEx-LoRA's post-aggregation deviation is identically ZERO — asserted by the
property tests; FedIT's is positive, grows with local epochs, shrinks with
depth and over rounds (reproduced in benchmarks/divergence.py).
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import fedit_aggregate, map_factors

Params = Dict[str, Any]


def deviation_tree(client_loras: List[Params]) -> Params:
    """Per-factor dict of {"scaled": float, "relative": float, "fro": float}."""
    k = len(client_loras)
    global_lora = fedit_aggregate(client_loras)

    def fn(g, *factors):
        mean_prod = sum(jnp.matmul(f["a"].astype(jnp.float32),
                                   f["b"].astype(jnp.float32)) for f in factors) / k
        prod_mean = jnp.matmul(g["a"].astype(jnp.float32), g["b"].astype(jnp.float32))
        dev = mean_prod - prod_mean
        fro = jnp.sqrt(jnp.sum(jnp.square(dev), axis=(-2, -1)))
        size = dev.shape[-2] * dev.shape[-1]
        ideal_fro = jnp.sqrt(jnp.sum(jnp.square(mean_prod), axis=(-2, -1)))
        return {
            "fro": fro,
            "scaled": fro / np.sqrt(size),
            "relative": fro / jnp.maximum(ideal_fro, 1e-12),
        }

    return map_factors(fn, global_lora, *client_loras)


def flatten_deviations(dev_tree: Params, metric: str = "scaled") -> Dict[str, np.ndarray]:
    """path → value (stacked-layer leaves stay as arrays over the layer axis)."""
    from repro.util.tree import flatten_with_paths

    flat = flatten_with_paths(dev_tree)
    out = {}
    for path, val in flat.items():
        if path.endswith("/" + metric):
            out[path[: -len("/" + metric)]] = np.asarray(val)
    return out


def mean_deviation(client_loras: List[Params], metric: str = "scaled") -> float:
    dev = flatten_deviations(deviation_tree(client_loras), metric)
    vals = np.concatenate([np.atleast_1d(v).ravel() for v in dev.values()])
    return float(vals.mean())
