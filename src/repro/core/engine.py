"""Fused weighted round-close: the single-dispatch stacked-client engine.

The seed trainer closed a FedEx round with a Python tree-walk over *lists* of
client adapter trees: per-leaf ``jnp.stack`` at deadline, an eager op per
factor for the mean, an eager dense ΔW_res materialisation, an eager add into
W0 — dozens of dispatches per round, each a host↔device round trip. This
module replaces that with ONE jitted program over pre-stacked client buffers:

* :class:`RoundBuffers` — preallocated ``(C_max, …)`` device stacks per
  adapter leaf. The fedsrv transport decodes uplink payloads *into* a slot as
  each delivery arrives (streaming accumulation), so round close starts with
  the stack already resident — no burst of host→device copies at deadline.
  Slots are assigned in client-id order over the round's candidate set;
  non-delivered lanes simply keep zero weight (the participation mask).
* :func:`make_close_fn` / :class:`RoundCloseEngine` — the fused close: global
  factor means, the exact residual fold into W0, and the round's divergence
  metric, all inside one ``jax.jit`` with the W0 leaves and client stacks
  donated (``donate_argnums``) so XLA updates them in place. Stacked-layer
  leaves and MoE raw-tensor targets batch through the same program; the
  ``C_max`` padding means every round — any quorum, any weighting — reuses
  one compiled executable per (uniform?, shapes) signature.

Backends: ``jnp`` composes the operators of core/aggregation.py inside the
jit (the mathematical ground truth — on CPU XLA fuses the residual+fold so
nothing extra hits memory); ``pallas`` routes the fold through the
kernels/fedex_residual + kernels/factor_mean tiled kernels, which never
materialise the dense m×n residual in HBM (the TPU hot path). ``auto`` picks
pallas on TPU, jnp elsewhere.

Numerics contract: the uniform full-participation close is **bitwise
identical to the jitted composition** of ``fedex_aggregate`` +
``apply_residual`` (same op sequence, same XLA program). The historical
*eager* list path differs from any fused program by ≤2 ulp where XLA
contracts mul+add into FMA — asserted in tests/test_engine.py. Weighted and
ragged rounds hold the exact residual identity to tight float32 tolerance.

The divergence metric (paper §6) is computed WITHOUT materialising the dense
deviation: dev = Σu_c·a_c b_c − ā b̄ factors as L@R with L=[a_0…a_{C-1}, ā]
and R=[u_0 b_0; …; −b̄], and ‖L@R‖²_F = Σ_{ij} (LᵀL)_{ij}·(R Rᵀ)_{ij} — two
(C+1)r × (C+1)r Grams instead of an m×n deviation matrix. Cancellation in the
Gram sum gives this an absolute noise floor around 1e-6 when clients have
barely diverged (it is exact at any magnitude the §6 analysis cares about).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.util.tree import flatten_with_paths, unflatten_from_paths

Params = Dict[str, Any]

_CPU = jax.default_backend() == "cpu"


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        on_tpu = any(d.platform == "tpu" for d in jax.devices())
        return "pallas" if on_tpu else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown engine backend {backend!r}")
    return backend


# --------------------------------------------------------------------------
# factor specs: pair every lora {a, b} node with its W0 leaf in params
# --------------------------------------------------------------------------

class FactorSpec:
    """One adapted matrix: lora factor paths + the W0 leaf they update.

    ``key`` is the '/'-joined lora-tree path of the factor node; the W0 leaf
    lives at the same path in params, either as ``{key}/kernel`` (projection
    modules) or as a raw tensor (MoE expert stacks). Leading axes before the
    trailing (m, n) are scan-stacked layers / experts and batch through the
    engine unchanged.
    """

    def __init__(self, key: str, has_kernel: bool, w0_shape: Tuple[int, ...],
                 w0_dtype, a_shape: Tuple[int, ...], b_shape: Tuple[int, ...]):
        self.key = key
        self.has_kernel = has_kernel
        self.w0_shape = w0_shape
        self.w0_dtype = w0_dtype
        self.a_shape = a_shape
        self.b_shape = b_shape


def build_factor_specs(params: Params, lora: Params) -> List[FactorSpec]:
    """Walk the adapter tree against params, one spec per {a, b} node."""
    specs: List[FactorSpec] = []

    def walk(prefix: List[str], p: Any, l: Any) -> None:
        if isinstance(l, dict) and set(l.keys()) >= {"a", "b"}:
            key = "/".join(prefix)
            if isinstance(p, dict) and "kernel" in p:
                w0 = p["kernel"]
                has_kernel = True
            else:
                w0 = p  # raw tensor target (MoE experts)
                has_kernel = False
            specs.append(FactorSpec(key, has_kernel, tuple(w0.shape), w0.dtype,
                                    tuple(l["a"].shape), tuple(l["b"].shape)))
            return
        if isinstance(l, dict):
            for k in l:
                if isinstance(p, dict) and k in p:
                    walk(prefix + [k], p[k], l[k])

    walk([], params, lora)
    if not specs:
        raise ValueError("no adapter factors found — empty lora tree?")
    return specs


def _get_path(tree: Any, path: str) -> Any:
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _set_path(tree: Params, path: str, value: Any) -> Params:
    """Functional nested-dict update (copies only the spine)."""
    parts = path.split("/")
    out = dict(tree)
    node = out
    for p in parts[:-1]:
        node[p] = dict(node[p])
        node = node[p]
    node[parts[-1]] = value
    return out


# --------------------------------------------------------------------------
# streaming round buffers
# --------------------------------------------------------------------------

class RoundBuffers:
    """Preallocated ``(C_max, …)`` device stacks, written slot-by-slot.

    The coordinator assigns each round's candidate clients to slots (client-id
    order). On accelerators :meth:`write_flat` scatters one decoded payload
    into its lane via a single jitted ``dynamic_update_index_in_dim`` program
    with the stack buffers donated, so the update is in place — no copy of
    the full stack per arrival. On CPU XLA has no donation (the scatter would
    copy every stack per arrival), so arrivals stage into preallocated host
    numpy buffers — one O(leaf) slice-assign each — and ``take()`` pays a
    single host→device conversion per round, exactly the cost of the old
    per-leaf ``jnp.stack``. ``take()`` hands the stacks to the close program
    (which donates them as scratch); the next ``begin_round`` re-materialises
    zeros.
    """

    def __init__(self, lora_template: Params, c_max: int):
        if c_max < 1:
            raise ValueError("c_max must be ≥ 1")
        self.c_max = c_max
        flat = flatten_with_paths(lora_template)
        self._shapes = {p: tuple(x.shape) for p, x in flat.items()}
        self._host = _CPU
        self._stacks = None  # Dict[str, jnp.ndarray | np.ndarray]
        self._slots: Dict[int, int] = {}
        self._written: Dict[int, int] = {}
        if not self._host:
            @functools.partial(jax.jit, donate_argnums=(0,))
            def _scatter(stacks, slot, leaves):
                return {
                    p: jax.lax.dynamic_update_index_in_dim(
                        stacks[p], jnp.asarray(leaves[p], jnp.float32),
                        slot, 0)
                    for p in stacks
                }

            self._scatter = _scatter

    def _alloc(self):
        if self._host:
            return {p: np.zeros((self.c_max,) + s, np.float32)
                    for p, s in self._shapes.items()}
        return {p: jnp.zeros((self.c_max,) + s, jnp.float32)
                for p, s in self._shapes.items()}

    # -- round lifecycle ----------------------------------------------------
    def begin_round(self, slots: Dict[int, int]) -> None:
        """slots: client_id → lane, assigned over the round's candidate set."""
        if len(slots) > self.c_max:
            raise ValueError(f"{len(slots)} candidates > C_max={self.c_max}")
        if any(not 0 <= s < self.c_max for s in slots.values()):
            raise ValueError(f"slot out of range in {slots}")
        self._slots = dict(slots)
        self._written = {}
        if self._stacks is None:
            self._stacks = self._alloc()

    def write_flat(self, client_id: int, flat: Dict[str, Any]) -> None:
        """Scatter one client's decoded adapter leaves into its lane."""
        slot = self._slots[client_id]
        if self._host:
            for p in self._shapes:
                self._stacks[p][slot] = np.asarray(flat[p], np.float32)
        else:
            leaves = {p: flat[p] for p in self._shapes}
            self._stacks = self._scatter(self._stacks, jnp.int32(slot), leaves)
        self._written[client_id] = slot

    def write(self, client_id: int, lora_tree: Params) -> None:
        self.write_flat(client_id, flatten_with_paths(lora_tree))

    # -- views --------------------------------------------------------------
    @property
    def delivered(self) -> Dict[int, int]:
        """client_id → slot for every payload written this round."""
        return dict(self._written)

    def slot_of(self, client_id: int) -> int:
        return self._slots[client_id]

    def take(self) -> Dict[str, jnp.ndarray]:
        """Hand the stacks to the close program (donated there); reset."""
        stacks, self._stacks = self._stacks, None
        if stacks is None:
            raise RuntimeError("take() before begin_round/any writes")
        if self._host:  # one host→device conversion per round
            stacks = {p: jnp.asarray(x) for p, x in stacks.items()}
        return stacks


# --------------------------------------------------------------------------
# the fused close program
# --------------------------------------------------------------------------

def _dev_fro_scaled(a_stack: jnp.ndarray, b_stack: jnp.ndarray,
                    u: jnp.ndarray) -> jnp.ndarray:
    """Scaled Frobenius norm of Σu_c·a_c b_c − ā b̄ via the factored Grams —
    never materialises the (…, m, n) deviation. Returns (…,) per leading axes."""
    a = a_stack.astype(jnp.float32)  # (C, ..., m, r)
    b = b_stack.astype(jnp.float32)  # (C, ..., r, n)
    c = a.shape[0]
    abar = jnp.einsum("c,c...mr->...mr", u, a)
    bbar = jnp.einsum("c,c...rn->...rn", u, b)
    L = jnp.concatenate([a[i] for i in range(c)] + [abar], axis=-1)
    R = jnp.concatenate([u[i] * b[i] for i in range(c)] + [-bbar], axis=-2)
    gl = jnp.einsum("...mi,...mj->...ij", L, L)
    gr = jnp.einsum("...in,...jn->...ij", R, R)
    fro_sq = jnp.maximum(jnp.einsum("...ij,...ij->...", gl, gr), 0.0)
    m, n = a.shape[-2], b.shape[-1]
    return jnp.sqrt(fro_sq) / np.sqrt(m * n)


def _uniform_close(specs: Sequence[FactorSpec], scale: float,
                   w0_leaves: Dict[str, jnp.ndarray],
                   stacks: Dict[str, jnp.ndarray], c_max: int):
    """Full-participation uniform close — literally the aggregation operators
    over stack slices, so the jitted program is the jnp ground truth."""
    client_trees = [
        {s.key: {"a": stacks[s.key + "/a"][c], "b": stacks[s.key + "/b"][c]}
         for s in specs}
        for c in range(c_max)
    ]
    g = agg.fedit_aggregate(client_trees)
    res = agg.fedex_residual(client_trees, g)
    new_w0 = {
        s.key: (w0_leaves[s.key].astype(jnp.float32)
                + scale * res[s.key]).astype(s.w0_dtype)
        for s in specs
    }
    glob = {s.key: g[s.key] for s in specs}
    return new_w0, glob


def _weighted_close_jnp(specs: Sequence[FactorSpec], scale: float,
                        w0_leaves: Dict[str, jnp.ndarray],
                        stacks: Dict[str, jnp.ndarray],
                        w: jnp.ndarray, c_max: int):
    """Weighted/masked close, jnp twin: Σw_c a_c b_c − ā b̄ folded into W0.
    Zero-weight lanes vanish from every sum — the participation mask."""
    new_w0, glob = {}, {}
    for s in specs:
        a = stacks[s.key + "/a"]  # (C, ..., m, r) f32
        b = stacks[s.key + "/b"]
        ga = jnp.einsum("c,c...mr->...mr", w, a)
        gb = jnp.einsum("c,c...rn->...rn", w, b)
        mean_prod = jnp.einsum("c,c...mr,c...rn->...mn", w, a, b)
        res = mean_prod - jnp.matmul(ga, gb)
        new_w0[s.key] = (w0_leaves[s.key].astype(jnp.float32)
                         + scale * res).astype(s.w0_dtype)
        glob[s.key] = {"a": ga, "b": gb}
    return new_w0, glob


def _weighted_close_pallas(specs: Sequence[FactorSpec], scale: float,
                           w0_leaves: Dict[str, jnp.ndarray],
                           stacks: Dict[str, jnp.ndarray],
                           w: Optional[jnp.ndarray], interpret: Optional[bool]):
    """Fused-kernel close: factor means + residual fold through the tiled
    Pallas kernels — the dense m×n residual never exists in HBM."""
    from repro.kernels import factor_mean, fedex_fold

    new_w0, glob = {}, {}
    for s in specs:
        a = stacks[s.key + "/a"]  # (C, ..., m, r)
        b = stacks[s.key + "/b"]
        ga = factor_mean(a, w, interpret=interpret)
        gb = factor_mean(b, w, interpret=interpret)
        # kernel layout: leading layer axes first, client axis innermost
        am = jnp.moveaxis(a, 0, -3)
        bm = jnp.moveaxis(b, 0, -3)
        new_w0[s.key] = fedex_fold(
            w0_leaves[s.key], am, bm, scale, weights=w,
            interpret=interpret).astype(s.w0_dtype)
        glob[s.key] = {"a": ga, "b": gb}
    return new_w0, glob


def make_close_fn(specs: Sequence[FactorSpec], *, scale: float, c_max: int,
                  backend: str = "auto", interpret: Optional[bool] = None,
                  donate: bool = True):
    """Build the jitted close program.

    Signature: ``close(w0_leaves, stacks, weights, mask, uniform=...)`` →
    ``(new_w0_leaves, global_factors, divergence)`` with ``w0_leaves`` and
    ``stacks`` donated (in-place update; skipped on CPU where XLA has no
    donation support, or with ``donate=False`` for callers that replay the
    program on the same buffers, e.g. benchmarks). ``uniform=True`` is the
    static full-participation branch — bitwise twin of the jitted list path;
    otherwise ``weights`` is the (C_max,) vector with zeros masking
    non-delivered lanes and ``mask`` its 0/1 indicator (used for the
    uniform-over-delivered divergence).
    """
    backend = _resolve_backend(backend)
    specs = list(specs)

    def _close(w0_leaves, stacks, weights, mask, *, uniform: bool):
        if uniform:
            new_w0, glob = _uniform_close(specs, scale, w0_leaves, stacks,
                                          c_max)
            u = jnp.full((c_max,), 1.0 / c_max, jnp.float32)
        else:
            if backend == "pallas":
                new_w0, glob = _weighted_close_pallas(
                    specs, scale, w0_leaves, stacks, weights, interpret)
            else:
                new_w0, glob = _weighted_close_jnp(
                    specs, scale, w0_leaves, stacks, weights, c_max)
            u = mask / jnp.maximum(mask.sum(), 1.0)
        parts = [
            _dev_fro_scaled(stacks[s.key + "/a"], stacks[s.key + "/b"],
                            u).ravel()
            for s in specs
        ]
        div = jnp.concatenate(parts).mean() if parts else jnp.float32(0)
        return new_w0, glob, div

    donate_argnums = (0, 1) if donate and not _CPU else ()
    return jax.jit(_close, static_argnames=("uniform",),
                   donate_argnums=donate_argnums)


class RoundCloseEngine:
    """Owns the streaming buffers + the compiled close program for a trainer.

    One engine per (params structure, adapter structure, C_max, scale):
    ``buffers`` is handed to the fedsrv coordinator as the delivery sink, and
    :meth:`close` runs the single-dispatch fused close over whatever subset
    actually arrived, with any weighting. The C_max padding contract: stacks
    are always ``(C_max, …)``; a round's candidates get lanes in client-id
    order; weights (zeros on non-delivered lanes) mask the rest — so ragged
    quorums and weighted rounds reuse ONE compiled program, and the uniform
    full-participation round keeps its own bitwise-stable branch.
    """

    def __init__(self, params: Params, lora_template: Params, *,
                 c_max: int, scale: float, backend: str = "auto",
                 interpret: Optional[bool] = None, donate: bool = True):
        self.specs = build_factor_specs(params, lora_template)
        self.c_max = c_max
        self.scale = scale
        self.backend = _resolve_backend(backend)
        self.buffers = RoundBuffers(lora_template, c_max)
        self._close = make_close_fn(self.specs, scale=scale, c_max=c_max,
                                    backend=self.backend, interpret=interpret,
                                    donate=donate)

    # ------------------------------------------------------------------
    def weight_vector(self, client_ids: Sequence[int],
                      weights: Optional[Sequence[float]]
                      ) -> Tuple[np.ndarray, np.ndarray, bool]:
        """(C_max,) weights + mask from the delivered ids; uniform? flag."""
        slots = [self.buffers.slot_of(cid) for cid in client_ids]
        mask = np.zeros(self.c_max, np.float32)
        mask[slots] = 1.0
        norm = agg.normalize_weights(weights, len(client_ids))
        uniform = norm is None and len(client_ids) == self.c_max
        w = np.zeros(self.c_max, np.float32)
        if norm is None:
            w[slots] = 1.0 / len(client_ids)
        else:
            for s, wi in zip(slots, norm):
                w[s] = wi
        return w, mask, uniform

    def close(self, params: Params, client_ids: Sequence[int],
              weights: Optional[Sequence[float]] = None
              ) -> Tuple[Params, Params, float]:
        """Close the round over the delivered subset.

        Returns ``(global_lora, new_params, divergence)``. ``params`` W0
        leaves and the streamed stacks are donated to the close program.
        """
        if not client_ids:
            raise ValueError("cannot close a round with no deliveries")
        missing = [c for c in client_ids if c not in self.buffers.delivered]
        if missing:
            raise ValueError(f"clients {missing} were never written to the "
                             "round buffers")
        w, mask, uniform = self.weight_vector(client_ids, weights)
        w0_leaves = {
            s.key: (_get_path(params, s.key)["kernel"] if s.has_kernel
                    else _get_path(params, s.key))
            for s in self.specs
        }
        stacks = self.buffers.take()
        new_w0, glob, div = self._close(w0_leaves, stacks,
                                        jnp.asarray(w), jnp.asarray(mask),
                                        uniform=uniform)
        new_params = params
        for s in self.specs:
            if s.has_kernel:
                node = dict(_get_path(params, s.key), kernel=new_w0[s.key])
                new_params = _set_path(new_params, s.key, node)
            else:
                new_params = _set_path(new_params, s.key, new_w0[s.key])
        flat = {}
        for s in self.specs:
            flat[s.key + "/a"] = glob[s.key]["a"]
            flat[s.key + "/b"] = glob[s.key]["b"]
        global_lora = unflatten_from_paths(flat)
        return global_lora, new_params, float(div)
