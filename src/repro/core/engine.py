"""Fused weighted round-close: the single-dispatch stacked-client engine.

The seed trainer closed a FedEx round with a Python tree-walk over *lists* of
client adapter trees: per-leaf ``jnp.stack`` at deadline, an eager op per
factor for the mean, an eager dense ΔW_res materialisation, an eager add into
W0 — dozens of dispatches per round, each a host↔device round trip. This
module replaces that with ONE jitted program over pre-stacked client buffers,
for EVERY aggregation variant the paper studies:

* :class:`RoundBuffers` — preallocated ``(C_max, …)`` device stacks per
  adapter leaf, DOUBLE-BUFFERED: a ring of ``depth`` rotating stack sets lets
  the fedsrv transport stream round N+1 uplinks into a fresh set while round
  N's close (which owns — and donates — the previous set) is still in
  flight. Rotation rules: ``begin_round`` opens a new set (fresh zeros — the
  close program consumed the previous allocation via donation, so sets are
  never reused across rounds), ``write_flat`` routes a delivery to its
  round's set by the payload's ``round_id``, ``take`` pops the OLDEST open
  round and hands its stacks to the close program. At most ``depth`` rounds
  may be open; exceeding it is an error, not a silent overwrite — UNLESS the
  caller gave open rounds a ``deadline`` and passes ``now`` when opening the
  next one: expired rounds are then EVICTED (dropped with a warning, late
  uplinks for them discarded) instead of wedging the ring. ``depth > 2``
  plus per-round deadlines is the FedBuff regime: commits lagging
  ``max_version_lag`` or more versions are evicted rather than blocking new
  rounds.
* :class:`DeferredDivergence` — the §6 divergence metric leaves the close as
  a DEVICE scalar; the host sync (``float(...)``, a blocking device→host
  transfer) happens only when the caller resolves the handle, which the
  trainer does at the NEXT round boundary. Dispatching the close therefore
  returns immediately, and the ring's round-N+1 uplink decoding genuinely
  overlaps the round-N close on accelerators. The handle quacks like a float
  (comparisons, arithmetic, ``np.asarray``) — any numeric use resolves it.
* :func:`make_close_fn` / :class:`RoundCloseEngine` — the fused close for all
  engine methods, each one jitted program with W0 leaves and client stacks
  donated (``donate_argnums``) so XLA updates them in place:

  - ``fedex`` — weighted factor means + the exact residual fold (Eq. 11–14).
  - ``fedex_svd`` — the rank-r' truncated close (Eq. 15–16): the
    Eckart–Young-optimal truncation is computed from the STACKED FACTORS via
    two (C·r)×(C·r) Gram eigendecompositions plus one small SVD
    (:func:`factored_truncated_residual`) — the dense m×n residual that the
    eager ``fedex_svd_aggregate`` hands to ``jnp.linalg.svd`` never exists.
  - ``reinit`` (§6 Table 5) — the full ideal update Σw_c·a_c b_c folds into
    W0 (the signed product kernel); fresh adapters are drawn host-side with
    the same deterministic fold-in as ``aggregation.reinit_adapters``.
  - ``keep_local`` (§6 Table 5) — per-client residuals Σw_j·a_j b_j − a_i b_i
    fold into every delivered client's OWN W0 in one pass over stacked
    per-lane W0 buffers (the per-client kernel: per-lane sign vectors
    w − e_i without C separate passes).

Backends: ``jnp`` composes the operators of core/aggregation.py inside the
jit (the mathematical ground truth — on CPU XLA fuses the residual+fold so
nothing extra hits memory); ``pallas`` routes the folds through the
kernels/fedex_residual (+ product/per-client variants) and kernels/factor_mean
tiled kernels, which never materialise a dense m×n residual in HBM (the TPU
hot path). ``auto`` picks pallas on TPU, jnp elsewhere. The svd close's small
Gram eigendecomposition/SVD stays in jnp on EITHER backend (LAPACK / XLA
custom calls on (C·r)×(C·r) matrices — there is nothing to tile); only its
rank-r' fold goes through the product kernel on pallas.

Numerics contract: the uniform full-participation ``fedex`` / ``reinit`` /
``keep_local`` closes are **bitwise identical to the jitted composition** of
the core/aggregation.py operators (same op sequence, same XLA program). The
historical *eager* list path differs from any fused program by ≤2 ulp where
XLA contracts mul+add into FMA — asserted in tests/test_engine.py. Weighted
and ragged rounds hold the exact residual identity to tight float32
tolerance. The ``fedex_svd`` close matches the dense Eckart–Young oracle to
~1e-5 relative (Gram squaring halves the attainable precision; documented
and asserted in tests/test_engine_methods.py).

The divergence metric (paper §6) is computed WITHOUT materialising the dense
deviation: dev = Σu_c·a_c b_c − ā b̄ = Σ_c u_c·a_c (b_c − b̄) factors as L@R
with L = [u_0·a_0 … u_{C-1}·a_{C-1}] and R = [b_0 − b̄; …], and ‖L@R‖²_F =
Σ_{ij} (LᵀL)_{ij}·(R Rᵀ)_{ij} — two C·r × C·r Grams instead of an m×n
deviation matrix. The same factorisation feeds the svd close. Cancellation in
the Gram sum gives the metric an absolute noise floor around 1e-6 when
clients have barely diverged (it is exact at any magnitude the §6 analysis
cares about).
"""

from __future__ import annotations

import functools
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.obs import NULL
from repro.util.logging import get_logger
from repro.util.tree import flatten_with_paths, unflatten_from_paths

logger = get_logger("engine")

Params = Dict[str, Any]

_CPU = jax.default_backend() == "cpu"

ENGINE_METHODS = ("fedex", "fedex_svd", "reinit", "keep_local", "hetero")


class DeferredDivergence:
    """§6 divergence as a device scalar with the host sync deferred.

    The close program computes the divergence on device; wrapping it here
    instead of calling ``float()`` keeps the close dispatch ASYNCHRONOUS —
    the trainer resolves the handle at the next round boundary, so the
    round-N close overlaps round-N+1 uplink decoding (the whole point of the
    :class:`RoundBuffers` ring). Any numeric use (comparison, arithmetic,
    ``np.asarray``, ``float``) resolves the handle — i.e. blocks on the
    device value — and caches the result.
    """

    __slots__ = ("_raw", "_value", "round_id", "_recorder")

    def __init__(self, raw, round_id=None, recorder=None):
        self._raw = raw
        self._value: Optional[float] = None
        self.round_id = round_id
        # obs: resolution is the close's block-until-ready — record it as its
        # own span so a premature host sync is visible in the trace
        self._recorder = recorder

    @property
    def resolved(self) -> bool:
        """True once the host sync has happened (no device value pending)."""
        return self._value is not None

    @property
    def raw(self):
        """The unresolved device scalar (None after resolution)."""
        return self._raw

    def resolve(self) -> float:
        """Block on the device value (the ONLY host sync) and cache it."""
        if self._value is None:
            rec = self._recorder
            if rec is not None and rec.enabled:
                t0 = time.perf_counter_ns()
                with rec.span("divergence.resolve", cat="engine",
                              round=self.round_id):
                    self._value = float(self._raw)
                block_us = (time.perf_counter_ns() - t0) / 1e3
                rec.hist("engine.close_block_us").observe(block_us)
                if self.round_id is not None:
                    rec.round_set(self.round_id,
                                  close_block_us=round(block_us, 1),
                                  divergence=self._value)
            else:
                self._value = float(self._raw)
            self._raw = None  # drop the device reference
        return self._value

    # -- float duck-typing: any numeric use resolves ------------------------
    def __float__(self) -> float:
        return self.resolve()

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self.resolve(), dtype=dtype)

    def __lt__(self, other):
        return self.resolve() < other

    def __le__(self, other):
        return self.resolve() <= other

    def __gt__(self, other):
        return self.resolve() > other

    def __ge__(self, other):
        return self.resolve() >= other

    def __eq__(self, other):
        if isinstance(other, DeferredDivergence):
            return self.resolve() == other.resolve()
        return self.resolve() == other

    def __ne__(self, other):
        return not self.__eq__(other)

    __hash__ = None  # mutable (resolution caches); never a dict key

    def __abs__(self):
        return abs(self.resolve())

    def __sub__(self, other):
        return self.resolve() - other

    def __rsub__(self, other):
        return other - self.resolve()

    def __add__(self, other):
        return self.resolve() + other

    __radd__ = __add__

    def __mul__(self, other):
        return self.resolve() * other

    __rmul__ = __mul__

    def __truediv__(self, other):
        return self.resolve() / other

    def __rtruediv__(self, other):
        return other / self.resolve()

    def __format__(self, spec):
        return format(self.resolve(), spec)

    def __repr__(self) -> str:
        if self.resolved:
            return f"DeferredDivergence({self._value!r}, resolved)"
        return f"DeferredDivergence(<device scalar>, round_id={self.round_id!r})"


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        on_tpu = any(d.platform == "tpu" for d in jax.devices())
        return "pallas" if on_tpu else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown engine backend {backend!r}")
    return backend


def _tree_bytes(tree: Any) -> int:
    """Total array bytes of a (nested) container of array leaves."""
    return sum(int(getattr(x, "nbytes", 0)) for x in jax.tree.leaves(tree))


class _ProgramCache:
    """Bounded LRU of the engine's jitted close/fold programs.

    The stacked engine held exactly one program; the chunked mode multiplies
    signatures (partial fold, per-method finalize, keep_local per-chunk fold,
    the svd Gram/core/projection programs) and long-lived processes that
    rebuild engines would otherwise grow the population without bound.
    Eviction drops the least-recently-used program — it recompiles on next
    use, so correctness is unaffected — and is observable: the
    ``engine.compile_cache_size`` gauge tracks the population and the
    ``close.compile_evicted`` counter every eviction.
    """

    def __init__(self, cap: int = 16):
        if cap < 1:
            raise ValueError(f"program cache cap must be ≥ 1, got {cap}")
        self.cap = cap
        self.evictions = 0
        self._programs: "OrderedDict[Any, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._programs)

    def __contains__(self, key) -> bool:
        return key in self._programs

    def get(self, key, build, rec=NULL):
        """Return the cached program for ``key``, building (and possibly
        evicting the LRU entry) on a miss."""
        prog = self._programs.get(key)
        if prog is None:
            prog = build()
            self._programs[key] = prog
            while len(self._programs) > self.cap:
                old, _ = self._programs.popitem(last=False)
                self.evictions += 1
                if rec.enabled:
                    rec.counter("close.compile_evicted").inc()
                logger.info("evicted close program %r (cache cap %d)",
                            old, self.cap)
        else:
            self._programs.move_to_end(key)
        if rec.enabled:
            rec.gauge("engine.compile_cache_size").set(len(self._programs))
        return prog


# --------------------------------------------------------------------------
# factor specs: pair every lora {a, b} node with its W0 leaf in params
# --------------------------------------------------------------------------

class FactorSpec:
    """One adapted matrix: lora factor paths + the W0 leaf they update.

    ``key`` is the '/'-joined lora-tree path of the factor node; the W0 leaf
    lives at the same path in params, either as ``{key}/kernel`` (projection
    modules) or as a raw tensor (MoE expert stacks). Leading axes before the
    trailing (m, n) are scan-stacked layers / experts and batch through the
    engine unchanged.
    """

    def __init__(self, key: str, has_kernel: bool, w0_shape: Tuple[int, ...],
                 w0_dtype, a_shape: Tuple[int, ...], b_shape: Tuple[int, ...]):
        self.key = key
        self.has_kernel = has_kernel
        self.w0_shape = w0_shape
        self.w0_dtype = w0_dtype
        self.a_shape = a_shape
        self.b_shape = b_shape


def build_factor_specs(params: Params, lora: Params) -> List[FactorSpec]:
    """Walk the adapter tree against params, one spec per {a, b} node."""
    specs: List[FactorSpec] = []

    def walk(prefix: List[str], p: Any, l: Any) -> None:
        if isinstance(l, dict) and set(l.keys()) >= {"a", "b"}:
            key = "/".join(prefix)
            if isinstance(p, dict) and "kernel" in p:
                w0 = p["kernel"]
                has_kernel = True
            else:
                w0 = p  # raw tensor target (MoE experts)
                has_kernel = False
            specs.append(FactorSpec(key, has_kernel, tuple(w0.shape), w0.dtype,
                                    tuple(l["a"].shape), tuple(l["b"].shape)))
            return
        if isinstance(l, dict):
            for k in l:
                if isinstance(p, dict) and k in p:
                    walk(prefix + [k], p[k], l[k])

    walk([], params, lora)
    if not specs:
        raise ValueError("no adapter factors found — empty lora tree?")
    return specs


def _get_path(tree: Any, path: str) -> Any:
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _set_path(tree: Params, path: str, value: Any) -> Params:
    """Functional nested-dict update (copies only the spine)."""
    parts = path.split("/")
    out = dict(tree)
    node = out
    for p in parts[:-1]:
        node[p] = dict(node[p])
        node = node[p]
    node[parts[-1]] = value
    return out


def collect_w0_leaves(specs: Sequence[FactorSpec],
                      params: Params) -> Dict[str, jnp.ndarray]:
    """key → adapted W0 leaf (the ``kernel`` child for projection modules,
    the raw tensor for MoE expert stacks). Shared by the streaming engine and
    the mesh-mode closer (launch/mesh_train.py)."""
    return {
        s.key: (_get_path(params, s.key)["kernel"] if s.has_kernel
                else _get_path(params, s.key))
        for s in specs
    }


def fold_back_w0(specs: Sequence[FactorSpec], params: Params,
                 new_w0: Dict[str, jnp.ndarray]) -> Params:
    """Write the close's updated W0 leaves back into the params tree
    (functional spine-copy update). Inverse of :func:`collect_w0_leaves`."""
    new_params = params
    for s in specs:
        if s.has_kernel:
            node = dict(_get_path(params, s.key), kernel=new_w0[s.key])
            new_params = _set_path(new_params, s.key, node)
        else:
            new_params = _set_path(new_params, s.key, new_w0[s.key])
    return new_params


# --------------------------------------------------------------------------
# streaming round buffers (double-buffered ring)
# --------------------------------------------------------------------------

def _ring_locked(fn):
    """Serialise a RoundBuffers method on the ring's RLock. The HTTP
    federation service (fedsrv/server.py) decodes uplinks on ThreadingHTTP-
    Server worker threads, so ``write_flat`` races ``begin_round``/
    ``evict``/``take`` — decode and validation stay parallel (they happen in
    the codec, before the ring is touched); only the scatter and the round
    bookkeeping serialise. Re-entrant: ``begin_round`` evicts under its own
    lock, and single-threaded callers (the sim coordinators) pay one
    uncontended acquire per call."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._ring_lock:
            return fn(self, *args, **kwargs)
    return wrapper


class RoundBuffers:
    """Preallocated ``(C_max, …)`` device stacks, written slot-by-slot, with a
    ``depth``-deep ring of rotating stack sets.

    The coordinator assigns each round's candidate clients to slots (client-id
    order) via :meth:`begin_round`; deliveries scatter into their round's set
    via :meth:`write_flat` (the transport passes the payload's ``round_id``
    so round N+1 uplinks can stream while round N's set awaits — or is being
    consumed by — its close); :meth:`take` pops the OLDEST open round (FIFO)
    and hands its stacks to the close program.

    Rotation / donation-safety rules:

    * every ``begin_round`` allocates a FRESH zero set — the close program
      donates (consumes) the set ``take`` handed it, so a set is never reused
      across rounds and an in-flight close can never see the next round's
      writes;
    * at most ``depth`` rounds may be open at once; opening more raises
      (never silently overwrites an un-closed round's data) — unless expired
      rounds can be evicted first, see below;
    * within a round, slot lanes are written at most once per client and
      non-delivered lanes simply stay zero (the weight mask handles them).

    Per-round deadlines / eviction (the ``depth > 2`` FedBuff regime): a
    round may be opened with a ``deadline`` on whatever monotonic scale its
    coordinator uses (sim-seconds for the sync coordinator, commit VERSIONS
    for FedBuff). When a ``begin_round`` with ``now=...`` finds all ``depth``
    sets in flight, open rounds whose deadline has passed (``deadline ≤
    now``) are EVICTED — their stacks dropped with a warning — instead of
    wedging the ring; a commit lagging ``max_version_lag`` or more versions
    behind is abandoned, not waited on. Uplinks that later arrive for an
    evicted round are discarded (``write_flat`` returns ``False``), never
    scattered into a live round's lanes. Rounds without a deadline are never
    evicted implicitly; :meth:`evict` drops one explicitly.

    On accelerators :meth:`write_flat` scatters one decoded payload into its
    lane via a single jitted ``dynamic_update_index_in_dim`` program with the
    stack buffers donated, so the update is in place — no copy of the full
    stack per arrival. On CPU XLA has no donation (the scatter would copy
    every stack per arrival), so arrivals stage into preallocated host numpy
    buffers — one O(leaf) slice-assign each — and ``take()`` pays a single
    host→device conversion per round, exactly the cost of the old per-leaf
    ``jnp.stack``.
    """

    def __init__(self, lora_template: Params, c_max: int, depth: int = 2,
                 recorder=None, *, chunk: int = 0, on_chunk=None,
                 retain_chunks: bool = False):
        if c_max < 1:
            raise ValueError("c_max must be ≥ 1")
        if depth < 1:
            raise ValueError("depth must be ≥ 1")
        if chunk < 0:
            raise ValueError(f"chunk must be ≥ 0, got {chunk}")
        if chunk > 0 and on_chunk is None:
            raise ValueError("a chunked ring needs an on_chunk fold callback")
        self.c_max = c_max
        self.depth = depth
        # chunked streaming mode: rounds with more than ``chunk`` candidate
        # lanes stage uplinks in (chunk, …) host buffers; each chunk that
        # fills (and is next in SLOT order) is eagerly folded into a running
        # accumulator via ``on_chunk(acc, chunk_stacks, raw_weights, rid, k)``
        # while later uplinks keep streaming. Determinism rule: chunk k never
        # folds before chunks < k, so the fold sequence is a pure function of
        # the slot assignment — never of uplink arrival order — and two runs
        # (or a crash twin) produce bitwise-identical accumulators.
        # ``retain_chunks`` keeps folded chunks' host buffers (keep_local and
        # fedex_svd closes re-stream them); rounds that fit in one chunk take
        # the classic stacked path so small rounds keep the stacked bitwise
        # contract ("auto" semantics of FedConfig.close_chunk).
        self.chunk = chunk
        self.on_chunk = on_chunk
        self.retain_chunks = retain_chunks
        self.partial_folds = 0  # eager (mid-round) chunk folds, all rounds
        self.rec = recorder if recorder is not None else NULL
        flat = flatten_with_paths(lora_template)
        self._shapes = {p: tuple(x.shape) for p, x in flat.items()}
        self._host = _CPU
        # round_id → {"slots": cid→lane, "written": cid→lane, "stacks": dict,
        #             "deadline": Optional[float]}
        self._open: "OrderedDict[Any, Dict[str, Any]]" = OrderedDict()
        # recently evicted round ids (bounded): late uplinks for them are
        # dropped silently instead of raising as unroutable
        self._evicted: "OrderedDict[Any, Any]" = OrderedDict()
        # recently CLOSED (taken) round ids (bounded): a replayed uplink for
        # a round whose close already consumed its set is dropped, not a
        # KeyError — the ring remembers where the round went
        self._closed: "OrderedDict[Any, Any]" = OrderedDict()
        self.evictions = 0
        self.stale_drops = 0  # uplinks discarded for already-evicted rounds
        self.replay_drops = 0  # uplinks replayed for already-closed rounds
        self.duplicate_drops = 0  # second (client, round) write, same lane
        self._auto = 0
        # threaded ingest (fedsrv/server.py): see _ring_locked
        self._ring_lock = threading.RLock()
        if not self._host:
            @functools.partial(jax.jit, donate_argnums=(0,))
            def _scatter(stacks, slot, leaves):
                return {
                    p: jax.lax.dynamic_update_index_in_dim(
                        stacks[p], jnp.asarray(leaves[p], jnp.float32),
                        slot, 0)
                    for p in stacks
                }

            self._scatter = _scatter

    def _alloc(self):
        if self._host:
            return {p: np.zeros((self.c_max,) + s, np.float32)
                    for p, s in self._shapes.items()}
        return {p: jnp.zeros((self.c_max,) + s, jnp.float32)
                for p, s in self._shapes.items()}

    def _alloc_chunk(self):
        # chunk staging is ALWAYS host numpy (every backend): the eager fold
        # pays one host→device conversion per chunk, and partially written
        # chunks stay cheaply checkpointable (state_dict slices them out
        # without a device sync)
        return {p: np.zeros((self.chunk,) + s, np.float32)
                for p, s in self._shapes.items()}

    def _entry(self, round_id=None) -> Tuple[Any, Dict[str, Any]]:
        if not self._open:
            raise RuntimeError("no open round — begin_round() first")
        if round_id is None:
            rid = next(iter(self._open))
            return rid, self._open[rid]
        if round_id not in self._open:
            raise KeyError(f"round {round_id!r} is not open "
                           f"(open: {list(self._open)})")
        return round_id, self._open[round_id]

    # -- round lifecycle ----------------------------------------------------
    @_ring_locked
    def begin_round(self, slots: Dict[int, int], round_id=None, *,
                    deadline: Optional[float] = None,
                    now: Optional[float] = None):
        """Open a new round: ``slots`` maps client_id → lane over the round's
        candidate set. Returns the round id (auto-assigned when omitted).

        ``deadline`` (optional) marks when this round becomes evictable, on
        the caller's monotonic scale (sim-time / commit version); ``now`` is
        the current value on that scale. A full ring first evicts expired
        rounds (``deadline ≤ now``) before giving up; without ``now`` (or
        with nothing expired) a full ring still raises."""
        if len(slots) > self.c_max:
            raise ValueError(f"{len(slots)} candidates > C_max={self.c_max}")
        if any(not 0 <= s < self.c_max for s in slots.values()):
            raise ValueError(f"slot out of range in {slots}")
        if round_id is None:
            round_id = f"_auto{self._auto}"
            self._auto += 1
        if round_id in self._open:
            raise ValueError(f"round {round_id!r} is already open")
        # ring wrap: a caller legitimately reusing an old id (e.g. a round
        # counter that wrapped) gets a FRESH round — forget the stale
        # closed/evicted memory so its uplinks route to the new set. A
        # replayed uplink racing this begin_round is only droppable BEFORE
        # the id is reopened; afterwards the id names the live round again.
        self._evicted.pop(round_id, None)
        self._closed.pop(round_id, None)
        if len(self._open) >= self.depth and now is not None:
            for rid in [r for r, e in self._open.items()
                        if e["deadline"] is not None and e["deadline"] <= now]:
                self.evict(rid, reason=f"deadline {self._open[rid]['deadline']}"
                                       f" ≤ now {now}")
        if len(self._open) >= self.depth:
            raise RuntimeError(
                f"all {self.depth} buffer sets are in flight (open rounds: "
                f"{list(self._open)}) — take() the oldest before opening "
                "another, or give open rounds a deadline so a full ring can "
                "evict them")
        # "auto" chunking contract: a round whose candidate set fits in one
        # chunk takes the classic stacked path (same program, same bitwise
        # behaviour as a chunk=0 ring); larger rounds stream in chunks
        chunked = 0 < self.chunk < len(slots)
        entry: Dict[str, Any] = {"slots": dict(slots), "written": {},
                                 "deadline": deadline, "chunked": chunked}
        if chunked:
            num_chunks = max(slots.values()) // self.chunk + 1
            expected = [0] * num_chunks
            for s in slots.values():
                expected[s // self.chunk] += 1
            entry.update(
                stacks=None, chunks={}, retained={}, acc=None,
                w=np.zeros(num_chunks * self.chunk, np.float32),
                # per-slot TRUE adapter ranks (hetero rounds): −1 = full rank
                # (unmasked — the non-hetero default), set by write_flat's
                # ``rank`` and snapshotted with the round state so a resumed
                # twin replays the same masked folds
                ranks=np.full(num_chunks * self.chunk, -1, np.int32),
                next_chunk=0, num_chunks=num_chunks, expected=expected,
                filled=[0] * num_chunks, eager_folds=0)
        else:
            entry["stacks"] = self._alloc()
        self._open[round_id] = entry
        if self.rec.enabled:
            self.rec.event("ring.begin", cat="ring", round=round_id,
                           lanes=len(slots), deadline=deadline,
                           chunked=chunked)
            self.rec.gauge("ring.occupancy").set(len(self._open))
        return round_id

    @_ring_locked
    def evict(self, round_id, reason: str = "explicit") -> Dict[int, int]:
        """Drop an open round WITHOUT closing it: its stacks are discarded and
        any late uplink for it will be dropped (not an error). Returns the
        evicted round's delivered {client_id: lane} map for accounting."""
        rid, e = self._entry(round_id)
        del self._open[rid]
        self._evicted[rid] = reason
        while len(self._evicted) > 64:  # bounded memory of evicted ids
            self._evicted.popitem(last=False)
        self.evictions += 1
        if self.rec.enabled:
            self.rec.counter("ring.evictions").inc()
            self.rec.event("ring.evict", cat="ring", round=rid, reason=reason,
                           delivered=len(e["written"]), lanes=len(e["slots"]))
            self.rec.gauge("ring.occupancy").set(len(self._open))
        logger.warning("evicted round %r (%s): %d/%d lanes delivered — "
                       "its uplinks are discarded", rid, reason,
                       len(e["written"]), len(e["slots"]))
        return dict(e["written"])

    @_ring_locked
    def write_flat(self, client_id: int, flat: Dict[str, Any],
                   round_id=None, *, weight: Optional[float] = None,
                   rank: Optional[int] = None) -> bool:
        """Scatter one client's decoded adapter leaves into its lane.

        ``round_id=None`` routes to the oldest open round that has a lane for
        this client (single-open callers never need to pass it). Returns
        ``True`` when the write landed; a write addressed to an EVICTED round
        is dropped (returns ``False``) — the uplink lost its race against the
        ring's deadline and must not scatter into a live round's lanes.
        The eviction-drop guarantee needs the EXPLICIT ``round_id``: with
        ``None`` there is no payload identity to check against the evicted
        set, so a late uplink could land in a newer open round that also has
        a lane for this client. Any caller that evicts (the coordinators, via
        ``decode_into``) must route by the payload's round_id — they do.

        ``weight`` is this uplink's RAW (unnormalised) aggregation weight —
        chunked rounds fold it into the running accumulators at ingest, so
        the caller must stream the same weighting it will close with (the
        close cross-checks and raises on a mismatch). Defaults to 1.0
        (uniform); stacked rounds ignore it (they weight at close time).

        ``rank`` is this uplink's TRUE adapter rank (hetero rounds stream
        rank-rᵢ payloads zero-padded to the template r_max): chunked rounds
        record it per slot so the eager partial folds mask the padded
        columns, and it rides in ``state_dict`` for crash-safe resume.
        ``None`` (every non-hetero caller) means full rank."""
        if round_id is None:
            for rid, e in self._open.items():
                if client_id in e["slots"]:
                    round_id = rid
                    break
            else:
                raise KeyError(
                    f"client {client_id} has no lane in any open round "
                    f"(open: {list(self._open)}) — stale uplink from an "
                    "already-closed round?")
        if round_id in self._evicted and round_id not in self._open:
            self.stale_drops += 1
            if self.rec.enabled:
                self.rec.counter("ring.stale_drops").inc()
                self.rec.event("ring.stale_drop", cat="ring", round=round_id,
                               client=client_id)
            logger.warning("dropping uplink from client %d for evicted "
                           "round %r", client_id, round_id)
            return False
        if round_id in self._closed and round_id not in self._open:
            # a replayed uplink for a round whose close already consumed its
            # set — drop it; it must never scatter into a live round's lanes
            self.replay_drops += 1
            if self.rec.enabled:
                self.rec.counter("ring.replay_drops").inc()
                self.rec.event("ring.replay_drop", cat="ring", round=round_id,
                               client=client_id)
            logger.warning("dropping replayed uplink from client %d for "
                           "closed round %r", client_id, round_id)
            return False
        _, e = self._entry(round_id)
        if client_id in e["written"]:
            # duplicate (client, round): the lane was already written this
            # round — the first copy wins, the duplicate is dropped
            self.duplicate_drops += 1
            if self.rec.enabled:
                self.rec.counter("ring.duplicate_drops").inc()
                self.rec.event("ring.duplicate_drop", cat="ring",
                               round=round_id, client=client_id)
            logger.warning("dropping duplicate uplink from client %d for "
                           "round %r", client_id, round_id)
            return False
        slot = e["slots"][client_id]
        # obs: the ring.write span is the overlap invariant's witness — round
        # N+1 write intervals must land inside round N's close window
        with self.rec.span("ring.write", cat="ring", round=round_id,
                           client=client_id):
            if e["chunked"]:
                k, row = divmod(slot, self.chunk)
                buf = e["chunks"].get(k)
                if buf is None:
                    buf = e["chunks"].setdefault(k, self._alloc_chunk())
                for p in self._shapes:
                    buf[p][row] = np.asarray(flat[p], np.float32)
                e["w"][slot] = np.float32(1.0 if weight is None else weight)
                if rank is not None:
                    e["ranks"][slot] = np.int32(rank)
                e["filled"][k] += 1
            elif self._host:
                for p in self._shapes:
                    e["stacks"][p][slot] = np.asarray(flat[p], np.float32)
            else:
                leaves = {p: flat[p] for p in self._shapes}
                e["stacks"] = self._scatter(e["stacks"], jnp.int32(slot),
                                            leaves)
        e["written"][client_id] = slot
        if e["chunked"]:
            self._cascade(round_id, e)
        return True

    def write(self, client_id: int, lora_tree: Params, round_id=None, *,
              weight: Optional[float] = None,
              rank: Optional[int] = None) -> bool:
        return self.write_flat(client_id, flatten_with_paths(lora_tree),
                               round_id, weight=weight, rank=rank)

    @_ring_locked
    def chunk_ranks(self, round_id, k: int) -> Optional[np.ndarray]:
        """Chunk k's per-slot rank vector (−1 = full rank), or None for a
        stacked round. Read by the hetero partial fold to mask padded
        columns at ingest; re-entrant under the ring lock (the fold cascade
        calls back into the engine while holding it)."""
        _, e = self._entry(round_id)
        if not e["chunked"]:
            return None
        return np.asarray(e["ranks"][k * self.chunk:(k + 1) * self.chunk])

    # -- chunked fold cascade ----------------------------------------------
    def _cascade(self, rid, e) -> None:
        """Eagerly fold every complete chunk that is NEXT IN SLOT ORDER.

        A full chunk k only folds once chunks < k have folded — the fold
        sequence (and therefore the accumulator value) is a pure function of
        the slot assignment and the delivered payloads, never of arrival
        order. A full out-of-order chunk simply waits its turn."""
        while (e["next_chunk"] < e["num_chunks"]
               and e["filled"][e["next_chunk"]]
               == e["expected"][e["next_chunk"]]):
            self._fold_next(rid, e, eager=True)

    def _fold_next(self, rid, e, *, eager: bool) -> None:
        k = e["next_chunk"]
        buf = e["chunks"].pop(k, None)
        if buf is None:
            # nothing of this chunk was delivered: zero rows with zero
            # weights fold as an exact no-op, keeping every fold the same
            # (chunk, …) program signature
            buf = self._alloc_chunk()
        w = np.asarray(e["w"][k * self.chunk:(k + 1) * self.chunk])
        t0 = time.perf_counter_ns()
        # eager folds are the chunked path's overlap witnesses (the obs
        # report joins them with ring.write spans); close-time flushes of
        # trailing partial chunks use their own span name
        span = "close.partial_fold" if eager else "close.chunk_flush"
        with self.rec.span(span, cat="engine", round=rid, chunk=k):
            e["acc"] = self.on_chunk(e["acc"], buf, w, rid, k)
        if self.retain_chunks:
            e["retained"][k] = buf
        e["next_chunk"] = k + 1
        if eager:
            e["eager_folds"] += 1
            self.partial_folds += 1
        if self.rec.enabled:
            self.rec.hist("close.chunk_flush_us").observe(
                (time.perf_counter_ns() - t0) / 1e3)
            if eager:
                self.rec.counter("close.partial_folds").inc()

    @_ring_locked
    def is_chunked(self, round_id=None) -> bool:
        return bool(self._entry(round_id)[1]["chunked"])

    # -- views --------------------------------------------------------------
    @property
    @_ring_locked
    def open_rounds(self) -> List[Any]:
        return list(self._open)

    @property
    @_ring_locked
    def delivered(self) -> Dict[int, int]:
        """client_id → slot written in the OLDEST open round (next to close)."""
        return dict(self._entry()[1]["written"])

    @_ring_locked
    def delivered_in(self, round_id=None) -> Dict[int, int]:
        return dict(self._entry(round_id)[1]["written"])

    @_ring_locked
    def lanes(self, round_id=None) -> Dict[int, int]:
        """client_id → lane for ALL of a round's candidates (delivered or not)."""
        return dict(self._entry(round_id)[1]["slots"])

    @_ring_locked
    def slot_of(self, client_id: int, round_id=None) -> int:
        return self._entry(round_id)[1]["slots"][client_id]

    @_ring_locked
    def take(self, round_id=None) -> Dict[str, jnp.ndarray]:
        """Pop the oldest (or named) open round; hand its stacks to the close
        program (donated there — this set is gone for good)."""
        rid, e = self._entry(round_id)
        if e["chunked"]:
            raise RuntimeError(f"round {rid!r} streams in chunks — close it "
                               "via take_chunked()")
        del self._open[rid]
        self._closed[rid] = True
        while len(self._closed) > 64:  # bounded memory of closed ids
            self._closed.popitem(last=False)
        if self.rec.enabled:
            self.rec.event("ring.take", cat="ring", round=rid,
                           delivered=len(e["written"]), lanes=len(e["slots"]))
            self.rec.gauge("ring.occupancy").set(len(self._open))
        stacks = e["stacks"]
        if self._host:  # one host→device conversion per round
            stacks = {p: jnp.asarray(x) for p, x in stacks.items()}
        return stacks

    @_ring_locked
    def take_chunked(self, round_id=None) -> Tuple[Any, Dict[str, Any]]:
        """Flush the remaining chunks IN SLOT ORDER, pop the round and return
        ``(round_id, entry)`` — the entry carries the folded accumulators
        (``acc``), the raw ingest weights (``w``), retained chunk buffers
        when the method re-streams them, and the delivery bookkeeping.

        Trailing chunks that never filled flush here: unwritten lanes hold
        zero factors AND zero weight, so the padded fold is exact — every
        fold in the round's life shares one (chunk, …) program signature."""
        rid, e = self._entry(round_id)
        if not e["chunked"]:
            raise RuntimeError(f"round {rid!r} is stacked — close it via "
                               "take()")
        while e["next_chunk"] < e["num_chunks"]:
            self._fold_next(rid, e, eager=False)
        del self._open[rid]
        self._closed[rid] = True
        while len(self._closed) > 64:
            self._closed.popitem(last=False)
        if self.rec.enabled:
            self.rec.event("ring.take", cat="ring", round=rid,
                           delivered=len(e["written"]), lanes=len(e["slots"]),
                           chunked=True, partial_folds=e["eager_folds"])
            self.rec.gauge("ring.occupancy").set(len(self._open))
        return rid, e

    # -- checkpoint/resume (crash-safe round state) -------------------------
    @_ring_locked
    def state_dict(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(json-able bookkeeping, array leaves) snapshot of the ring.

        Open rounds' partially-written stacks ride along as flat arrays keyed
        ``ring/{round}/{path}`` so a resumed coordinator can keep streaming
        into them; at a round boundary the ring is normally empty and the
        snapshot is just the drop counters + closed/evicted id memories."""
        meta: Dict[str, Any] = {
            "open": [],
            "evicted": list(self._evicted.items()),
            "closed": list(self._closed),
            "evictions": self.evictions,
            "stale_drops": self.stale_drops,
            "replay_drops": self.replay_drops,
            "duplicate_drops": self.duplicate_drops,
            "partial_folds": self.partial_folds,
            "auto": self._auto,
        }
        arrays: Dict[str, Any] = {}
        for rid, e in self._open.items():
            entry: Dict[str, Any] = {
                "round": rid,
                "slots": {str(c): s for c, s in e["slots"].items()},
                "written": {str(c): s for c, s in e["written"].items()},
                "deadline": e["deadline"],
                "chunked": e["chunked"],
            }
            if e["chunked"]:
                # a mid-round chunked entry is its accumulators + the not-yet
                # -folded chunk buffers + the slot-indexed raw weights; the
                # fold cascade's position (next_chunk/filled) rides in meta
                # so a resumed twin replays the exact remaining fold sequence
                entry.update(next_chunk=e["next_chunk"],
                             num_chunks=e["num_chunks"],
                             expected=list(e["expected"]),
                             filled=list(e["filled"]),
                             eager_folds=e["eager_folds"],
                             pending_chunks=sorted(e["chunks"]),
                             retained_chunks=sorted(e["retained"]),
                             acc_keys=sorted(e["acc"]) if e["acc"] else [])
                arrays[f"ring/{rid}/_w"] = np.asarray(e["w"])
                arrays[f"ring/{rid}/_ranks"] = np.asarray(e["ranks"])
                for k, buf in e["chunks"].items():
                    for p, x in buf.items():
                        arrays[f"ring/{rid}/_chunk{k}/{p}"] = np.asarray(x)
                for k, buf in e["retained"].items():
                    for p, x in buf.items():
                        arrays[f"ring/{rid}/_ret{k}/{p}"] = np.asarray(x)
                if e["acc"]:
                    for name, x in e["acc"].items():
                        arrays[f"ring/{rid}/_acc/{name}"] = np.asarray(x)
            else:
                for p, x in e["stacks"].items():
                    arrays[f"ring/{rid}/{p}"] = np.asarray(x)
            meta["open"].append(entry)
        return meta, arrays

    @_ring_locked
    def load_state(self, meta: Dict[str, Any],
                   arrays: Dict[str, Any]) -> None:
        self._open = OrderedDict()
        for entry in meta["open"]:
            rid = entry["round"]
            e: Dict[str, Any] = {
                "slots": {int(c): s for c, s in entry["slots"].items()},
                "written": {int(c): s for c, s in entry["written"].items()},
                "deadline": entry["deadline"],
                "chunked": bool(entry.get("chunked", False))}
            if e["chunked"]:
                def _bufs(prefix, ks):
                    return {int(k): {p: np.asarray(
                        arrays[f"ring/{rid}/_{prefix}{k}/{p}"], np.float32)
                        for p in self._shapes} for k in ks}
                acc = None
                if entry["acc_keys"]:
                    acc = {name: jnp.asarray(
                        arrays[f"ring/{rid}/_acc/{name}"])
                        for name in entry["acc_keys"]}
                e.update(stacks=None,
                         chunks=_bufs("chunk", entry["pending_chunks"]),
                         retained=_bufs("ret", entry["retained_chunks"]),
                         acc=acc,
                         w=np.asarray(arrays[f"ring/{rid}/_w"], np.float32),
                         ranks=(np.asarray(arrays[f"ring/{rid}/_ranks"],
                                           np.int32)
                                if f"ring/{rid}/_ranks" in arrays
                                else np.full(int(entry["num_chunks"])
                                             * self.chunk, -1, np.int32)),
                         next_chunk=int(entry["next_chunk"]),
                         num_chunks=int(entry["num_chunks"]),
                         expected=[int(x) for x in entry["expected"]],
                         filled=[int(x) for x in entry["filled"]],
                         eager_folds=int(entry["eager_folds"]))
            else:
                stacks = {p: np.asarray(arrays[f"ring/{rid}/{p}"], np.float32)
                          for p in self._shapes}
                if not self._host:
                    stacks = {p: jnp.asarray(x) for p, x in stacks.items()}
                e["stacks"] = stacks
            self._open[rid] = e
        self._evicted = OrderedDict(
            (rid, reason) for rid, reason in meta["evicted"])
        self._closed = OrderedDict((rid, True) for rid in meta["closed"])
        self.evictions = int(meta["evictions"])
        self.stale_drops = int(meta["stale_drops"])
        self.replay_drops = int(meta.get("replay_drops", 0))
        self.duplicate_drops = int(meta.get("duplicate_drops", 0))
        self.partial_folds = int(meta.get("partial_folds", 0))
        self._auto = int(meta["auto"])


# --------------------------------------------------------------------------
# factored residual machinery (shared by divergence + the svd close)
# --------------------------------------------------------------------------

def _stacked_residual_factors(a_stack: jnp.ndarray, b_stack: jnp.ndarray,
                              u: jnp.ndarray
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Low-rank factors of the weighted residual, straight from the stacks.

    Σ_c u_c·a_c b_c − ā b̄  =  Σ_c u_c·a_c (b_c − b̄)  =  L @ R  with
    L = [u_0·a_0 | … | u_{C-1}·a_{C-1}]  (…, m, C·r)  and
    R = [b_0 − b̄ ; … ; b_{C-1} − b̄]     (…, C·r, n),  b̄ = Σ_c u_c·b_c —
    the rank-≤C·r form (ā b̄ lies inside span{a_c}, so no extra block is
    needed). Zero-weight lanes contribute zero L columns.
    """
    a = a_stack.astype(jnp.float32)  # (C, ..., m, r)
    b = b_stack.astype(jnp.float32)  # (C, ..., r, n)
    c = a.shape[0]
    bbar = jnp.einsum("c,c...rn->...rn", u, b)
    L = jnp.concatenate([u[i] * a[i] for i in range(c)], axis=-1)
    R = jnp.concatenate([b[i] - bbar for i in range(c)], axis=-2)
    return L, R


def _dev_fro_scaled(a_stack: jnp.ndarray, b_stack: jnp.ndarray,
                    u: jnp.ndarray) -> jnp.ndarray:
    """Scaled Frobenius norm of Σu_c·a_c b_c − ā b̄ via the factored Grams —
    never materialises the (…, m, n) deviation. Returns (…,) per leading axes."""
    L, R = _stacked_residual_factors(a_stack, b_stack, u)
    gl = jnp.einsum("...mi,...mj->...ij", L, L)
    gr = jnp.einsum("...in,...jn->...ij", R, R)
    fro_sq = jnp.maximum(jnp.einsum("...ij,...ij->...", gl, gr), 0.0)
    m, n = a_stack.shape[-2], b_stack.shape[-1]
    return jnp.sqrt(fro_sq) / np.sqrt(m * n)


def _safe_inv_sqrt(lam: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(λ^{-1/2}, λ^{1/2}) with pseudo-inverse semantics: eigenvalues below
    the rank-detection floor (masked lanes, redundant factors) map to 0."""
    tol = jnp.max(lam, axis=-1, keepdims=True) * (lam.shape[-1] * 1e-7)
    pos = lam > tol
    safe = jnp.where(pos, lam, 1.0)
    return (jnp.where(pos, jax.lax.rsqrt(safe), 0.0),
            jnp.where(pos, jnp.sqrt(safe), 0.0))


def factored_truncated_residual(a_stack: jnp.ndarray, b_stack: jnp.ndarray,
                                weights: jnp.ndarray, rank: int
                                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eckart–Young-optimal rank-``rank`` factors of the weighted residual,
    computed WITHOUT ever forming the dense (m, n) matrix.

    With ΔW = L @ R from :func:`_stacked_residual_factors` (P = C·r columns):
    eigendecompose the two small Grams G_L = LᵀL = E_L Λ_L E_Lᵀ and
    G_R = R Rᵀ = E_R Λ_R E_Rᵀ, so L = Q_L Λ_L^{1/2} E_Lᵀ with orthonormal
    Q_L = L E_L Λ_L^{-1/2} (pseudo-inverse on null directions — masked lanes
    give zero columns) and likewise R = E_R Λ_R^{1/2} Q_Rᵀ. Then
    ΔW = Q_L S Q_Rᵀ with the P×P core S = Λ_L^{1/2} E_Lᵀ E_R Λ_R^{1/2}; the
    SVD of S gives ΔW's singular triplets, and the top-r' slice yields

        A' = L E_L Λ_L^{-1/2} U_{:r'} Σ_{:r'}   (…, m, r')
        B' = V_{:r'}ᵀ Λ_R^{-1/2} E_Rᵀ R          (…, r', n)

    with A' @ B' the optimal rank-r' approximation (Eq. 15–16). Every
    intermediate is (m, P), (P, n) or (P, P) — asserted shape-by-shape on the
    jaxpr in tests. Leading stacked-layer / expert axes batch through.
    Accuracy: the Gram squaring costs ~half the float32 digits; the result
    matches the dense-SVD oracle to ~1e-5 relative (documented tolerance).
    """
    L, R = _stacked_residual_factors(a_stack, b_stack, weights)
    gl = jnp.einsum("...mi,...mj->...ij", L, L)
    gr = jnp.einsum("...in,...jn->...ij", R, R)
    el, vl = jnp.linalg.eigh(gl)
    er, vr = jnp.linalg.eigh(gr)
    il, sl = _safe_inv_sqrt(el)
    ir, sr = _safe_inv_sqrt(er)
    core = sl[..., :, None] * (jnp.swapaxes(vl, -1, -2) @ vr) * sr[..., None, :]
    u, s, vt = jnp.linalg.svd(core, full_matrices=False)
    u_r = u[..., :, :rank]
    s_r = s[..., :rank]
    vt_r = vt[..., :rank, :]
    aprime = L @ ((vl * il[..., None, :]) @ u_r) * s_r[..., None, :]
    bprime = (vt_r @ jnp.swapaxes(vr * ir[..., None, :], -1, -2)) @ R
    return aprime, bprime


def factored_truncated_product(L: jnp.ndarray, R: jnp.ndarray, rank: int
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eckart–Young-optimal rank-``rank`` factors of the UNCENTERED product
    ``L @ R`` — the hetero close's truncation primitive, shared with the eager
    oracle (core/hetero.py) so engine and oracle compose the SAME ops.

    Identical Gram machinery to :func:`factored_truncated_residual` (two
    (P, P) eigendecompositions + one small SVD, P = L's column count; the
    dense (m, n) product never exists — jaxpr-asserted in tests), but on the
    raw product rather than the centred residual, and with the BALANCED
    singular split A' = Q_L U √Σ, B' = √Σ Vᵀ Q_Rᵀ R (the LoRA-friendly
    parameterisation of core/hetero.py) instead of folding Σ into A' alone.
    Zero columns of L / zero rows of R (rank-padded lanes) yield zero Gram
    eigenvalues that ``_safe_inv_sqrt`` floors away, so r_max-padded ragged
    stacks truncate exactly as their unpadded originals. The rank-r' slice of
    the returned factors IS the optimal rank-r' truncation for any r' ≤ rank
    (same singular triplets), which is how the hetero close serves every
    client rank from ONE decomposition.
    """
    gl = jnp.einsum("...mi,...mj->...ij", L, L)
    gr = jnp.einsum("...in,...jn->...ij", R, R)
    el, vl = jnp.linalg.eigh(gl)
    er, vr = jnp.linalg.eigh(gr)
    il, sl = _safe_inv_sqrt(el)
    ir, sr = _safe_inv_sqrt(er)
    core = sl[..., :, None] * (jnp.swapaxes(vl, -1, -2) @ vr) * sr[..., None, :]
    u, s, vt = jnp.linalg.svd(core, full_matrices=False)
    sq = jnp.sqrt(jnp.maximum(s[..., :rank], 0.0))
    aprime = L @ ((vl * il[..., None, :]) @ u[..., :, :rank]) * sq[..., None, :]
    bprime = sq[..., :, None] * (
        (vt[..., :rank, :] @ jnp.swapaxes(vr * ir[..., None, :], -1, -2)) @ R)
    return aprime, bprime


def _rank_mask(ranks: jnp.ndarray, r: int) -> jnp.ndarray:
    """(C,) int rank vector → (C, r) 0/1 float mask: column j of lane c is
    live iff j < ranks[c]. Negative ranks mean "unmasked" (full r)."""
    rk = jnp.where(ranks < 0, r, ranks)
    return (jnp.arange(r)[None, :] < rk[:, None]).astype(jnp.float32)


def _mask_factor_stacks(a: jnp.ndarray, b: jnp.ndarray, ranks: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero the rank-padded columns of a (C, …, m, r) stack and the matching
    rows of its (C, …, r, n) twin. Multiplying by the 0/1 mask is EXACT
    (0·x = 0, 1·x = x), so lanes whose padding carries garbage (a defended
    decode that validated but over-wrote) still contribute exactly zero."""
    c, r = a.shape[0], a.shape[-1]
    mask = _rank_mask(ranks, r)
    ma = mask.reshape((c,) + (1,) * (a.ndim - 2) + (r,))
    mb = mask.reshape((c,) + (1,) * (b.ndim - 3) + (r, 1))
    return a * ma, b * mb


def _l_block(a_chunk: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(chunk, …, m, r) → (…, m, chunk·r): one chunk's weighted L columns,
    lane-major — exactly the columns the stacked ``_stacked_residual_factors``
    concatenation would give these lanes, so chunk-pair Gram blocks tile the
    full (C·r)² Gram."""
    a = a_chunk.astype(jnp.float32)
    la = w.reshape((-1,) + (1,) * (a.ndim - 1)) * a
    la = jnp.moveaxis(la, 0, -2)  # (…, m, chunk, r)
    return la.reshape(la.shape[:-2] + (la.shape[-2] * la.shape[-1],))


def _r_block(b_chunk: jnp.ndarray, bbar: jnp.ndarray) -> jnp.ndarray:
    """(chunk, …, r, n) → (…, chunk·r, n): one chunk's centred R rows,
    lane-major (the stacked concatenation's row blocks)."""
    rb = b_chunk.astype(jnp.float32) - bbar[None]
    rb = jnp.moveaxis(rb, 0, -3)  # (…, chunk, r, n)
    return rb.reshape(rb.shape[:-3] + (rb.shape[-3] * rb.shape[-2],
                                       rb.shape[-1]))


# --------------------------------------------------------------------------
# the fused close programs (one per engine method)
# --------------------------------------------------------------------------

def _slice_client_trees(specs: Sequence[FactorSpec],
                        stacks: Dict[str, jnp.ndarray],
                        c_max: int) -> List[Params]:
    """Stack lanes as a list of adapter trees — the uniform closes feed these
    to the aggregation operators verbatim, so the jitted program is the jnp
    ground truth op-for-op (the bitwise contract)."""
    return [
        {s.key: {"a": stacks[s.key + "/a"][c], "b": stacks[s.key + "/b"][c]}
         for s in specs}
        for c in range(c_max)
    ]


def _uniform_close(specs: Sequence[FactorSpec], scale: float,
                   w0_leaves: Dict[str, jnp.ndarray],
                   stacks: Dict[str, jnp.ndarray], c_max: int):
    """Full-participation uniform fedex close — literally the aggregation
    operators over stack slices, so the jitted program is the jnp ground
    truth."""
    client_trees = _slice_client_trees(specs, stacks, c_max)
    g = agg.fedit_aggregate(client_trees)
    res = agg.fedex_residual(client_trees, g)
    new_w0 = {
        s.key: (w0_leaves[s.key].astype(jnp.float32)
                + scale * res[s.key]).astype(s.w0_dtype)
        for s in specs
    }
    glob = {s.key: g[s.key] for s in specs}
    return new_w0, glob


def _weighted_close_jnp(specs: Sequence[FactorSpec], scale: float,
                        w0_leaves: Dict[str, jnp.ndarray],
                        stacks: Dict[str, jnp.ndarray],
                        w: jnp.ndarray, c_max: int):
    """Weighted/masked fedex close, jnp twin: Σw_c a_c b_c − ā b̄ folded into
    W0. Zero-weight lanes vanish from every sum — the participation mask."""
    new_w0, glob = {}, {}
    for s in specs:
        a = stacks[s.key + "/a"]  # (C, ..., m, r) f32
        b = stacks[s.key + "/b"]
        ga = jnp.einsum("c,c...mr->...mr", w, a)
        gb = jnp.einsum("c,c...rn->...rn", w, b)
        mean_prod = jnp.einsum("c,c...mr,c...rn->...mn", w, a, b)
        res = mean_prod - jnp.matmul(ga, gb)
        new_w0[s.key] = (w0_leaves[s.key].astype(jnp.float32)
                         + scale * res).astype(s.w0_dtype)
        glob[s.key] = {"a": ga, "b": gb}
    return new_w0, glob


def _weighted_close_pallas(specs: Sequence[FactorSpec], scale: float,
                           w0_leaves: Dict[str, jnp.ndarray],
                           stacks: Dict[str, jnp.ndarray],
                           w: Optional[jnp.ndarray], interpret: Optional[bool]):
    """Fused-kernel fedex close: factor means + residual fold through the
    tiled Pallas kernels — the dense m×n residual never exists in HBM."""
    from repro.kernels import factor_mean, fedex_fold

    new_w0, glob = {}, {}
    for s in specs:
        a = stacks[s.key + "/a"]  # (C, ..., m, r)
        b = stacks[s.key + "/b"]
        ga = factor_mean(a, w, interpret=interpret)
        gb = factor_mean(b, w, interpret=interpret)
        # kernel layout: leading layer axes first, client axis innermost
        am = jnp.moveaxis(a, 0, -3)
        bm = jnp.moveaxis(b, 0, -3)
        new_w0[s.key] = fedex_fold(
            w0_leaves[s.key], am, bm, scale, weights=w,
            interpret=interpret).astype(s.w0_dtype)
        glob[s.key] = {"a": ga, "b": gb}
    return new_w0, glob


def _svd_close(specs: Sequence[FactorSpec], scale: float, svd_rank: int,
               w0_leaves: Dict[str, jnp.ndarray],
               stacks: Dict[str, jnp.ndarray], w: jnp.ndarray,
               backend: str, interpret: Optional[bool]):
    """Truncated-SVD close: factored Eckart–Young residual (never dense),
    folded into W0 as the rank-r' product A' @ B'."""
    new_w0, glob = {}, {}
    for s in specs:
        a = stacks[s.key + "/a"]  # (C, ..., m, r)
        b = stacks[s.key + "/b"]
        if backend == "pallas":
            from repro.kernels import factor_mean, product_fold
            ga = factor_mean(a, w, interpret=interpret)
            gb = factor_mean(b, w, interpret=interpret)
            ap, bp = factored_truncated_residual(a, b, w, svd_rank)
            new_w0[s.key] = product_fold(
                w0_leaves[s.key], jnp.expand_dims(ap, -3),
                jnp.expand_dims(bp, -3), jnp.ones((1,), jnp.float32), scale,
                interpret=interpret).astype(s.w0_dtype)
        else:
            ga = jnp.einsum("c,c...mr->...mr", w, a)
            gb = jnp.einsum("c,c...rn->...rn", w, b)
            ap, bp = factored_truncated_residual(a, b, w, svd_rank)
            new_w0[s.key] = (w0_leaves[s.key].astype(jnp.float32)
                             + scale * jnp.matmul(ap, bp)).astype(s.w0_dtype)
        glob[s.key] = {"a": ga, "b": gb}
    return new_w0, glob


def _reinit_close(specs: Sequence[FactorSpec], scale: float,
                  w0_leaves: Dict[str, jnp.ndarray],
                  stacks: Dict[str, jnp.ndarray], w: jnp.ndarray,
                  c_max: int, uniform: bool, backend: str,
                  interpret: Optional[bool]):
    """Reinit close (Table 5): the FULL ideal update Σw_c·a_c b_c folds into
    W0 (fresh adapters carry b=0, so nothing is left behind). The uniform
    branch composes product_mean over stack slices — bitwise twin of the
    jitted assignment oracle on EVERY backend (like the fedex uniform
    branch; the kernel path serves weighted/ragged rounds)."""
    if uniform:
        client_trees = _slice_client_trees(specs, stacks, c_max)
        ideal = agg.product_mean(client_trees)
        return {
            s.key: (w0_leaves[s.key].astype(jnp.float32)
                    + scale * ideal[s.key]).astype(s.w0_dtype)
            for s in specs
        }
    new_w0 = {}
    for s in specs:
        a = stacks[s.key + "/a"]
        b = stacks[s.key + "/b"]
        if backend == "pallas":
            from repro.kernels import product_fold
            am = jnp.moveaxis(a, 0, -3)
            bm = jnp.moveaxis(b, 0, -3)
            new_w0[s.key] = product_fold(
                w0_leaves[s.key], am, bm, w, scale,
                interpret=interpret).astype(s.w0_dtype)
        else:
            ideal = jnp.einsum("c,c...mr,c...rn->...mn", w, a, b)
            new_w0[s.key] = (w0_leaves[s.key].astype(jnp.float32)
                             + scale * ideal).astype(s.w0_dtype)
    return new_w0


def _keep_local_close(specs: Sequence[FactorSpec], scale: float,
                      w0_stacks: Dict[str, jnp.ndarray],
                      stacks: Dict[str, jnp.ndarray], w: jnp.ndarray,
                      c_max: int, uniform: bool, backend: str,
                      interpret: Optional[bool]):
    """Keep_local close (Table 5): every lane's OWN base gets its residual
    Σ_j w_j·a_j b_j − a_c b_c. ``w0_stacks`` carry the per-lane W0 leaves
    ((C_max, …) like the factor stacks); non-delivered lanes produce a lane
    the caller discards. The uniform branch composes per_client_residuals
    over stack slices — bitwise twin of the jitted assignment oracle on
    EVERY backend (like the fedex uniform branch; the kernel path serves
    weighted/ragged rounds)."""
    if uniform:
        # the bitwise branch composes the eager operators lane-by-lane; it
        # costs ~2× the batched-einsum branch below (unbatchable per-client
        # matmul chains) — the price of the uniform bitwise contract. The
        # trainer's full-round close still beats the eager path (fused
        # divergence + single dispatch); weighted/ragged rounds take the
        # fast branch.
        client_trees = _slice_client_trees(specs, stacks, c_max)
        residuals = agg.per_client_residuals(client_trees)
        return {
            s.key: jnp.stack([
                (w0_stacks[s.key][c].astype(jnp.float32)
                 + scale * residuals[c][s.key]).astype(s.w0_dtype)
                for c in range(c_max)
            ])
            for s in specs
        }
    new_w0 = {}
    for s in specs:
        a = stacks[s.key + "/a"]  # (C, ..., m, r)
        b = stacks[s.key + "/b"]
        if backend == "pallas":
            from repro.kernels import perclient_fold
            new_w0[s.key] = perclient_fold(
                w0_stacks[s.key], a, b, w, scale,
                interpret=interpret).astype(s.w0_dtype)
        else:
            ideal = jnp.einsum("c,c...mr,c...rn->...mn", w, a, b)
            own = jnp.matmul(a, b)
            new_w0[s.key] = (w0_stacks[s.key].astype(jnp.float32)
                             + scale * (ideal[None] - own)).astype(s.w0_dtype)
    return new_w0


def _hetero_close(specs: Sequence[FactorSpec], scale: float,
                  w0_stacks: Dict[str, jnp.ndarray],
                  stacks: Dict[str, jnp.ndarray], w: jnp.ndarray,
                  ranks: jnp.ndarray, c_max: int, uniform: bool,
                  backend: str, interpret: Optional[bool]):
    """Heterogeneous-rank close (core/hetero.py's scheme, engine-side): the
    ideal update Δ̄ = Σ_c w_c·a_c b_c is truncated ONCE at the template rank
    r_max via :func:`factored_truncated_product`; lane c's adapters are the
    leading-rᵢ slice of that truncation (same singular triplets — rank masks
    in place of per-client SVDs) and its residual Δ̄ − aᵢ'bᵢ' folds into its
    OWN (C_max, …)-stacked W0, so W0_c + ΔW_c + a_c'b_c' = W0_c + Δ̄ exactly
    for every lane. Ragged lanes ride zero-padded to r_max with a (C_max,)
    rank vector next to the weight vector; the masks multiply by exact 0/1 so
    masked rank columns contribute exactly zero to every sum. The uniform
    branch (full participation, uniform weights, every delivered rank =
    r_max) composes the eager oracle's op sequence over stack slices — the
    bitwise contract; the ragged branch shares every decomposition input with
    the oracle's padded form, differing only by the fold's FMA contraction
    (≤2 ulp, asserted in tests/test_engine_hetero.py).

    Returns ``(new_w0_stacks, glob, masked_stacks)`` with ``glob[key] =
    {"a": A'(r_max), "b": B'(r_max)}`` (callers slice per-client ranks) and
    the rank-masked stacks for the divergence tail."""
    new_w0, glob, masked = {}, {}, {}
    for s in specs:
        a = stacks[s.key + "/a"].astype(jnp.float32)  # (C, ..., m, r_max)
        b = stacks[s.key + "/b"].astype(jnp.float32)  # (C, ..., r_max, n)
        r = s.a_shape[-1]
        if uniform:
            am, bm = a, b  # every lane at full rank: masking is the identity
            L = jnp.concatenate([a[i] / c_max for i in range(c_max)], axis=-1)
        else:
            am, bm = _mask_factor_stacks(a, b, ranks)
            L = jnp.concatenate([w[i] * am[i] for i in range(c_max)], axis=-1)
        R = jnp.concatenate([bm[i] for i in range(c_max)], axis=-2)
        ap, bp = factored_truncated_product(L, R, r)
        if backend == "pallas" and not uniform:
            from repro.kernels import hetero_fold
            new_w0[s.key] = hetero_fold(
                w0_stacks[s.key], a, b, w, ranks, ap, bp, scale,
                interpret=interpret).astype(s.w0_dtype)
        else:
            # Δ̄ as the factored product (the oracle's op), per-lane own =
            # masked slice of the shared truncation; adding the exact-zero
            # masked terms reproduces the oracle's sliced matmul exactly
            ideal = L @ R
            if uniform:
                own_full = ap @ bp  # every lane at r_max: one shared own
                owns = [own_full] * c_max
            else:
                mask = _rank_mask(ranks, r)
                owns = [(ap * mask[c]) @ bp for c in range(c_max)]
            new_w0[s.key] = jnp.stack([
                (w0_stacks[s.key][c].astype(jnp.float32)
                 + scale * (ideal - owns[c])).astype(s.w0_dtype)
                for c in range(c_max)
            ])
        glob[s.key] = {"a": ap, "b": bp}
        masked[s.key + "/a"] = am
        masked[s.key + "/b"] = bm
    return new_w0, glob, masked


def make_close_fn(specs: Sequence[FactorSpec], *, scale: float, c_max: int,
                  method: str = "fedex", svd_rank: int = 0,
                  backend: str = "auto", interpret: Optional[bool] = None,
                  donate: bool = True):
    """Build the jitted close program for one engine method.

    Signature: ``close(w0_leaves, stacks, weights, mask, uniform=...)`` →
    ``(new_w0_leaves, global_factors, divergence)`` with ``w0_leaves`` and
    ``stacks`` donated (in-place update; skipped on CPU where XLA has no
    donation support, or with ``donate=False`` for callers that replay the
    program on the same buffers, e.g. benchmarks).

    * ``method="fedex"`` — ``uniform=True`` is the static full-participation
      branch, bitwise twin of the jitted list path; otherwise ``weights`` is
      the (C_max,) vector with zeros masking non-delivered lanes and ``mask``
      its 0/1 indicator (used for the uniform-over-delivered divergence).
    * ``method="fedex_svd"`` — the factored rank-``svd_rank`` truncated close
      (requires ``svd_rank ≥ 1``); both uniform and ragged rounds go through
      the weight vector (truncation has no bitwise-uniform contract).
    * ``method="reinit"`` — ``w0_leaves`` as fedex; returns ``glob={}``
      (fresh adapters are drawn host-side by the engine).
    * ``method="keep_local"`` — ``w0_leaves`` holds (C_max, …)-stacked
      per-lane W0 leaves and the returned ``new_w0`` is stacked likewise;
      ``glob={}``.
    * ``method="hetero"`` — heterogeneous client ranks: ``w0_leaves`` is
      (C_max, …)-stacked per-lane W0 leaves (as keep_local) and the ``mask``
      positional slot carries the (C_max,) INT rank vector (ragged lanes
      zero-padded to the template rank r_max; rank 0 masks a lane entirely,
      negative means full rank). Returns stacked ``new_w0`` plus ``glob`` =
      the shared rank-r_max truncation factors per spec — the caller slices
      each client's leading rᵢ columns/rows.
    """
    backend = _resolve_backend(backend)
    specs = list(specs)
    if method not in ENGINE_METHODS:
        raise ValueError(f"unknown engine method {method!r} "
                         f"(expected one of {ENGINE_METHODS})")
    if method == "fedex_svd" and svd_rank < 1:
        raise ValueError(f"fedex_svd close needs svd_rank ≥ 1, got {svd_rank}"
                         " (svd_rank=0 means exact — use the fedex close)")

    def _close(w0_leaves, stacks, weights, mask, *, uniform: bool):
        if method == "fedex":
            if uniform:
                new_w0, glob = _uniform_close(specs, scale, w0_leaves, stacks,
                                              c_max)
            elif backend == "pallas":
                new_w0, glob = _weighted_close_pallas(
                    specs, scale, w0_leaves, stacks, weights, interpret)
            else:
                new_w0, glob = _weighted_close_jnp(
                    specs, scale, w0_leaves, stacks, weights, c_max)
        elif method == "fedex_svd":
            new_w0, glob = _svd_close(specs, scale, svd_rank, w0_leaves,
                                      stacks, weights, backend, interpret)
        elif method == "reinit":
            new_w0 = _reinit_close(specs, scale, w0_leaves, stacks, weights,
                                   c_max, uniform, backend, interpret)
            glob = {}
        elif method == "hetero":
            new_w0, glob, masked = _hetero_close(
                specs, scale, w0_leaves, stacks, weights, mask, c_max,
                uniform, backend, interpret)
            # divergence over the rank-masked stacks: padded columns must
            # contribute exactly zero to the §6 metric too
            stacks = masked
        else:  # keep_local
            new_w0 = _keep_local_close(specs, scale, w0_leaves, stacks,
                                       weights, c_max, uniform, backend,
                                       interpret)
            glob = {}
        if method == "hetero" and not uniform:
            # the mask slot carries the rank vector — a lane participates in
            # the divergence iff it delivered weight AND a non-empty rank
            live = jnp.where((mask > 0) & (weights > 0),
                             jnp.float32(1.0), jnp.float32(0.0))
            u = live / jnp.maximum(live.sum(), 1.0)
        else:
            u = (jnp.full((c_max,), 1.0 / c_max, jnp.float32) if uniform
                 else mask / jnp.maximum(mask.sum(), 1.0))
        parts = [
            _dev_fro_scaled(stacks[s.key + "/a"], stacks[s.key + "/b"],
                            u).ravel()
            for s in specs
        ]
        div = jnp.concatenate(parts).mean() if parts else jnp.float32(0)
        return new_w0, glob, div

    donate_argnums = (0, 1) if donate and not _CPU else ()
    return jax.jit(_close, static_argnames=("uniform",),
                   donate_argnums=donate_argnums)


class RoundCloseEngine:
    """Owns the streaming buffers + the compiled close program for a trainer.

    One engine per (params structure, adapter structure, C_max, scale,
    method): ``buffers`` is handed to the fedsrv coordinator as the delivery
    sink, and :meth:`close` / :meth:`close_keep_local` run the
    single-dispatch fused close over whatever subset actually arrived, with
    any weighting. The C_max padding contract: stacks are always
    ``(C_max, …)``; a round's candidates get lanes in client-id order;
    weights (zeros on non-delivered lanes) mask the rest — so ragged quorums
    and weighted rounds reuse ONE compiled program, and the uniform
    full-participation fedex/reinit/keep_local rounds keep their own
    bitwise-stable branch. ``depth`` (default 2) double-buffers the streaming
    stacks so the next round's uplinks can be decoded into a fresh set while
    this round's close still owns the previous one.
    """

    def __init__(self, params: Params, lora_template: Params, *,
                 c_max: int, scale: float, method: str = "fedex",
                 svd_rank: int = 0, backend: str = "auto",
                 interpret: Optional[bool] = None, donate: bool = True,
                 depth: int = 2, recorder=None, chunk: int = 0,
                 program_cache_cap: int = 16,
                 client_ranks: Optional[Sequence[int]] = None):
        self.specs = build_factor_specs(params, lora_template)
        self.c_max = c_max
        # hetero: per-client TRUE adapter ranks (index = client id). The
        # template rank is r_max — every stack lane is padded to it and the
        # close masks the truncation back down per lane. None = every client
        # at full rank (the uniform bitwise branch).
        if client_ranks is not None:
            rmax = self.specs[0].a_shape[-1] if self.specs else 0
            client_ranks = tuple(int(r) for r in client_ranks)
            if len(client_ranks) != c_max:
                raise ValueError(
                    f"client_ranks has {len(client_ranks)} entries for "
                    f"c_max={c_max}")
            bad = [r for r in client_ranks if not 1 <= r <= rmax]
            if bad:
                raise ValueError(
                    f"client_ranks {bad} outside [1, r_max={rmax}] — the "
                    "lora template must be built at the LARGEST client rank")
        self.client_ranks = client_ranks
        self.scale = scale
        self.method = method
        self.svd_rank = svd_rank
        self.backend = _resolve_backend(backend)
        self.rec = recorder if recorder is not None else NULL
        self.chunk = int(chunk)
        self._interpret = interpret
        self._donate = donate
        # LRU'd jitted programs: the stacked close plus, in chunked mode, the
        # partial fold / finalize / keep_local per-chunk / svd Gram-core-
        # projection family — bounded so long-lived engines can't grow the
        # compile cache without limit (satellite fix; see _ProgramCache)
        self._programs = _ProgramCache(cap=program_cache_cap)
        # analytic peak-live-device-bytes accounting per in-flight close (see
        # the "Memory model" docs section): inputs + outputs + materialised
        # intermediates, with a donated input/output pair counted once;
        # identical formula on every backend so CPU runs model accelerator
        # residency rather than host RAM
        self._peak: Dict[Any, int] = {}
        self.last_peak_bytes = 0
        self.buffers = RoundBuffers(
            lora_template, c_max, depth=depth, recorder=self.rec,
            chunk=self.chunk,
            on_chunk=self._fold_chunk if self.chunk else None,
            # keep_local folds each lane's OWN base, and fedex_svd / hetero
            # re-stream the L/R blocks for the projection pass — all three
            # need the chunk factor buffers back at close time
            retain_chunks=method in ("keep_local", "fedex_svd", "hetero"))
        self._lora_template = lora_template
        self._close = make_close_fn(self.specs, scale=scale, c_max=c_max,
                                    method=method, svd_rank=svd_rank,
                                    backend=self.backend, interpret=interpret,
                                    donate=donate)
        self._programs.get(("stacked", method), lambda: self._close)

    # ------------------------------------------------------------------
    def _dispatch(self, w0_leaves, stacks, w, mask, uniform: bool, round_id):
        """Run the jitted close program with obs instrumentation: the
        ``close.dispatch`` span times ONLY the (async) dispatch — the
        block-until-ready half lives in ``DeferredDivergence.resolve`` —
        and the compile-cache delta distinguishes a compile (miss) from a
        cache hit per (method, uniform) signature."""
        rec = self.rec
        self._note_peak(round_id, _tree_bytes(w0_leaves) + _tree_bytes(stacks)
                        + self._div_temp_bytes(self.c_max))
        if not rec.enabled:
            return self._close(w0_leaves, stacks, jnp.asarray(w),
                               jnp.asarray(mask), uniform=uniform)
        before = self._close._cache_size()
        t0 = time.perf_counter_ns()
        with rec.span("close.dispatch", cat="engine", round=round_id,
                      method=self.method, uniform=uniform):
            out = self._close(w0_leaves, stacks, jnp.asarray(w),
                              jnp.asarray(mask), uniform=uniform)
        dispatch_us = (time.perf_counter_ns() - t0) / 1e3
        sig = f"{self.method}[uniform={uniform}]"
        compiled = self._close._cache_size() > before
        rec.counter(f"engine.compile_{'miss' if compiled else 'hit'}"
                    f"[{sig}]").inc()
        rec.hist("engine.close_dispatch_us").observe(dispatch_us)
        if round_id is not None:
            rec.round_set(round_id, method=self.method,
                          close_dispatch_us=round(dispatch_us, 1),
                          compile_miss=int(compiled),
                          ring_occupancy=len(self.buffers.open_rounds),
                          ring_evictions=self.buffers.evictions,
                          stale_drops=self.buffers.stale_drops,
                          replay_drops=self.buffers.replay_drops,
                          duplicate_drops=self.buffers.duplicate_drops)
        return out

    # ------------------------------------------------------------------
    def weight_vector(self, client_ids: Sequence[int],
                      weights: Optional[Sequence[float]],
                      round_id=None) -> Tuple[np.ndarray, np.ndarray, bool]:
        """(C_max,) weights + mask from the delivered ids; uniform? flag."""
        slots = [self.buffers.slot_of(cid, round_id) for cid in client_ids]
        mask = np.zeros(self.c_max, np.float32)
        mask[slots] = 1.0
        norm = agg.normalize_weights(weights, len(client_ids))
        uniform = norm is None and len(client_ids) == self.c_max
        w = np.zeros(self.c_max, np.float32)
        if norm is None:
            w[slots] = 1.0 / len(client_ids)
        else:
            for s, wi in zip(slots, norm):
                w[s] = wi
        return w, mask, uniform

    def _validate_delivered(self, client_ids: Sequence[int],
                            round_id=None) -> None:
        if not client_ids:
            raise ValueError("cannot close a round with no deliveries")
        written = self.buffers.delivered_in(round_id)
        missing = [c for c in client_ids if c not in written]
        if missing:
            raise ValueError(f"clients {missing} were never written to the "
                             "round buffers")

    def _w0_leaves(self, params: Params) -> Dict[str, jnp.ndarray]:
        return collect_w0_leaves(self.specs, params)

    def _fold_back(self, params: Params,
                   new_w0: Dict[str, jnp.ndarray]) -> Params:
        return fold_back_w0(self.specs, params, new_w0)

    # -- analytic peak-memory accounting -------------------------------
    def _note_peak(self, round_id, nbytes: int) -> None:
        cur = self._peak.get(round_id, 0)
        if nbytes > cur:
            self._peak[round_id] = nbytes
            while len(self._peak) > 64:  # bounded (abandoned rounds)
                self._peak.pop(next(iter(self._peak)))

    def _finish_peak(self, round_id) -> int:
        peak = self._peak.pop(round_id, 0)
        self.last_peak_bytes = peak
        if self.rec.enabled:
            self.rec.gauge("close.peak_bytes").set(peak)
            if round_id is not None:
                self.rec.round_set(round_id, peak_bytes=peak)
        return peak

    def peak_close_bytes(self, round_id) -> int:
        """Recorded peak live device bytes of a still-accumulating round."""
        return self._peak.get(round_id, 0)

    def _div_temp_bytes(self, c: int) -> int:
        """Device bytes of the divergence intermediates a stacked close
        materialises: per spec the L (…, m, C·r) and R (…, C·r, n) factors
        plus two (C·r)² Grams — the terms that make stacked closes O(C) and
        O((C·r)²) in memory."""
        total = 0
        for s in self.specs:
            lead = int(np.prod(s.a_shape[:-2], dtype=np.int64))
            m, r = s.a_shape[-2], s.a_shape[-1]
            n = s.b_shape[-1]
            p = c * r
            total += 4 * lead * (m * p + p * n + 2 * p * p)
        return total

    def _prod_temp_bytes(self) -> int:
        """Bytes of one dense (…, m, n) residual temp per spec (the chunked
        finalize's only dense intermediate)."""
        return sum(
            4 * int(np.prod(s.a_shape[:-1], dtype=np.int64))
            * s.b_shape[-1] for s in self.specs)

    # -- chunked accumulators + fold ------------------------------------
    def _init_acc(self) -> Dict[str, jnp.ndarray]:
        """Fresh float32 accumulators: weighted factor sums Σŵa / Σŵb for
        every method, plus the weighted product fold target Σŵ·a b for the
        methods whose close needs the dense ideal/residual (fedex / reinit /
        keep_local; fedex_svd stays factored — its close works off Gram
        blocks of the retained chunks)."""
        acc: Dict[str, jnp.ndarray] = {}
        need_prod = self.method != "fedex_svd"
        for s in self.specs:
            acc["ga/" + s.key] = jnp.zeros(s.a_shape, jnp.float32)
            acc["gb/" + s.key] = jnp.zeros(s.b_shape, jnp.float32)
            if need_prod:
                acc["prod/" + s.key] = jnp.zeros(
                    s.a_shape[:-1] + (s.b_shape[-1],), jnp.float32)
        return acc

    def _build_fold(self):
        """One jitted partial fold shared by EVERY chunk of every round:
        acc += Σ_lanes ŵ·(a, b, a@b). Zero-weight lanes (unwritten rows of a
        padded trailing chunk) are exact no-ops, so partial chunks reuse the
        same (chunk, …) program signature — the compile cache stays O(1) in
        round count and chunk fill."""
        specs, backend, interpret = self.specs, self.backend, self._interpret
        need_prod = self.method != "fedex_svd"

        def _fold(acc, stacks, w):
            out = dict(acc)
            for s in specs:
                a = stacks[s.key + "/a"].astype(jnp.float32)
                b = stacks[s.key + "/b"].astype(jnp.float32)
                if backend == "pallas":
                    from repro.kernels import factor_mean, product_accum
                    out["ga/" + s.key] = (acc["ga/" + s.key]
                                          + factor_mean(a, w,
                                                        interpret=interpret))
                    out["gb/" + s.key] = (acc["gb/" + s.key]
                                          + factor_mean(b, w,
                                                        interpret=interpret))
                    if need_prod:
                        out["prod/" + s.key] = product_accum(
                            acc["prod/" + s.key], jnp.moveaxis(a, 0, -3),
                            jnp.moveaxis(b, 0, -3), w, 1.0,
                            interpret=interpret)
                else:
                    out["ga/" + s.key] = acc["ga/" + s.key] + jnp.einsum(
                        "c,c...mr->...mr", w, a)
                    out["gb/" + s.key] = acc["gb/" + s.key] + jnp.einsum(
                        "c,c...rn->...rn", w, b)
                    if need_prod:
                        out["prod/" + s.key] = acc["prod/" + s.key] + \
                            jnp.einsum("c,c...mr,c...rn->...mn", w, a, b)
            return out

        donate = (0,) if self._donate and not _CPU else ()
        return jax.jit(_fold, donate_argnums=donate)

    def _fold_chunk(self, acc, chunk_bufs, w, round_id, chunk_index):
        """RoundBuffers' on_chunk callback: one H2D conversion + one fold
        dispatch per chunk. The accumulator is donated to the fold program,
        so the partial fold is a true read-modify-write."""
        if acc is None:
            acc = self._init_acc()
        stacks = {p: jnp.asarray(x) for p, x in chunk_bufs.items()}
        if self.method == "hetero":
            # rank-mask the chunk's lanes BEFORE accumulation so padded
            # truncation columns contribute exactly zero even if a decoder
            # ever writes junk past a lane's true rank (decode pads with
            # zeros, so this is a defended no-op on the honest path)
            rk = self.buffers.chunk_ranks(round_id, chunk_index)
            if rk is not None:
                rkd = jnp.asarray(rk, jnp.int32)
                for s in self.specs:
                    am, bm = _mask_factor_stacks(
                        stacks[s.key + "/a"], stacks[s.key + "/b"], rkd)
                    stacks[s.key + "/a"], stacks[s.key + "/b"] = am, bm
        wd = jnp.asarray(w, jnp.float32)
        prog = self._programs.get(("fold",), self._build_fold, self.rec)
        new_acc = prog(acc, stacks, wd)
        self._note_peak(round_id, _tree_bytes(stacks) + _tree_bytes(new_acc)
                        + int(wd.nbytes))
        return new_acc

    def _check_ingest_weights(self, entry, w: np.ndarray, round_id) -> float:
        """Chunked closes weight at INGEST — verify the streamed raw weights
        normalise to the close-time weight vector, and return their sum W.
        A mismatch means chunks were folded under one weighting and the close
        was asked for another: the accumulators are already wrong, so this
        raises instead of silently corrupting the fold."""
        raw = np.asarray(entry["w"], np.float64)
        wsum = float(raw.sum())
        if wsum <= 0.0:
            raise ValueError("chunked close: total ingest weight is 0")
        for cid, slot in entry["written"].items():
            want = float(w[slot]) if slot < len(w) else 0.0
            got = raw[slot] / wsum
            if not np.isclose(got, want, rtol=1e-4, atol=1e-6):
                raise ValueError(
                    f"chunked close of round {round_id!r}: client {cid}'s "
                    f"ingest weight normalises to {got:.6g} but the close "
                    f"was given {want:.6g} — stream and close must use the "
                    "same weighting (and the same delivered set)")
        return wsum

    # -- chunked finalize programs --------------------------------------
    def _build_finalize(self):
        """fedex/reinit chunked finalize: normalise the accumulators, form
        the residual (fedex) or ideal (reinit) and fold into W0. Divergence
        comes free from the dense residual: ‖Σŵ·ab − (Σŵa)(Σŵb)‖_F/√(mn) —
        the INGEST-weighted convention (equal to the stacked close's
        uniform-over-delivered metric whenever ingest weights are uniform)."""
        specs, scale, method = self.specs, self.scale, self.method

        def _fin(w0_leaves, acc, winv):
            new_w0, glob, parts = {}, {}, []
            for s in specs:
                ga = acc["ga/" + s.key] * winv
                gb = acc["gb/" + s.key] * winv
                mean_prod = acc["prod/" + s.key] * winv
                res = mean_prod - jnp.matmul(ga, gb)
                upd = mean_prod if method == "reinit" else res
                new_w0[s.key] = (w0_leaves[s.key].astype(jnp.float32)
                                 + scale * upd).astype(s.w0_dtype)
                if method == "fedex":
                    glob[s.key] = {"a": ga, "b": gb}
                m, n = s.a_shape[-2], s.b_shape[-1]
                fro = jnp.sqrt(jnp.maximum(
                    jnp.sum(res * res, axis=(-2, -1)), 0.0)) / np.sqrt(m * n)
                parts.append(fro.ravel())
            div = jnp.concatenate(parts).mean() if parts else jnp.float32(0)
            return new_w0, glob, div

        donate = (0, 1) if self._donate and not _CPU else ()
        return jax.jit(_fin, donate_argnums=donate)

    def _build_kl_finalize(self):
        """keep_local chunked finalize, part 1: the shared ideal update
        Σŵ·ab / W plus the divergence (same residual identity as above)."""
        specs = self.specs

        def _fin(acc, winv):
            ideal, parts = {}, []
            for s in specs:
                ga = acc["ga/" + s.key] * winv
                gb = acc["gb/" + s.key] * winv
                mp_ = acc["prod/" + s.key] * winv
                ideal[s.key] = mp_
                res = mp_ - jnp.matmul(ga, gb)
                m, n = s.a_shape[-2], s.b_shape[-1]
                parts.append((jnp.sqrt(jnp.maximum(
                    jnp.sum(res * res, axis=(-2, -1)), 0.0))
                    / np.sqrt(m * n)).ravel())
            div = jnp.concatenate(parts).mean() if parts else jnp.float32(0)
            return ideal, div

        return jax.jit(_fin)

    def _build_kl_chunk(self):
        """keep_local chunked finalize, part 2 — one chunk of lanes: every
        lane's own base gets W0_c + scale·(ideal − a_c b_c). Op-for-op the
        stacked jnp branch restricted to this chunk's lanes, so chunked
        keep_local closes stay bitwise twins on exactly-representable data."""
        specs, scale = self.specs, self.scale

        def _klc(w0c, stacks, ideal):
            out = {}
            for s in specs:
                a = stacks[s.key + "/a"].astype(jnp.float32)
                b = stacks[s.key + "/b"].astype(jnp.float32)
                own = jnp.matmul(a, b)
                out[s.key] = (w0c[s.key].astype(jnp.float32)
                              + scale * (ideal[s.key][None] - own)
                              ).astype(s.w0_dtype)
            return out

        donate = (0,) if self._donate and not _CPU else ()
        return jax.jit(_klc, donate_argnums=donate)

    def _build_svd_gram(self):
        """fedex_svd chunked, stage 1: the (i, j) chunk-pair Gram blocks
        G_L[i,j] = L_iᵀ L_j and G_R[i,j] = R_i R_jᵀ — tiles of the exact
        stacked (C·r)² Grams, accumulated pair-wise so no more than two
        chunks' factors are ever resident at once. The dense m×n residual
        still never exists."""
        specs = self.specs

        def _gram(ci, cj, wi, wj, bbar):
            gl, gr = {}, {}
            for s in specs:
                li = _l_block(ci[s.key + "/a"], wi)
                lj = _l_block(cj[s.key + "/a"], wj)
                ri = _r_block(ci[s.key + "/b"], bbar[s.key])
                rj = _r_block(cj[s.key + "/b"], bbar[s.key])
                gl[s.key] = jnp.einsum("...mi,...mj->...ij", li, lj)
                gr[s.key] = jnp.einsum("...in,...jn->...ij", ri, rj)
            return gl, gr

        return jax.jit(_gram)

    def _build_svd_core(self):
        """fedex_svd chunked, stage 2: the eigh/eigh/svd core on the
        assembled Grams — identical math to factored_truncated_residual, but
        returning the UNSCALED projection operators (and the top singular
        values separately) so stage 3 can stream chunks through them."""
        specs, rank = self.specs, self.svd_rank

        def _core(gl, gr):
            out = {}
            for s in specs:
                el, vl = jnp.linalg.eigh(gl[s.key])
                er, vr = jnp.linalg.eigh(gr[s.key])
                il, sl = _safe_inv_sqrt(el)
                ir, sr = _safe_inv_sqrt(er)
                core = sl[..., :, None] * (jnp.swapaxes(vl, -1, -2) @ vr) \
                    * sr[..., None, :]
                u, sv, vt = jnp.linalg.svd(core, full_matrices=False)
                projl = (vl * il[..., None, :]) @ u[..., :, :rank]
                projr = vt[..., :rank, :] @ jnp.swapaxes(
                    vr * ir[..., None, :], -1, -2)
                out[s.key] = (projl, sv[..., :rank], projr)
            return out

        return jax.jit(_core)

    def _build_svd_proj(self):
        """fedex_svd chunked, stage 3 — one chunk: accumulate its block of
        A' = Σ_k L_k projL_k and B' = Σ_k projR_k R_k (slot order again)."""
        specs = self.specs

        def _proj(stacks, w, projl_i, projr_i, bbar, ap, bp):
            new_ap, new_bp = {}, {}
            for s in specs:
                li = _l_block(stacks[s.key + "/a"], w)
                ri = _r_block(stacks[s.key + "/b"], bbar[s.key])
                new_ap[s.key] = ap[s.key] + li @ projl_i[s.key]
                new_bp[s.key] = bp[s.key] + projr_i[s.key] @ ri
            return new_ap, new_bp

        donate = (5, 6) if self._donate and not _CPU else ()
        return jax.jit(_proj, donate_argnums=donate)

    def _build_svd_fin(self):
        """fedex_svd chunked, stage 4: scale A' by the singular values (the
        stacked close's op order), fold the rank-r' product into W0, and read
        the divergence off the Grams: ‖ΔW‖²_F = Σ_ij G_L∘G_R."""
        specs, scale = self.specs, self.scale

        def _fin(w0_leaves, ap, sr, bp, acc, winv, gl, gr):
            new_w0, glob, parts = {}, {}, []
            for s in specs:
                apr = ap[s.key] * sr[s.key][..., None, :]
                new_w0[s.key] = (w0_leaves[s.key].astype(jnp.float32)
                                 + scale * jnp.matmul(apr, bp[s.key])
                                 ).astype(s.w0_dtype)
                glob[s.key] = {"a": acc["ga/" + s.key] * winv,
                               "b": acc["gb/" + s.key] * winv}
                fro_sq = jnp.maximum(jnp.einsum(
                    "...ij,...ij->...", gl[s.key], gr[s.key]), 0.0)
                m, n = s.a_shape[-2], s.b_shape[-1]
                parts.append((jnp.sqrt(fro_sq) / np.sqrt(m * n)).ravel())
            div = jnp.concatenate(parts).mean() if parts else jnp.float32(0)
            return new_w0, glob, div

        donate = (0,) if self._donate and not _CPU else ()
        return jax.jit(_fin, donate_argnums=donate)

    def _build_hetero_core(self):
        """hetero chunked, stage 2: the eigh/eigh/svd core on the
        UNCENTERED Grams at each spec's template rank r_max —
        factored_truncated_product's math, returning the unscaled
        projection operators (and singular values separately) so stage 3
        can stream chunks through them."""
        specs = self.specs

        def _core(gl, gr):
            out = {}
            for s in specs:
                rank = s.a_shape[-1]
                el, vl = jnp.linalg.eigh(gl[s.key])
                er, vr = jnp.linalg.eigh(gr[s.key])
                il, sl = _safe_inv_sqrt(el)
                ir, sr = _safe_inv_sqrt(er)
                core = sl[..., :, None] * (jnp.swapaxes(vl, -1, -2) @ vr) \
                    * sr[..., None, :]
                u, sv, vt = jnp.linalg.svd(core, full_matrices=False)
                projl = (vl * il[..., None, :]) @ u[..., :, :rank]
                projr = vt[..., :rank, :] @ jnp.swapaxes(
                    vr * ir[..., None, :], -1, -2)
                out[s.key] = (projl, sv[..., :rank], projr)
            return out

        return jax.jit(_core)

    def _build_hetero_fin(self):
        """hetero chunked, stage 3½: the balanced √s split of the streamed
        projections — factored_truncated_product's final op order, so the
        chunked factors match the stacked close's convention."""
        specs = self.specs

        def _fin(ap0, sr, bp0):
            ap, bp = {}, {}
            for s in specs:
                sq = jnp.sqrt(jnp.maximum(sr[s.key], 0.0))
                ap[s.key] = ap0[s.key] * sq[..., None, :]
                bp[s.key] = sq[..., :, None] * bp0[s.key]
            return ap, bp

        return jax.jit(_fin)

    def _build_hetero_chunk(self):
        """hetero chunked, stage 4 — one chunk of lanes: every lane's own
        base gets W0_c + scale·(ideal − (A'∘mask_c) B'), where mask_c zeroes
        the shared truncation's columns past the lane's true rank — the
        leading-slice Eckart–Young truncation, computed without slicing so
        one program serves every rank in the fleet."""
        specs, scale = self.specs, self.scale

        def _hc(w0c, masks, ap, bp, ideal):
            out = {}
            for s in specs:
                r = s.a_shape[-1]
                mk = masks[:, :r]
                shaped = mk.reshape(
                    (mk.shape[0],) + (1,) * (ap[s.key].ndim - 1) + (r,))
                own = jnp.matmul(ap[s.key][None] * shaped, bp[s.key][None])
                out[s.key] = (w0c[s.key].astype(jnp.float32)
                              + scale * (ideal[s.key][None] - own)
                              ).astype(s.w0_dtype)
            return out

        donate = (0,) if self._donate and not _CPU else ()
        return jax.jit(_hc, donate_argnums=donate)

    # -- chunked closes --------------------------------------------------
    def _slot_weights(self, entry, w) -> np.ndarray:
        """Slot-indexed NORMALISED weights (the cross-check already proved
        they match the close-time vector; use the close-time values so the
        L blocks equal the stacked close's columns)."""
        nslots = entry["num_chunks"] * self.buffers.chunk
        wn = np.zeros(nslots, np.float32)
        ncopy = min(len(w), nslots)
        wn[:ncopy] = np.asarray(w, np.float32)[:ncopy]
        return wn

    def _pairwise_grams(self, entry, wn, bbar, round_id):
        """(i, j) chunk-pair Gram tiles assembled into the full (C·r)²
        Grams — shared by the fedex_svd (centered) and hetero (uncentered;
        zero ``bbar``) chunked closes. At most two chunks' factors are ever
        resident at once; the dense m×n residual still never exists."""
        chunk, nk = self.buffers.chunk, entry["num_chunks"]
        dev = {}

        def _chunk_dev(k):
            if k not in dev:
                dev.clear()  # at most ONE cached chunk besides the current
                dev[k] = {p: jnp.asarray(x)
                          for p, x in entry["retained"][k].items()}
            return dev[k]

        gram = self._programs.get(("svdgram",), self._build_svd_gram,
                                  self.rec)
        blocks = [[None] * nk for _ in range(nk)]
        for i in range(nk):
            ci = {p: jnp.asarray(x) for p, x in entry["retained"][i].items()}
            wi = jnp.asarray(wn[i * chunk:(i + 1) * chunk])
            for j in range(i + 1):
                cj = ci if j == i else _chunk_dev(j)
                wj = wi if j == i else jnp.asarray(
                    wn[j * chunk:(j + 1) * chunk])
                blocks[i][j] = gram(ci, cj, wi, wj, bbar)
        # assemble the full Grams from the block tiles (Gram symmetry gives
        # the upper triangle as transposes)
        gl_full, gr_full = {}, {}
        for s in self.specs:
            rows_l, rows_r = [], []
            for i in range(nk):
                row_l, row_r = [], []
                for j in range(nk):
                    if j <= i:  # computed pair: blocks[i][j] IS G(i, j)
                        bl, br = blocks[i][j]
                        row_l.append(bl[s.key])
                        row_r.append(br[s.key])
                    else:  # mirror: G(i, j) = G(j, i)ᵀ (Gram symmetry)
                        bl, br = blocks[j][i]
                        row_l.append(jnp.swapaxes(bl[s.key], -1, -2))
                        row_r.append(jnp.swapaxes(br[s.key], -1, -2))
                rows_l.append(jnp.concatenate(row_l, axis=-1))
                rows_r.append(jnp.concatenate(row_r, axis=-1))
            gl_full[s.key] = jnp.concatenate(rows_l, axis=-2)
            gr_full[s.key] = jnp.concatenate(rows_r, axis=-2)
        return gl_full, gr_full

    def _stream_projection(self, entry, wn, proj_ops, bbar, rank_of):
        """Stream every retained chunk through the projection operators:
        A' = Σ_k L_k projL_k and B' = Σ_k projR_k R_k (slot order).
        ``rank_of(spec)`` is the truncation width — ``svd_rank`` for
        fedex_svd, the template r_max for hetero."""
        chunk, nk = self.buffers.chunk, entry["num_chunks"]
        proj = self._programs.get(("svdproj",), self._build_svd_proj,
                                  self.rec)
        ap = {s.key: jnp.zeros(s.a_shape[:-1] + (rank_of(s),), jnp.float32)
              for s in self.specs}
        bp = {s.key: jnp.zeros(s.b_shape[:-2] + (rank_of(s), s.b_shape[-1]),
                               jnp.float32) for s in self.specs}
        for i in range(nk):
            ci = {p: jnp.asarray(x) for p, x in entry["retained"][i].items()}
            wi = jnp.asarray(wn[i * chunk:(i + 1) * chunk])
            projl_i, projr_i = {}, {}
            for s in self.specs:
                projl, _sv, projr = proj_ops[s.key]
                cr = chunk * s.a_shape[-1]
                projl_i[s.key] = projl[..., i * cr:(i + 1) * cr, :]
                projr_i[s.key] = projr[..., :, i * cr:(i + 1) * cr]
            ap, bp = proj(ci, wi, projl_i, projr_i, bbar, ap, bp)
        return ap, bp

    def _svd_chunked(self, w0_leaves, entry, w, winv, round_id):
        """Orchestrate the four svd stages over the retained chunks. Memory:
        at most two chunks' factors + the (C·r)² Grams are live — the Grams
        dominate exactly as in the stacked close (they ARE the method), but
        the full (C, …) factor stacks never materialise on device."""
        acc = entry["acc"]
        bbar = {s.key: acc["gb/" + s.key] * winv for s in self.specs}
        wn = self._slot_weights(entry, w)
        gl_full, gr_full = self._pairwise_grams(entry, wn, bbar, round_id)
        gram_bytes = _tree_bytes(gl_full) + _tree_bytes(gr_full)
        self._note_peak(round_id, 2 * gram_bytes + _tree_bytes(acc))
        core = self._programs.get(("svdcore",), self._build_svd_core,
                                  self.rec)
        proj_ops = core(gl_full, gr_full)
        ap, bp = self._stream_projection(entry, wn, proj_ops, bbar,
                                         lambda s: self.svd_rank)
        self._note_peak(round_id, gram_bytes + _tree_bytes(ap)
                        + _tree_bytes(bp) + _tree_bytes(w0_leaves)
                        + _tree_bytes(acc))
        sr = {s.key: proj_ops[s.key][1] for s in self.specs}
        fin = self._programs.get(("svdfin",), self._build_svd_fin, self.rec)
        return fin(w0_leaves, ap, sr, bp, acc, jnp.float32(winv),
                   gl_full, gr_full)

    def _chunked_obs(self, round_id, entry, t0) -> None:
        """Mirror _dispatch's per-close metrics for the chunked path."""
        rec = self.rec
        if not rec.enabled:
            return
        dispatch_us = (time.perf_counter_ns() - t0) / 1e3
        rec.hist("engine.close_dispatch_us").observe(dispatch_us)
        if round_id is not None:
            rec.round_set(round_id, method=self.method, chunked=1,
                          close_dispatch_us=round(dispatch_us, 1),
                          partial_folds=entry["eager_folds"],
                          ring_occupancy=len(self.buffers.open_rounds),
                          ring_evictions=self.buffers.evictions,
                          stale_drops=self.buffers.stale_drops,
                          replay_drops=self.buffers.replay_drops,
                          duplicate_drops=self.buffers.duplicate_drops)

    def _close_chunked(self, params: Params, client_ids: Sequence[int],
                       weights: Optional[Sequence[float]], *,
                       round_id, rng: Optional[jax.Array]
                       ) -> Tuple[Params, Params, DeferredDivergence]:
        """Chunked fedex / fedex_svd / reinit close: flush the trailing
        chunks in slot order, normalise the streamed accumulators by the
        total ingest weight, and finalize — the full (C, …) stacks never
        exist on device, so peak close memory is O(chunk) + accumulators
        (+ the (C·r)² Grams for fedex_svd, which needs them regardless)."""
        w, _mask, _uniform = self.weight_vector(client_ids, weights, round_id)
        rid, entry = self.buffers.take_chunked(round_id)
        wsum = self._check_ingest_weights(entry, w, rid)
        winv = jnp.float32(1.0 / np.float32(wsum))
        w0_leaves = self._w0_leaves(params)
        t0 = time.perf_counter_ns()
        with self.rec.span("close.dispatch", cat="engine", round=rid,
                           method=self.method, uniform=False, chunked=True):
            if self.method == "fedex_svd":
                new_w0, glob, div = self._svd_chunked(w0_leaves, entry, w,
                                                      winv, rid)
            else:
                self._note_peak(rid, _tree_bytes(w0_leaves)
                                + _tree_bytes(entry["acc"])
                                + self._prod_temp_bytes())
                fin = self._programs.get(("cfin", self.method),
                                         self._build_finalize, self.rec)
                new_w0, glob, div = fin(w0_leaves, entry["acc"], winv)
        self._chunked_obs(rid, entry, t0)
        self._finish_peak(rid)
        new_params = self._fold_back(params, new_w0)
        if self.method == "reinit":
            global_lora = agg.reinit_adapters(self._lora_template, rng)
        else:
            flat = {}
            for s in self.specs:
                flat[s.key + "/a"] = glob[s.key]["a"]
                flat[s.key + "/b"] = glob[s.key]["b"]
            global_lora = unflatten_from_paths(flat)
        return global_lora, new_params, DeferredDivergence(
            div, rid, recorder=self.rec if self.rec.enabled else None)

    def _close_keep_local_chunked(self, client_params: Sequence[Params],
                                  client_ids: Sequence[int],
                                  weights: Optional[Sequence[float]], *,
                                  round_id
                                  ) -> Tuple[Dict[int, Params],
                                             DeferredDivergence]:
        """Chunked keep_local close: one shared ideal from the accumulators,
        then each retained chunk's lanes fold their OWN bases chunk-by-chunk
        in slot order — peak memory holds one chunk of per-lane W0s instead
        of all C_max of them."""
        w, _mask, _uniform = self.weight_vector(client_ids, weights, round_id)
        lanes = self.buffers.lanes(round_id)
        lane_to_cid = {lane: cid for cid, lane in lanes.items()}
        delivered = set(client_ids)
        rid, entry = self.buffers.take_chunked(round_id)
        self._check_ingest_weights(entry, w, rid)
        wsum = float(np.asarray(entry["w"], np.float64).sum())
        winv = jnp.float32(1.0 / np.float32(wsum))
        chunk = self.buffers.chunk
        t0 = time.perf_counter_ns()
        out: Dict[int, Params] = {}
        with self.rec.span("close.dispatch", cat="engine", round=rid,
                           method=self.method, uniform=False, chunked=True):
            fin = self._programs.get(("klfin",), self._build_kl_finalize,
                                     self.rec)
            ideal, div = fin(entry["acc"], winv)
            klc = self._programs.get(("klchunk",), self._build_kl_chunk,
                                     self.rec)
            for k in range(entry["num_chunks"]):
                rows = [lane_to_cid.get(k * chunk + row)
                        for row in range(chunk)]
                if not any(cid in delivered for cid in rows
                           if cid is not None):
                    continue
                w0c = {}
                for s in self.specs:
                    leaves = []
                    for cid in rows:
                        p = (client_params[cid] if cid is not None
                             else client_params[0])
                        node = _get_path(p, s.key)
                        leaves.append(node["kernel"] if s.has_kernel
                                      else node)
                    w0c[s.key] = jnp.stack(leaves)
                stacks = {p: jnp.asarray(x)
                          for p, x in entry["retained"][k].items()}
                self._note_peak(rid, _tree_bytes(ideal) + _tree_bytes(w0c)
                                + _tree_bytes(stacks)
                                + _tree_bytes(entry["acc"]))
                new_chunk = klc(w0c, stacks, ideal)
                for row, cid in enumerate(rows):
                    if cid is None or cid not in delivered:
                        continue
                    newp = client_params[cid]
                    for s in self.specs:
                        leaf = new_chunk[s.key][row]
                        if s.has_kernel:
                            node = dict(_get_path(client_params[cid], s.key),
                                        kernel=leaf)
                            newp = _set_path(newp, s.key, node)
                        else:
                            newp = _set_path(newp, s.key, leaf)
                    out[cid] = newp
        self._chunked_obs(rid, entry, t0)
        self._finish_peak(rid)
        return out, DeferredDivergence(
            div, rid, recorder=self.rec if self.rec.enabled else None)

    # -- hetero helpers --------------------------------------------------
    def _client_rank(self, cid: int) -> int:
        """Client ``cid``'s TRUE adapter rank (template r_max when no
        per-client spec was registered)."""
        rmax = self.specs[0].a_shape[-1]
        if self.client_ranks is None:
            return rmax
        return int(self.client_ranks[cid])

    def _rank_vector(self, client_ids, lanes) -> np.ndarray:
        """(C_max,) int32 slot-indexed rank vector for the delivered set —
        0 on non-delivered lanes (fully masked), the registered true rank on
        delivered ones. Rides in the close's ``mask`` argument slot."""
        ranks = np.zeros(self.c_max, np.int32)
        for cid in client_ids:
            ranks[lanes[cid]] = self._client_rank(cid)
        return ranks

    def _writeback_lane(self, client_params, cid, new_stacks, lane):
        """Client ``cid``'s params with lane ``lane`` of the per-lane W0
        output stacks folded back in (keep_local/hetero write-back)."""
        newp = client_params[cid]
        for s in self.specs:
            leaf = new_stacks[s.key][lane]
            if s.has_kernel:
                node = dict(_get_path(client_params[cid], s.key),
                            kernel=leaf)
                newp = _set_path(newp, s.key, node)
            else:
                newp = _set_path(newp, s.key, leaf)
        return newp

    def _hetero_loras(self, glob_flat, client_ids, ranks, lanes
                      ) -> Dict[int, Params]:
        """Per-client rank-r_i adapters: the LEADING slices of the shared
        r_max truncation factors (the balanced √s split makes the leading-
        r_i slice the Eckart–Young rank-r_i truncation of the same mean)."""
        out: Dict[int, Params] = {}
        for cid in client_ids:
            r_i = int(ranks[lanes[cid]])
            flat = {}
            for s in self.specs:
                flat[s.key + "/a"] = glob_flat[s.key + "/a"][..., :, :r_i]
                flat[s.key + "/b"] = glob_flat[s.key + "/b"][..., :r_i, :]
            out[cid] = unflatten_from_paths(flat)
        return out

    def _close_hetero_chunked(self, client_params: Sequence[Params],
                              client_ids: Sequence[int],
                              weights: Optional[Sequence[float]], *,
                              round_id
                              ) -> Tuple[Dict[int, Params],
                                         Dict[int, Params], Params,
                                         DeferredDivergence]:
        """Chunked hetero close: ideal + divergence from the streamed
        accumulators (ingest-weighted convention, as every chunked close),
        the shared r_max truncation from UNCENTERED pairwise chunk Grams
        (``_pairwise_grams`` with a zero centering vector — dense m×n never
        formed), then each retained chunk's lanes fold their OWN bases with
        rank-masked truncations, one chunk of per-lane W0s resident at a
        time."""
        w, _mask, _uniform = self.weight_vector(client_ids, weights,
                                                round_id)
        lanes = self.buffers.lanes(round_id)
        lane_to_cid = {lane: cid for cid, lane in lanes.items()}
        delivered = set(client_ids)
        ranks = self._rank_vector(client_ids, lanes)
        rmax = self.specs[0].a_shape[-1]
        rid, entry = self.buffers.take_chunked(round_id)
        wsum = self._check_ingest_weights(entry, w, rid)
        winv = jnp.float32(1.0 / np.float32(wsum))
        chunk = self.buffers.chunk
        # lane rank masks over ALL slots (chunks may pad past C_max)
        nslots = entry["num_chunks"] * chunk
        slot_ranks = np.zeros(nslots, np.int32)
        slot_ranks[:len(ranks)] = ranks
        rmask = (np.arange(rmax)[None, :]
                 < slot_ranks[:, None]).astype(np.float32)
        t0 = time.perf_counter_ns()
        out: Dict[int, Params] = {}
        with self.rec.span("close.dispatch", cat="engine", round=rid,
                           method=self.method, uniform=False, chunked=True):
            fin = self._programs.get(("klfin",), self._build_kl_finalize,
                                     self.rec)
            ideal, div = fin(entry["acc"], winv)
            zero_bbar = {s.key: jnp.zeros(s.b_shape, jnp.float32)
                         for s in self.specs}
            wn = self._slot_weights(entry, w)
            gl_full, gr_full = self._pairwise_grams(entry, wn, zero_bbar,
                                                    rid)
            self._note_peak(rid, 2 * (_tree_bytes(gl_full)
                                      + _tree_bytes(gr_full))
                            + _tree_bytes(entry["acc"]))
            core = self._programs.get(("hcore",), self._build_hetero_core,
                                      self.rec)
            proj_ops = core(gl_full, gr_full)
            ap0, bp0 = self._stream_projection(entry, wn, proj_ops,
                                               zero_bbar,
                                               lambda s: s.a_shape[-1])
            hfin = self._programs.get(("hfin",), self._build_hetero_fin,
                                      self.rec)
            sr = {s.key: proj_ops[s.key][1] for s in self.specs}
            ap, bp = hfin(ap0, sr, bp0)
            hc = self._programs.get(("hchunk",), self._build_hetero_chunk,
                                    self.rec)
            for k in range(entry["num_chunks"]):
                rows = [lane_to_cid.get(k * chunk + row)
                        for row in range(chunk)]
                if not any(cid in delivered for cid in rows
                           if cid is not None):
                    continue
                w0c = {}
                for s in self.specs:
                    leaves = []
                    for cid in rows:
                        p = (client_params[cid] if cid is not None
                             else client_params[0])
                        node = _get_path(p, s.key)
                        leaves.append(node["kernel"] if s.has_kernel
                                      else node)
                    w0c[s.key] = jnp.stack(leaves)
                masks = jnp.asarray(rmask[k * chunk:(k + 1) * chunk])
                self._note_peak(rid, _tree_bytes(ideal) + _tree_bytes(w0c)
                                + _tree_bytes(ap) + _tree_bytes(bp)
                                + _tree_bytes(entry["acc"]))
                new_chunk = hc(w0c, masks, ap, bp, ideal)
                for row, cid in enumerate(rows):
                    if cid is None or cid not in delivered:
                        continue
                    out[cid] = self._writeback_lane(
                        client_params, cid, new_chunk, row)
        self._chunked_obs(rid, entry, t0)
        self._finish_peak(rid)
        glob_flat = {}
        for s in self.specs:
            glob_flat[s.key + "/a"] = ap[s.key]
            glob_flat[s.key + "/b"] = bp[s.key]
        global_lora = unflatten_from_paths(glob_flat)
        client_loras = self._hetero_loras(glob_flat, client_ids, ranks,
                                          lanes)
        return out, client_loras, global_lora, DeferredDivergence(
            div, rid, recorder=self.rec if self.rec.enabled else None)

    # ------------------------------------------------------------------
    def close(self, params: Params, client_ids: Sequence[int],
              weights: Optional[Sequence[float]] = None, *,
              round_id=None, rng: Optional[jax.Array] = None
              ) -> Tuple[Params, Params, DeferredDivergence]:
        """Close the round over the delivered subset (fedex / fedex_svd /
        reinit methods — keep_local closes through :meth:`close_keep_local`).

        Returns ``(global_lora, new_params, divergence)``. ``params`` W0
        leaves and the streamed stacks are donated to the close program.
        The divergence comes back as a :class:`DeferredDivergence` device
        handle — NO host sync happens inside the close; the caller resolves
        the handle at its next round boundary (or on first numeric use).
        ``reinit`` additionally needs the round's ``rng`` and returns the
        freshly drawn adapters (identical to ``aggregation.reinit_adapters``)
        as the new global.
        """
        if self.method == "keep_local":
            raise ValueError("keep_local engine closes per-client bases — "
                             "use close_keep_local()")
        if self.method == "hetero":
            raise ValueError("hetero engine closes per-client bases — "
                             "use close_hetero()")
        if self.method == "reinit" and rng is None:
            raise ValueError("reinit close needs the round's rng")
        if round_id is None and self.buffers.open_rounds:
            round_id = self.buffers.open_rounds[0]  # oldest — same as take()
        self._validate_delivered(client_ids, round_id)
        if self.buffers.is_chunked(round_id):
            return self._close_chunked(params, client_ids, weights,
                                       round_id=round_id, rng=rng)
        w, mask, uniform = self.weight_vector(client_ids, weights, round_id)
        w0_leaves = self._w0_leaves(params)
        stacks = self.buffers.take(round_id)
        new_w0, glob, div = self._dispatch(w0_leaves, stacks, w, mask,
                                           uniform, round_id)
        self._finish_peak(round_id)
        new_params = self._fold_back(params, new_w0)
        if self.method == "reinit":
            global_lora = agg.reinit_adapters(self._lora_template, rng)
        else:
            flat = {}
            for s in self.specs:
                flat[s.key + "/a"] = glob[s.key]["a"]
                flat[s.key + "/b"] = glob[s.key]["b"]
            global_lora = unflatten_from_paths(flat)
        return global_lora, new_params, DeferredDivergence(
            div, round_id, recorder=self.rec if self.rec.enabled else None)

    def close_keep_local(self, client_params: Sequence[Params],
                         client_ids: Sequence[int],
                         weights: Optional[Sequence[float]] = None, *,
                         round_id=None
                         ) -> Tuple[Dict[int, Params], DeferredDivergence]:
        """Close a keep_local round: every DELIVERED client's own base gets
        its residual Σ_j w_j·a_j b_j − a_i b_i folded in, all lanes in one
        jitted dispatch over (C_max, …)-stacked per-lane W0 buffers.

        ``client_params`` is the trainer's per-client params list (indexed by
        client id). Returns ``({client_id: new_params}, divergence)`` for the
        delivered subset only — non-delivered lanes' outputs are discarded.
        The divergence is a :class:`DeferredDivergence` (no host sync here).
        """
        if self.method != "keep_local":
            raise ValueError(f"engine method is {self.method!r}, "
                             "not keep_local")
        if round_id is None and self.buffers.open_rounds:
            round_id = self.buffers.open_rounds[0]  # oldest — same as take()
        self._validate_delivered(client_ids, round_id)
        if self.buffers.is_chunked(round_id):
            return self._close_keep_local_chunked(client_params, client_ids,
                                                  weights, round_id=round_id)
        w, mask, uniform = self.weight_vector(client_ids, weights, round_id)
        lanes = self.buffers.lanes(round_id)
        lane_to_cid = {lane: cid for cid, lane in lanes.items()}
        w0_stacks = {}
        for s in self.specs:
            leaves = []
            for lane in range(self.c_max):
                cid = lane_to_cid.get(lane)
                p = client_params[cid] if cid is not None else client_params[0]
                node = _get_path(p, s.key)
                leaves.append(node["kernel"] if s.has_kernel else node)
            w0_stacks[s.key] = jnp.stack(leaves)
        stacks = self.buffers.take(round_id)
        new_stacks, _, div = self._dispatch(w0_stacks, stacks, w, mask,
                                            uniform, round_id)
        self._finish_peak(round_id)
        out: Dict[int, Params] = {}
        for cid in client_ids:
            out[cid] = self._writeback_lane(client_params, cid, new_stacks,
                                            lanes[cid])
        return out, DeferredDivergence(
            div, round_id, recorder=self.rec if self.rec.enabled else None)

    def close_hetero(self, client_params: Sequence[Params],
                     client_ids: Sequence[int],
                     weights: Optional[Sequence[float]] = None, *,
                     round_id=None
                     ) -> Tuple[Dict[int, Params], Dict[int, Params],
                                Params, DeferredDivergence]:
        """Close a rank-heterogeneous round (the paper's §6 open question,
        engine-side): ONE shared rank-r_max Eckart–Young truncation of the
        weighted factored mean — computed from (C·r_max)² Grams, the dense
        m×n mean never formed — then every DELIVERED client's own base
        absorbs ΔW_i = Δ̄ − a'_i b'_i, where (a'_i, b'_i) is the LEADING
        rank-r_i slice of the shared factors (the balanced √s split makes
        that slice the optimal rank-r_i truncation). Every client then
        satisfies W0_i + ΔW_i + a'_i b'_i = W0 + Δ̄ exactly.

        ``client_params`` is the trainer's per-client params list (indexed
        by client id); ranks come from the engine's ``client_ranks``
        registry (template r_max when unset). Returns
        ``({cid: new_params}, {cid: rank-r_i lora}, global_lora,
        divergence)`` — the global is the shared r_max truncation, the
        divergence a :class:`DeferredDivergence` (no host sync here).
        """
        if self.method != "hetero":
            raise ValueError(f"engine method is {self.method!r}, "
                             "not hetero")
        if round_id is None and self.buffers.open_rounds:
            round_id = self.buffers.open_rounds[0]  # oldest — same as take()
        self._validate_delivered(client_ids, round_id)
        if self.buffers.is_chunked(round_id):
            return self._close_hetero_chunked(client_params, client_ids,
                                              weights, round_id=round_id)
        w, mask, uniform = self.weight_vector(client_ids, weights, round_id)
        lanes = self.buffers.lanes(round_id)
        lane_to_cid = {lane: cid for cid, lane in lanes.items()}
        ranks = self._rank_vector(client_ids, lanes)
        rmax = self.specs[0].a_shape[-1]
        # the bitwise-stable uniform branch additionally needs every
        # delivered lane at full rank (no masking anywhere)
        uniform = uniform and bool(np.all(ranks == rmax))
        w0_stacks = {}
        for s in self.specs:
            leaves = []
            for lane in range(self.c_max):
                cid = lane_to_cid.get(lane)
                p = (client_params[cid] if cid is not None
                     else client_params[0])
                node = _get_path(p, s.key)
                leaves.append(node["kernel"] if s.has_kernel else node)
            w0_stacks[s.key] = jnp.stack(leaves)
        stacks = self.buffers.take(round_id)
        new_stacks, glob, div = self._dispatch(w0_stacks, stacks, w, ranks,
                                               uniform, round_id)
        self._finish_peak(round_id)
        out: Dict[int, Params] = {}
        for cid in client_ids:
            out[cid] = self._writeback_lane(client_params, cid, new_stacks,
                                            lanes[cid])
        glob_flat = {}
        for s in self.specs:
            glob_flat[s.key + "/a"] = glob[s.key]["a"]
            glob_flat[s.key + "/b"] = glob[s.key]["b"]
        global_lora = unflatten_from_paths(glob_flat)
        client_loras = self._hetero_loras(glob_flat, client_ids, ranks,
                                          lanes)
        return out, client_loras, global_lora, DeferredDivergence(
            div, round_id, recorder=self.rec if self.rec.enabled else None)
