"""Federated fine-tuning driver (paper §4.2 pipeline, host-orchestrated).

Simulates the paper's cross-silo setting: k clients, each doing ``local_steps``
of AdamW on its LoRA adapters per round, followed by server aggregation
(fedex / fedit / ffa / fedex_svd / centralized) and — for FedEx — the residual
fold-in ``W0 ← W0 + (α/r)·ΔW_res`` (Eq. 14).

Round *orchestration* is delegated to the fedsrv coordinator (fedsrv/): the
trainer injects ``train_fn`` (one client's local steps, DP, keep_local base
selection) and the coordinator decides WHO runs and WHAT arrives — client
sampling, seeded dropout/stragglers, deadlines, uplink quantization, async
buffered commits. The seed behavior (all k clients, uniform weights, no
transport) is exactly the coordinator's trivial policy, bit-for-bit. The
trainer then dispatches the method-specific CLOSE (aggregation + residual
fold) over the delivered subset with the round's weights.

This is the *reference orchestration*: one process, clients sequential, every
client step jit'd. The mesh-parallel launcher (launch/mesh_train.py, via
``launch/train.py --mode mesh``) vmaps clients over a mesh axis and replaces
the host-side tree arithmetic with collectives — both paths run the SAME
close program over the same aggregation math (core/aggregation.py).

Overlap-aware closes: when the fused engine is on, the round close returns
its §6 divergence as a ``DeferredDivergence`` DEVICE handle — the trainer
records it un-synced and resolves it at the NEXT round boundary, so the
close's dispatch returns immediately and the RoundBuffers ring can stream
round N+1 uplinks while round N's close executes.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, LoRAConfig, TrainConfig
from repro.core import aggregation as agg
from repro.core.divergence import mean_deviation
from repro.core.lora import init_lora
from repro.optim import adamw_update, clip_by_global_norm, init_adamw, lr_at
from repro.util.logging import get_logger

logger = get_logger("federated")


def _freeze_a(grads):
    return agg.map_factors(lambda f: {"a": jnp.zeros_like(f["a"]), "b": f["b"]}, grads)


def make_local_step(model, lora_scale: float, train_cfg: TrainConfig,
                    freeze_a: bool = False) -> Callable:
    @jax.jit
    def step(params, lora, opt_state, batch, lr):
        def loss_fn(l):
            return model.loss(params, batch, lora=l, lora_scale=lora_scale)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora)
        if freeze_a:
            grads = _freeze_a(grads)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        lora, opt_state = adamw_update(
            grads, opt_state, lora, learning_rate=lr,
            beta1=train_cfg.beta1, beta2=train_cfg.beta2, eps=train_cfg.eps,
            weight_decay=train_cfg.weight_decay)
        return lora, opt_state, loss, gnorm

    return step


def make_eval_fn(model, lora_scale: float) -> Callable:
    @jax.jit
    def ev(params, lora, batch):
        loss, metrics = model.loss(params, batch, lora=lora, lora_scale=lora_scale)
        return metrics["loss"], metrics["accuracy"]

    return ev


@dataclass
class RoundRecord:
    round: int
    client_losses: List[float]
    eval_loss: float
    eval_acc: float
    # FedIT-vs-ideal deviation of this round's adapters. On the engine path
    # this briefly holds a core/engine.DeferredDivergence device handle; the
    # trainer swaps in the float at the next round boundary (run() never
    # returns records with unresolved handles).
    divergence_scaled: float
    lr: float


def evaluate_on_batches(eval_fn, params, lora,
                        batches) -> tuple[float, float]:
    """Mean (loss, accuracy) of ``eval_fn`` over ``batches`` (NaNs when
    empty). Shared by the host and mesh trainers."""
    if not batches:
        return float("nan"), float("nan")
    ls, accs = [], []
    for b in batches:
        l, a = eval_fn(params, lora, b)
        ls.append(float(l))
        accs.append(float(a))
    return sum(ls) / len(ls), sum(accs) / len(accs)


def resolve_divergences(history: List["RoundRecord"]) -> None:
    """Round-boundary host sync: swap any DeferredDivergence handles in the
    history for their float values. This is the ONLY place a trainer blocks
    on a close's device scalar — the close itself returns without a host
    transfer, so the ring's next-round uplink decoding overlaps the
    in-flight close on accelerators. Shared by the host and mesh trainers."""
    from repro.core.engine import DeferredDivergence

    for rec in history:
        if isinstance(rec.divergence_scaled, DeferredDivergence):
            rec.divergence_scaled = rec.divergence_scaled.resolve()


@dataclass
class FederatedTrainer:
    model: Any
    lora_cfg: LoRAConfig
    fed_cfg: FedConfig
    train_cfg: TrainConfig
    client_loaders: List[Any]
    eval_batches: List[Dict] = field(default_factory=list)
    seed: int = 0
    # obs recorder (repro.obs). None → built from fed_cfg.obs; pass a shared
    # Recorder to collect several trainer runs into one trace/metrics stream
    # (examples/coordinator_sim.py does this, one run label per scenario).
    recorder: Any = None

    def __post_init__(self):
        import dataclasses as _dc

        if self.recorder is None:
            from repro.obs import make_recorder
            self.recorder = make_recorder(self.fed_cfg.obs)

        rng = jax.random.key(self.seed)
        rp, rl = jax.random.split(rng)
        self.params = self.model.init(rp)
        self.global_lora = init_lora(rl, self.params, self.model.cfg, self.lora_cfg)
        if not self.global_lora:
            raise ValueError("no LoRA targets matched — check target_modules")
        self.scale = self.lora_cfg.scale
        self.method = self.fed_cfg.method
        freeze = self.method == "ffa"
        self.local_step = make_local_step(self.model, self.scale, self.train_cfg,
                                          freeze_a=freeze)
        self.eval_fn = make_eval_fn(self.model, self.scale)
        self.history: List[RoundRecord] = []
        # fedsrv RoundOutcome per standard round; adapter payloads are kept
        # only on the LAST entry (older rounds have delivery.lora stripped)
        self.outcomes: List[Any] = []
        # keep_local assignment needs per-client frozen bases
        self.client_params: Optional[List] = None
        if self.fed_cfg.assignment == "keep_local" and self.method == "fedex":
            self.client_params = [self.params for _ in range(self.fed_cfg.num_clients)]
        self._global_step = 0
        self._total_steps = self.fed_cfg.rounds * self.fed_cfg.local_steps
        self._last_div = 0.0
        self._start_round = 0  # advanced by load_state (crash-safe resume)
        # heterogeneous ranks (beyond-paper; core/hetero.py + engine
        # method="hetero"): per-client adapters of rank rᵢ + per-client
        # frozen bases for the residual fold. ``method="hetero"`` without
        # explicit ranks runs every client at lora.rank (uniform hetero).
        self.hetero = bool(self.fed_cfg.client_ranks) or self.method == "hetero"
        if self.hetero:
            self.client_ranks = list(self.fed_cfg.client_ranks) or (
                [self.lora_cfg.rank] * self.fed_cfg.num_clients)
            assert len(self.client_ranks) == self.fed_cfg.num_clients
            self._client_lora = [
                init_lora(jax.random.fold_in(rl, i), self.params, self.model.cfg,
                          _dc.replace(self.lora_cfg, rank=r))
                for i, r in enumerate(self.client_ranks)]
            self.client_params = [self.params] * self.fed_cfg.num_clients
        from repro.configs.base import validate_fed_lora
        validate_fed_lora(self.fed_cfg, self.lora_cfg)
        self.coordinator = self._build_coordinator()
        # fused round-close engine (core/engine.py): every engine-covered
        # method — fedex with any §6 assignment (average / keep_local /
        # reinit), fedex_svd, and the ragged-rank hetero close — runs in ONE
        # jitted program over streamed (C_max, …) stacks. Everything else
        # (fedit/ffa/centralized) keeps the eager list-of-trees ground truth.
        self.engine = None
        eng_method = None
        if self.fed_cfg.engine != "off":
            if self.hetero:
                # ragged uplinks pad to r_max = lora.rank at ingest; the
                # close masks each lane back to its true rank
                eng_method = "hetero"
            elif self.method == "fedex":
                eng_method = {"average": "fedex",
                              "keep_local": "keep_local",
                              "reinit": "reinit"}[self.fed_cfg.assignment]
            elif self.method == "fedex_svd":
                # svd_rank=0 means exact (config contract) → the fedex close
                eng_method = "fedex_svd" if self.fed_cfg.svd_rank else "fedex"
        if eng_method is not None:
            from repro.core.engine import RoundCloseEngine
            self.engine = RoundCloseEngine(
                self.params, self.global_lora,
                c_max=self.fed_cfg.num_clients, scale=self.scale,
                method=eng_method, svd_rank=self.fed_cfg.svd_rank,
                backend=self.fed_cfg.engine,
                depth=self.fed_cfg.ring_depth,
                recorder=self.recorder,
                chunk=self.fed_cfg.close_chunk,
                client_ranks=self.client_ranks if self.hetero else None)
            self.coordinator.sink = self.engine.buffers

    def _build_coordinator(self):
        """fedsrv coordinator from FedConfig; defaults = the trivial policy
        (all clients, no deadline/dropout, uniform weights, fp32 transport),
        which reproduces the seed's hard-coded loop bit-for-bit."""
        from repro.fedsrv import (AdapterCodec, AsyncBufferCoordinator,
                                  BytesLedger, ClientInfo, ClientRegistry,
                                  RoundCoordinator, RoundPolicy,
                                  StragglerModel, ValidationPolicy)

        fc = self.fed_cfg
        clients = [
            ClientInfo(client_id=i, num_examples=len(
                self.client_loaders[i % len(self.client_loaders)].sequences))
            for i in range(fc.num_clients)]
        registry = ClientRegistry(clients, seed=fc.seed)
        policy = RoundPolicy(participation=fc.participation,
                             min_quorum=fc.min_quorum,
                             deadline=fc.round_deadline,
                             weighting=fc.weighting)
        stragglers = StragglerModel(
            mean_latency=fc.mean_latency, jitter=fc.latency_jitter,
            dropout_prob=fc.dropout_prob, straggler_prob=fc.straggler_prob,
            straggler_factor=fc.straggler_factor, seed=fc.seed)
        codec = AdapterCodec(fc.quantize_uplink,
                             validation=ValidationPolicy(
                                 enabled=fc.uplink_validation,
                                 max_norm=fc.uplink_max_norm))
        self.ledger = BytesLedger()
        # seeded fault-injection layer (fedsrv/faults.py): exercised only
        # when a fault plan is configured — the clean path carries a None
        # injector and is bitwise-unchanged.
        self._chaos = bool(fc.faults)
        self.fault_injector = None
        if self._chaos:
            from repro.fedsrv.faults import FaultInjector, FaultPlan
            self.fault_injector = FaultInjector(
                FaultPlan.parse(fc.faults, seed=fc.seed),
                recorder=self.recorder)
        if fc.async_buffer > 0:
            return AsyncBufferCoordinator(
                registry, policy, stragglers, codec, self.ledger,
                buffer_size=fc.async_buffer,
                staleness_alpha=fc.staleness_alpha,
                max_version_lag=fc.ring_max_lag,
                recorder=self.recorder, faults=self.fault_injector,
                uplink_retries=fc.uplink_retries,
                retry_backoff=fc.retry_backoff)
        return RoundCoordinator(registry, policy, stragglers, codec,
                                self.ledger, recorder=self.recorder,
                                faults=self.fault_injector,
                                uplink_retries=fc.uplink_retries,
                                retry_backoff=fc.retry_backoff)

    # ------------------------------------------------------------------
    def _close_round(self, rnd: int, outcome, client_loras: List, weights):
        """Method-specific round close over the delivered subset (weighted)."""
        if self.engine is not None:
            # fused single-dispatch close: weighted factor means + the
            # method-specific residual fold + divergence in one jitted
            # program over the streamed stacks (W0 leaves and stacks
            # donated). No dense m×n residual tree ever exists host-side —
            # the svd close truncates on the factored Grams, the assignment
            # closes fold through the signed/per-client kernels.
            rid = outcome.round_id
            if self.engine.method == "keep_local":
                new_cp, self._last_div = self.engine.close_keep_local(
                    self.client_params, outcome.client_ids, weights,
                    round_id=rid)
                for cid, lora_i in zip(outcome.client_ids, client_loras):
                    self._client_lora[cid] = lora_i
                    self.client_params[cid] = new_cp[cid]
                self.global_lora = client_loras[0]
                return
            rng = (jax.random.key(self.seed + rnd)
                   if self.engine.method == "reinit" else None)
            self.global_lora, self.params, self._last_div = self.engine.close(
                self.params, outcome.client_ids, weights, round_id=rid,
                rng=rng)
            # ledger the truncation rank clamped to the delivered subset's
            # bound k_d·r — singular triplets past it are identically zero
            # and never transmitted (mirrors the eager path's clamp)
            k_d = len(outcome.client_ids)
            self._ledger_residual(
                rnd, None, k_d,
                truncated_rank=(min(self.engine.svd_rank,
                                    self.lora_cfg.rank * k_d)
                                if self.engine.method == "fedex_svd" else 0),
                leaf_shapes=[s.w0_shape for s in self.engine.specs])
            return
        k_d = len(client_loras)
        if self.method == "fedit":
            self.global_lora = agg.fedit_aggregate(client_loras, weights)
        elif self.method == "ffa":
            self.global_lora = agg.ffa_aggregate(client_loras, weights)
        elif self.method == "fedex_svd":
            # clamp to the DELIVERED subset's rank bound k_d·r: config-time
            # validation bounds r' by k·r only, and ranks past the bound are
            # pure padding (fedex_svd_aggregate rejects them).
            svd_rank = min(self.fed_cfg.svd_rank or self.lora_cfg.rank * k_d,
                           self.lora_cfg.rank * k_d)
            self.global_lora, residual = agg.fedex_svd_aggregate(
                client_loras, svd_rank, weights)
            self.params = agg.apply_residual(self.params, residual, self.scale)
            self._ledger_residual(rnd, residual, k_d, truncated_rank=svd_rank)
        elif self.method == "fedex":
            if self.fed_cfg.assignment == "average":
                self.global_lora, residual = agg.fedex_aggregate(
                    client_loras, weights)
                self.params = agg.apply_residual(self.params, residual, self.scale)
                self._ledger_residual(rnd, residual, k_d)
            elif self.fed_cfg.assignment == "reinit":
                new_loras, residual = agg.assign_after_aggregation(
                    "reinit", client_loras, jax.random.key(self.seed + rnd),
                    weights)
                self.global_lora = new_loras[0]
                self.params = agg.apply_residual(self.params, residual, self.scale)
                self._ledger_residual(rnd, residual, k_d)
            elif self.fed_cfg.assignment == "keep_local":
                residuals = agg.per_client_residuals(client_loras, weights)
                for cid, lora_i, res_i in zip(outcome.client_ids, client_loras,
                                              residuals):
                    self._client_lora[cid] = lora_i
                    self.client_params[cid] = agg.apply_residual(
                        self.client_params[cid], res_i, self.scale)
                self.global_lora = client_loras[0]
            else:
                raise ValueError(self.fed_cfg.assignment)
        else:
            raise ValueError(f"unknown method {self.method!r}")

    def _ledger_residual(self, rnd: int, residual, k_delivered: int,
                         truncated_rank: int = 0,
                         leaf_shapes: Optional[List[tuple]] = None) -> None:
        """Account the server→client residual broadcast in the bytes ledger
        (factored form of core/decompose.py, never the dense m×n matrix).
        ``leaf_shapes`` replaces ``residual`` on the engine path, where no
        dense residual tree exists — only the adapted W0 leaf shapes."""
        import numpy as np

        from repro.core.decompose import (factored_residual_params,
                                          truncated_residual_params)

        if leaf_shapes is None:
            leaf_shapes = [leaf.shape for leaf in jax.tree.leaves(residual)]
        per_client = 0
        for shape in leaf_shapes:
            if len(shape) < 2:
                continue
            copies = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
            m, n = int(shape[-2]), int(shape[-1])
            if truncated_rank:
                per_client += copies * truncated_residual_params(
                    m, n, truncated_rank)
            else:
                per_client += copies * factored_residual_params(
                    m, n, self.lora_cfg.rank, k_delivered)
        self.ledger.record_analytic(rnd, "downlink", per_client * k_delivered,
                                    note="factored-residual broadcast")

    # ------------------------------------------------------------------
    def _reconcile_comm(self, rnd: int, outcome) -> None:
        """Surface the round's measured ledger totals as round metrics and —
        where the analytic table applies — reconcile the measured param
        counts against ``core/comm.round_comm_params`` pinned to the
        OBSERVED delivered-client count. The measured ledger and the closed
        form are independent accountings of the same round; ``comm_match``
        is the per-round witness that they agree."""
        rec = self.recorder
        tot = self.ledger.round_totals(rnd)
        rec.round_set(rnd,
                      uplink_params=tot["uplink_params"],
                      uplink_bytes=tot["uplink_bytes"],
                      downlink_params=tot["downlink_params"],
                      downlink_bytes=tot["downlink_bytes"])
        k_d = len(outcome.delivered)
        if k_d == 0:
            return
        method = self.method
        if method == "fedex" and self.fed_cfg.assignment != "average":
            return  # keep_local/reinit ledger differs from the table's fedex
        if method not in ("fedex", "fedit", "fedex_svd"):
            return
        from repro.core.comm import adapted_matrices, round_comm_params
        from repro.util.tree import count_params
        try:
            mats = adapted_matrices(self.model.cfg, self.lora_cfg)
        except (AttributeError, TypeError):
            return  # model without a decoder-style config: no analytic twin
        r = self.lora_cfg.rank
        if count_params(self.global_lora) != sum(ms.m * r + r * ms.n
                                                 for ms in mats):
            return  # adapter layout ≠ the table's matrix set (e.g. subset)
        eff, svd = method, self.fed_cfg.svd_rank
        if method == "fedex_svd" and not svd:
            eff = "fedex"  # svd_rank=0 → the exact close (config contract)
        analytic = round_comm_params(
            eff, mats, r, self.fed_cfg.num_clients,
            svd_rank=min(svd, r * k_d) if svd else 0,
            participants=k_d)
        recon = self.ledger.reconcile(rnd, analytic)
        rec.round_set(rnd, comm_match=int(recon["ok"]))
        rec.counter(f"comm.reconcile_{'ok' if recon['ok'] else 'mismatch'}"
                    ).inc()
        if not recon["ok"]:
            rec.event("comm.mismatch", cat="trainer", round=rnd,
                      uplink=recon["uplink"], downlink=recon["downlink"])

    # ------------------------------------------------------------------
    def _client_round(self, client: int, params, lora):
        loader = self.client_loaders[client % len(self.client_loaders)]
        opt_state = init_adamw(lora)
        losses = []
        # uneven budgets: client c stops after its own step count (mesh mode
        # expresses the same schedule as masked scan iterations)
        steps = (self.fed_cfg.client_local_steps[client]
                 if self.fed_cfg.client_local_steps
                 else self.fed_cfg.local_steps)
        for s in range(steps):
            batch = loader.next_batch()
            lr = lr_at(self._global_step + s, base_lr=self.train_cfg.learning_rate,
                       total_steps=self._total_steps,
                       warmup_ratio=self.train_cfg.warmup_ratio,
                       kind=self.train_cfg.schedule)
            lora, opt_state, loss, gnorm = self.local_step(params, lora, opt_state,
                                                           batch, lr)
            losses.append(float(loss))
        return lora, losses

    def _evaluate(self, params, lora) -> tuple[float, float]:
        return evaluate_on_batches(self.eval_fn, params, lora,
                                   self.eval_batches)

    def _resolve_divergences(self) -> None:
        resolve_divergences(self.history)

    # ------------------------------------------------------------------
    def run(self, until: Optional[int] = None) -> List[RoundRecord]:
        """Run rounds ``[_start_round, until)`` (default: all configured
        rounds). ``until`` gives tests a deterministic kill point: run part
        of a checkpointed schedule, then resume a fresh trainer via
        :meth:`load_state` — the LR schedule and every seeded draw key off
        absolute round/step indices, so the resumed half replays bitwise."""
        k = self.fed_cfg.num_clients
        stop = self.fed_cfg.rounds if until is None else until
        from repro.core.engine import DeferredDivergence

        for rnd in range(self._start_round, stop):
            lr_now = float(lr_at(self._global_step, base_lr=self.train_cfg.learning_rate,
                                 total_steps=self._total_steps,
                                 kind=self.train_cfg.schedule,
                                 warmup_ratio=self.train_cfg.warmup_ratio))

            if self.hetero and self.engine is not None:
                from repro.core.hetero import pad_adapters

                # engine-side ragged close: every client's rank-rᵢ adapter
                # pads to the r_max template at ingest (exact — zero columns)
                # and streams into the ring with its TRUE rank riding the
                # slot's rank vector; close_hetero masks each lane back to
                # rᵢ inside the jitted program and folds each client's own
                # residual into ITS frozen base.
                rid = self.engine.buffers.begin_round(
                    {c: c for c in range(k)}, rnd)
                client_losses = []
                delivered = []
                if self.fault_injector is not None:
                    # chaos: ragged uplinks ride the SAME defended codec
                    # path as the uniform methods — encode → corrupt →
                    # decode_into — so crashes DROP the lane and validation
                    # failures QUARANTINE it; the close runs over the
                    # surviving subset and a lost lane contributes nothing.
                    self.coordinator._ensure_spec(self.global_lora)
                for c in range(k):
                    lora_c, losses = self._client_round(
                        c, self.client_params[c], self._client_lora[c])
                    client_losses.append(losses[-1])
                    padded = pad_adapters(lora_c, self.lora_cfg.rank)
                    if self.fault_injector is None:
                        self.engine.buffers.write(
                            c, padded, round_id=rid,
                            rank=self.client_ranks[c])
                        delivered.append(c)
                        continue
                    res = self.coordinator._uplink(
                        padded, rid, c, rank=self.client_ranks[c])
                    if res.ok:
                        delivered.append(c)
                # round boundary: previous rounds' deferred divergences
                # resolve only after this round's uplinks streamed in
                self._resolve_divergences()
                with self.recorder.span("round.close", cat="trainer",
                                        round=rnd, engine=True):
                    new_cp, new_loras, self.global_lora, div = \
                        self.engine.close_hetero(
                            self.client_params, delivered,
                            round_id=rid)
                for c in delivered:
                    self.client_params[c] = new_cp[c]
                    self._client_lora[c] = new_loras[c]
                self._last_div = div
                if self.recorder.enabled:
                    # closed-round comm fields (obs_report --check): under
                    # chaos the defended path ledgers real uplink bytes;
                    # the direct ring path transmits nothing measurable
                    tot = self.ledger.round_totals(rnd)
                    self.recorder.round_set(
                        rnd,
                        uplink_params=tot["uplink_params"],
                        uplink_bytes=tot["uplink_bytes"],
                        downlink_params=tot["downlink_params"],
                        downlink_bytes=tot["downlink_bytes"])
            elif self.hetero:
                from repro.core.hetero import hetero_fedex_aggregate

                client_loras = []
                client_losses = []
                for c in range(k):
                    lora_c, losses = self._client_round(
                        c, self.client_params[c], self._client_lora[c])
                    client_loras.append(lora_c)
                    client_losses.append(losses[-1])
                new_loras, residuals = hetero_fedex_aggregate(
                    client_loras, list(self.client_ranks),
                    r_max=self.lora_cfg.rank)
                self._client_lora = new_loras
                self.client_params = [
                    agg.apply_residual(p, r_i, self.scale)
                    for p, r_i in zip(self.client_params, residuals)]
                self.global_lora = new_loras[0]
                # pre-agg deviation is rank-heterogeneous → report dispersion
                # of client PRODUCTS around their mean instead
                prods = [agg.product_mean([l]) for l in client_loras]
                mean_prod = jax.tree.map(lambda *xs: sum(xs) / k, *prods)
                div = float(sum(
                    float(jnp.sqrt(jnp.mean(jnp.square(a - b))))
                    for a, b in zip(jax.tree.leaves(prods[0]),
                                    jax.tree.leaves(mean_prod))))
            elif self.method == "centralized":
                # single worker sees every client's stream round-robin
                lora, losses = self._client_round(rnd % k, self.params, self.global_lora)
                self.global_lora = lora
                div = 0.0
                client_losses = [losses[-1]]
            else:
                keep_local = (self.fed_cfg.assignment == "keep_local"
                              and self.method == "fedex")
                if keep_local and not hasattr(self, "_client_lora"):
                    self._client_lora = [self.global_lora] * k
                round_losses: Dict[int, float] = {}

                def train_fn(client, start_lora, round_id, _losses=round_losses):
                    c = client.client_id
                    base = (self.client_params[c]
                            if self.client_params is not None else self.params)
                    start = self._client_lora[c] if keep_local else start_lora
                    lora_c, losses = self._client_round(c, base, start)
                    if self.fed_cfg.dp_clip > 0:
                        from repro.core.privacy import privatize_upload
                        lora_c = privatize_upload(
                            jax.random.key(hash((self.seed, round_id, c)) % 2**31),
                            lora_c, start, clip=self.fed_cfg.dp_clip,
                            noise_multiplier=self.fed_cfg.dp_noise_multiplier)
                    _losses[c] = losses[-1]
                    return lora_c

                outcome = self.coordinator.run_round(rnd, train_fn,
                                                     self.global_lora)
                # round boundary: the PREVIOUS round's deferred divergence
                # resolves only now — after this round's uplinks have already
                # streamed into the ring — so its ring.write spans genuinely
                # overlap the in-flight close's [dispatch, resolve] window
                # (the invariant scripts/obs_report.py --check proves).
                self._resolve_divergences()
                self.outcomes.append(outcome)
                # keep adapter payloads only for the latest round — otherwise
                # history retains O(rounds · k · adapter_size) of fp32 trees
                if len(self.outcomes) > 1:
                    for d in self.outcomes[-2].delivered:
                        d.lora = None
                client_loras = [d.lora for d in outcome.delivered]
                client_losses = [round_losses[c] for c in outcome.client_ids]
                weights = outcome.weights

                if not outcome.delivered or outcome.degraded:
                    # zero deliveries, or quorum failed after quarantine
                    # (degraded): carry the previous global forward — the
                    # coordinator already evicted the round's ring set.
                    logger.warning("round=%d: %s; global carried forward",
                                   rnd, "degraded" if outcome.degraded
                                   else "no deliveries")
                    div = 0.0
                    if not client_losses:
                        client_losses = [float("nan")]
                elif self.engine is not None:
                    # fused close over the streamed stacks; it also computes
                    # the divergence metric inside the same jitted program
                    # (factored Grams — no dense deviation matrix, and no
                    # eager mean_deviation tree-walk per round)
                    with self.recorder.span("round.close", cat="trainer",
                                            round=rnd, engine=True):
                        self._close_round(rnd, outcome, client_loras, weights)
                    div = self._last_div
                else:
                    div = mean_deviation(client_loras)
                    with self.recorder.span("round.close", cat="trainer",
                                            round=rnd, engine=False):
                        self._close_round(rnd, outcome, client_loras, weights)
                if self.recorder.enabled:
                    self._reconcile_comm(rnd, outcome)

            self._global_step += self.fed_cfg.local_steps
            eval_params = (self.client_params[0] if self.client_params is not None
                           else self.params)
            eval_lora = (self._client_lora[0] if hasattr(self, "_client_lora")
                         else self.global_lora)
            with self.recorder.span("round.eval", cat="trainer", round=rnd,
                                    batches=len(self.eval_batches)):
                ev_loss, ev_acc = self._evaluate(eval_params, eval_lora)
            if self.recorder.enabled:
                self.recorder.round_set(rnd, eval_loss=round(ev_loss, 6),
                                        eval_acc=round(ev_acc, 6))
            if self.recorder.enabled and self._chaos:
                # chaos witness: the quarantine wall held — no poisoned
                # uplink leaked a non-finite value into the served adapter
                import numpy as _np
                finite = all(
                    bool(_np.isfinite(_np.asarray(x, _np.float32)).all())
                    for x in jax.tree.leaves(eval_lora))
                self.recorder.round_set(rnd, global_finite=int(finite))
            rec = RoundRecord(round=rnd, client_losses=client_losses,
                              eval_loss=ev_loss, eval_acc=ev_acc,
                              divergence_scaled=div, lr=lr_now)
            self.history.append(rec)
            deferred = (isinstance(div, DeferredDivergence)
                        and not div.resolved)
            logger.info(
                "round=%d method=%s eval_loss=%.4f eval_acc=%.4f div=%s "
                "client_loss=%.4f", rnd, self.method, ev_loss, ev_acc,
                "deferred" if deferred else f"{float(div):.3e}",
                sum(client_losses) / len(client_losses))
            if (self.fed_cfg.checkpoint_dir
                    and (rnd + 1) % self.fed_cfg.checkpoint_every == 0):
                from repro.checkpoint import round_state_path
                self.save_state(
                    round_state_path(self.fed_cfg.checkpoint_dir))
            # a completed round never re-runs: run(until=k) then run()
            # continues in-process exactly where load_state would resume
            self._start_round = rnd + 1
        # final boundary: no record leaves run() with an unresolved handle
        self._resolve_divergences()
        return self.history

    # ------------------------------------------------------------------
    # crash-safe round state (checkpoint/): a run killed between rounds
    # resumes from the last saved boundary and replays the remaining rounds
    # BITWISE against an uninterrupted run (tests/test_checkpoint_resume.py).
    def save_state(self, path: str) -> None:
        """Snapshot the full round boundary: model + adapters, coordinator
        clock, bytes ledger, loader iterator states, ring contents, and the
        async buffer (version / in-flight / snapshots). Forces the
        round-boundary host sync first — no deferred divergence handle
        survives into the file."""
        import dataclasses as _dc

        from repro.checkpoint import save_checkpoint

        self._resolve_divergences()
        tree: Dict[str, Any] = {"params": self.params,
                                "global": self.global_lora}
        if self.client_params is not None:
            tree["cparams"] = {str(i): p
                               for i, p in enumerate(self.client_params)}
        if hasattr(self, "_client_lora"):
            tree["clora"] = {str(i): l
                             for i, l in enumerate(self._client_lora)}
        meta: Dict[str, Any] = {
            "next_round": len(self.history),
            "global_step": self._global_step,
            "last_div": float(self._last_div),
            "clock": self.coordinator.clock.state_dict(),
            "ledger": self.ledger.state_dict(),
            "loaders": [ld.state_dict() for ld in self.client_loaders],
            "history": [_dc.asdict(r) for r in self.history],
        }
        if self.engine is not None:
            ring_meta, ring_arrays = self.engine.buffers.state_dict()
            meta["ring"] = ring_meta
            if ring_arrays:
                tree["ringarr"] = ring_arrays
        co = self.coordinator
        if hasattr(co, "_version"):  # FedBuff async buffered coordinator
            meta["async"] = {
                "version": co._version,
                "inflight": [[c.client_id, t, v] for t, c, v in co._inflight],
                "snapshot_versions": sorted(co._snapshots),
            }
            tree["snap"] = {str(v): co._snapshots[v] for v in co._snapshots}
        save_checkpoint(path, tree, meta)
        logger.info("round state saved: %s (next_round=%d)", path,
                    meta["next_round"])

    def load_state(self, path: str) -> None:
        """Restore a :meth:`save_state` snapshot into a freshly-constructed
        trainer (same configs). ``run()`` then continues from the saved
        boundary. RoundOutcome payloads are deliberately not checkpointed —
        ``outcomes`` restarts empty on a resumed run."""
        from repro.checkpoint import load_checkpoint
        from repro.util.tree import flatten_with_paths

        tree, meta = load_checkpoint(path)
        self.params = tree["params"]
        self.global_lora = tree["global"]
        if "cparams" in tree:
            cp = tree["cparams"]
            self.client_params = [cp[str(i)] for i in range(len(cp))]
        if "clora" in tree:
            cl = tree["clora"]
            self._client_lora = [cl[str(i)] for i in range(len(cl))]
        self._start_round = int(meta["next_round"])
        self._global_step = int(meta["global_step"])
        self._last_div = float(meta["last_div"])
        self.coordinator.clock.load_state(meta["clock"])
        self.ledger.load_state(meta["ledger"])
        for ld, st in zip(self.client_loaders, meta["loaders"]):
            ld.load_state(st)
        self.history = [RoundRecord(**r) for r in meta["history"]]
        self.outcomes = []
        if self.engine is not None and "ring" in meta:
            ring_arrays = (flatten_with_paths(tree["ringarr"])
                           if "ringarr" in tree else {})
            self.engine.buffers.load_state(meta["ring"], ring_arrays)
        if "async" in meta:
            co, st = self.coordinator, meta["async"]
            co._version = int(st["version"])
            co._inflight = [(float(t), co.registry.get(int(cid)), int(v))
                            for cid, t, v in st["inflight"]]
            snap = tree.get("snap", {})
            co._snapshots = {int(v): snap[str(v)]
                             for v in st["snapshot_versions"]}
        logger.info("round state loaded: %s (resuming at round %d)", path,
                    self._start_round)
