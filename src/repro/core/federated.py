"""Federated fine-tuning driver (paper §4.2 pipeline, host-orchestrated).

Simulates the paper's cross-silo setting: k clients, each doing ``local_steps``
of AdamW on its LoRA adapters per round, followed by server aggregation
(fedex / fedit / ffa / fedex_svd / centralized) and — for FedEx — the residual
fold-in ``W0 ← W0 + (α/r)·ΔW_res`` (Eq. 14).

This is the *reference orchestration*: one process, clients sequential, every
client step jit'd. The mesh-parallel launcher (launch/train.py) vmaps clients
over a mesh axis and replaces the host-side tree arithmetic with collectives —
both paths call the SAME aggregation operators from core/aggregation.py.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, LoRAConfig, TrainConfig
from repro.core import aggregation as agg
from repro.core.divergence import mean_deviation
from repro.core.lora import init_lora
from repro.optim import adamw_update, clip_by_global_norm, init_adamw, lr_at
from repro.util.logging import get_logger

logger = get_logger("federated")


def _freeze_a(grads):
    return agg.map_factors(lambda f: {"a": jnp.zeros_like(f["a"]), "b": f["b"]}, grads)


def make_local_step(model, lora_scale: float, train_cfg: TrainConfig,
                    freeze_a: bool = False) -> Callable:
    @jax.jit
    def step(params, lora, opt_state, batch, lr):
        def loss_fn(l):
            return model.loss(params, batch, lora=l, lora_scale=lora_scale)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora)
        if freeze_a:
            grads = _freeze_a(grads)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        lora, opt_state = adamw_update(
            grads, opt_state, lora, learning_rate=lr,
            beta1=train_cfg.beta1, beta2=train_cfg.beta2, eps=train_cfg.eps,
            weight_decay=train_cfg.weight_decay)
        return lora, opt_state, loss, gnorm

    return step


def make_eval_fn(model, lora_scale: float) -> Callable:
    @jax.jit
    def ev(params, lora, batch):
        loss, metrics = model.loss(params, batch, lora=lora, lora_scale=lora_scale)
        return metrics["loss"], metrics["accuracy"]

    return ev


@dataclass
class RoundRecord:
    round: int
    client_losses: List[float]
    eval_loss: float
    eval_acc: float
    divergence_scaled: float  # FedIT-vs-ideal deviation of this round's adapters
    lr: float


@dataclass
class FederatedTrainer:
    model: Any
    lora_cfg: LoRAConfig
    fed_cfg: FedConfig
    train_cfg: TrainConfig
    client_loaders: List[Any]
    eval_batches: List[Dict] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        import dataclasses as _dc

        rng = jax.random.key(self.seed)
        rp, rl = jax.random.split(rng)
        self.params = self.model.init(rp)
        self.global_lora = init_lora(rl, self.params, self.model.cfg, self.lora_cfg)
        if not self.global_lora:
            raise ValueError("no LoRA targets matched — check target_modules")
        self.scale = self.lora_cfg.scale
        self.method = self.fed_cfg.method
        freeze = self.method == "ffa"
        self.local_step = make_local_step(self.model, self.scale, self.train_cfg,
                                          freeze_a=freeze)
        self.eval_fn = make_eval_fn(self.model, self.scale)
        self.history: List[RoundRecord] = []
        # keep_local assignment needs per-client frozen bases
        self.client_params: Optional[List] = None
        if self.fed_cfg.assignment == "keep_local" and self.method == "fedex":
            self.client_params = [self.params for _ in range(self.fed_cfg.num_clients)]
        self._global_step = 0
        self._total_steps = self.fed_cfg.rounds * self.fed_cfg.local_steps
        # heterogeneous ranks (beyond-paper; core/hetero.py): per-client
        # adapters of rank rᵢ + per-client frozen bases for the residual fold.
        self.hetero = bool(self.fed_cfg.client_ranks)
        if self.hetero:
            assert len(self.fed_cfg.client_ranks) == self.fed_cfg.num_clients
            self._client_lora = [
                init_lora(jax.random.fold_in(rl, i), self.params, self.model.cfg,
                          _dc.replace(self.lora_cfg, rank=r))
                for i, r in enumerate(self.fed_cfg.client_ranks)]
            self.client_params = [self.params] * self.fed_cfg.num_clients

    # ------------------------------------------------------------------
    def _client_round(self, client: int, params, lora):
        loader = self.client_loaders[client % len(self.client_loaders)]
        opt_state = init_adamw(lora)
        losses = []
        for s in range(self.fed_cfg.local_steps):
            batch = loader.next_batch()
            lr = lr_at(self._global_step + s, base_lr=self.train_cfg.learning_rate,
                       total_steps=self._total_steps,
                       warmup_ratio=self.train_cfg.warmup_ratio,
                       kind=self.train_cfg.schedule)
            lora, opt_state, loss, gnorm = self.local_step(params, lora, opt_state,
                                                           batch, lr)
            losses.append(float(loss))
        return lora, losses

    def _evaluate(self, params, lora) -> tuple[float, float]:
        if not self.eval_batches:
            return float("nan"), float("nan")
        ls, accs = [], []
        for b in self.eval_batches:
            l, a = self.eval_fn(params, lora, b)
            ls.append(float(l))
            accs.append(float(a))
        return sum(ls) / len(ls), sum(accs) / len(accs)

    # ------------------------------------------------------------------
    def run(self) -> List[RoundRecord]:
        k = self.fed_cfg.num_clients
        for rnd in range(self.fed_cfg.rounds):
            lr_now = float(lr_at(self._global_step, base_lr=self.train_cfg.learning_rate,
                                 total_steps=self._total_steps,
                                 kind=self.train_cfg.schedule,
                                 warmup_ratio=self.train_cfg.warmup_ratio))

            if self.hetero:
                from repro.core.hetero import hetero_fedex_aggregate

                client_loras = []
                client_losses = []
                for c in range(k):
                    lora_c, losses = self._client_round(
                        c, self.client_params[c], self._client_lora[c])
                    client_loras.append(lora_c)
                    client_losses.append(losses[-1])
                new_loras, residuals = hetero_fedex_aggregate(
                    client_loras, list(self.fed_cfg.client_ranks))
                self._client_lora = new_loras
                self.client_params = [
                    agg.apply_residual(p, r_i, self.scale)
                    for p, r_i in zip(self.client_params, residuals)]
                self.global_lora = new_loras[0]
                # pre-agg deviation is rank-heterogeneous → report dispersion
                # of client PRODUCTS around their mean instead
                prods = [agg.product_mean([l]) for l in client_loras]
                mean_prod = jax.tree.map(lambda *xs: sum(xs) / k, *prods)
                div = float(sum(
                    float(jnp.sqrt(jnp.mean(jnp.square(a - b))))
                    for a, b in zip(jax.tree.leaves(prods[0]),
                                    jax.tree.leaves(mean_prod))))
            elif self.method == "centralized":
                # single worker sees every client's stream round-robin
                lora, losses = self._client_round(rnd % k, self.params, self.global_lora)
                self.global_lora = lora
                div = 0.0
                client_losses = [losses[-1]]
            else:
                keep_local = (self.fed_cfg.assignment == "keep_local"
                              and self.method == "fedex")
                if keep_local and not hasattr(self, "_client_lora"):
                    self._client_lora = [self.global_lora] * k
                client_loras = []
                client_losses = []
                for c in range(k):
                    base = (self.client_params[c] if self.client_params is not None
                            else self.params)
                    start_lora = self._client_lora[c] if keep_local else self.global_lora
                    lora_c, losses = self._client_round(c, base, start_lora)
                    if self.fed_cfg.dp_clip > 0:
                        from repro.core.privacy import privatize_upload
                        lora_c = privatize_upload(
                            jax.random.key(hash((self.seed, rnd, c)) % 2**31),
                            lora_c, start_lora, clip=self.fed_cfg.dp_clip,
                            noise_multiplier=self.fed_cfg.dp_noise_multiplier)
                    client_loras.append(lora_c)
                    client_losses.append(losses[-1])

                div = mean_deviation(client_loras)

                if self.method == "fedit":
                    self.global_lora = agg.fedit_aggregate(client_loras)
                elif self.method == "ffa":
                    self.global_lora = agg.ffa_aggregate(client_loras)
                elif self.method == "fedex_svd":
                    self.global_lora, residual = agg.fedex_svd_aggregate(
                        client_loras, self.fed_cfg.svd_rank or
                        self.lora_cfg.rank * k)
                    self.params = agg.apply_residual(self.params, residual, self.scale)
                elif self.method == "fedex":
                    if self.fed_cfg.assignment == "average":
                        self.global_lora, residual = agg.fedex_aggregate(client_loras)
                        self.params = agg.apply_residual(self.params, residual, self.scale)
                    elif self.fed_cfg.assignment == "reinit":
                        new_loras, residual = agg.assign_after_aggregation(
                            "reinit", client_loras, jax.random.key(self.seed + rnd))
                        self.global_lora = new_loras[0]
                        self.params = agg.apply_residual(self.params, residual, self.scale)
                    elif self.fed_cfg.assignment == "keep_local":
                        residuals = agg.per_client_residuals(client_loras)
                        self._client_lora = client_loras
                        self.client_params = [
                            agg.apply_residual(p, r, self.scale)
                            for p, r in zip(self.client_params, residuals)]
                        self.global_lora = client_loras[0]
                    else:
                        raise ValueError(self.fed_cfg.assignment)
                else:
                    raise ValueError(f"unknown method {self.method!r}")

            self._global_step += self.fed_cfg.local_steps
            eval_params = (self.client_params[0] if self.client_params is not None
                           else self.params)
            eval_lora = (self._client_lora[0] if hasattr(self, "_client_lora")
                         else self.global_lora)
            ev_loss, ev_acc = self._evaluate(eval_params, eval_lora)
            rec = RoundRecord(round=rnd, client_losses=client_losses,
                              eval_loss=ev_loss, eval_acc=ev_acc,
                              divergence_scaled=div, lr=lr_now)
            self.history.append(rec)
            logger.info(
                "round=%d method=%s eval_loss=%.4f eval_acc=%.4f div=%.3e "
                "client_loss=%.4f", rnd, self.method, ev_loss, ev_acc, div,
                sum(client_losses) / len(client_losses))
        return self.history
