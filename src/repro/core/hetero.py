"""Heterogeneous-rank exact aggregation — beyond the paper.

Paper §6: "To extend our method to rank-heterogeneous settings, the
assignments for Aᵢ and Bᵢ must also accommodate rank heterogeneity. Further
investigation is required…". This module supplies one such scheme with the
SAME exactness guarantee as FedEx-LoRA:

1. Ragged client adapters are zero-padded to r_max = max(rᵢ) (exact: padded
   rank columns multiply to zero in every product) and the ideal update
   Δ̄ = Σᵢ wᵢ·aᵢ bᵢ is formed ONLY in factored form (L=(m, k·r_max),
   R=(k·r_max, n) — never densified until fold-in).
2. ONE shared Eckart–Young truncation at r_max is computed from L, R via the
   (k·r_max)² Gram machinery (``engine.factored_truncated_product``); client
   i (capacity rank rᵢ) receives the LEADING rᵢ columns/rows — the balanced
   √s split orders columns by singular value, so the leading slice IS the
   optimal rank-rᵢ truncation of Δ̄, every client sharing one decomposition.
3. Its residual ΔWᵢ = Δ̄ − aᵢ'bᵢ' folds into ITS copy of W0 (per-client
   fold-in, as in the paper's keep_local strategy), so every client's
   effective weights equal the ideal weighted mean of products EXACTLY:

       W0 + ΔWᵢ + aᵢ'bᵢ' = W0 + Δ̄        ∀i.

Singular-factor split: aᵢ' = U√S, bᵢ' = √S Vᵀ keeps both factors balanced
(the LoRA-friendly parameterisation).

This is the EAGER ORACLE for the engine-side hetero close
(``core/engine.py`` ``method="hetero"`` / ``RoundCloseEngine.close_hetero``):
the engine runs the same padded formulation over (C_max, …) stacks with
per-lane rank masks, and tests/test_engine_hetero.py holds the two to
bitwise (uniform ranks + weights) / ≤2 ulp (ragged) parity.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.aggregation import map_factors, normalize_weights, _is_factor

Params = Dict[str, Any]


def pad_adapters(lora: Params, r_max: int) -> Params:
    """Zero-pad every {a, b} factor of an adapter tree to rank ``r_max``.

    Exact by construction: a's padded columns and b's padded rows only ever
    multiply each other or zero, so every product involving the padded
    adapters equals the unpadded one. This is the decode-side padding the
    engine/codec apply to ragged uplinks before they enter (C_max, …)
    stacks.
    """

    def _pad(f: Params) -> Params:
        a, b = f["a"], f["b"]
        r = a.shape[-1]
        if r == r_max:
            return {"a": a, "b": b}
        if r > r_max:
            raise ValueError(f"adapter rank {r} exceeds r_max={r_max}")
        pa = [(0, 0)] * (a.ndim - 1) + [(0, r_max - r)]
        pb = [(0, 0)] * (b.ndim - 2) + [(0, r_max - r), (0, 0)]
        return {"a": jnp.pad(a, pa), "b": jnp.pad(b, pb)}

    return map_factors(_pad, lora)


def _mean_product_factors(
    factors: List[Params],
    weights: Optional[Sequence[float]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Factored weighted mean of products: Δ̄ = L @ R.

    ``weights=None`` keeps the historical uniform ``a/k`` op order (the
    engine's bitwise-uniform branch); a weight vector multiplies each
    client's L columns instead (the engine's ragged branch op order).
    """
    k = len(factors)
    if weights is None:
        lefts = [f["a"].astype(jnp.float32) / k for f in factors]
    else:
        lefts = [w_i * f["a"].astype(jnp.float32)
                 for w_i, f in zip(weights, factors)]
    rights = [f["b"].astype(jnp.float32) for f in factors]
    return jnp.concatenate(lefts, axis=-1), jnp.concatenate(rights, axis=-2)


def hetero_fedex_aggregate(
    client_loras: List[Params],
    client_ranks: Sequence[int],
    weights: Optional[Sequence[float]] = None,
    r_max: Optional[int] = None,
) -> Tuple[List[Params], List[Params]]:
    """Returns (per-client new adapters, per-client residuals).

    ``client_loras[i]`` may have rank rᵢ ≠ rⱼ (each is zero-padded to
    r_max internally; already-padded trees pass through exactly).
    ``weights`` are optional per-client example weights (normalised here;
    ``None`` → uniform mean). ``r_max`` defaults to max(client_ranks);
    engine-parity callers pass the engine's template rank explicitly —
    decomposition numerics depend on the padded width, so matching the
    engine bitwise requires matching its r_max even when every delivered
    rank is smaller. Stacked-layer leaves batch natively — the
    Gram/eigh/svd core broadcasts over leading axes.
    """
    # late import: engine pulls no symbols from this module, so the oracle
    # can borrow its Gram-based truncation without an import cycle
    from repro.core.engine import factored_truncated_product

    k = len(client_loras)
    assert len(client_ranks) == k
    if r_max is None:
        r_max = max(int(r) for r in client_ranks)
    elif r_max < max(int(r) for r in client_ranks):
        raise ValueError(f"r_max={r_max} below max client rank")
    norm = normalize_weights(weights, k)
    if weights is not None and norm is None:
        # EXPLICIT equal weights keep the weighted op order (w·a, the
        # engine's ragged branch) rather than collapsing to the uniform a/k
        # path — callers choose the branch they want parity with
        norm = [1.0 / k] * k

    def per_matrix(*factors):
        padded = [pad_adapters(f, r_max) for f in factors]
        L, R = _mean_product_factors(padded, norm)
        ap, bp = factored_truncated_product(L, R, r_max)
        ideal = L @ R
        outs = []
        for r_i in client_ranks:
            a_new = ap[..., :, :r_i]
            b_new = bp[..., :r_i, :]
            resid = ideal - a_new @ b_new
            outs.append((a_new, b_new, resid))
        return outs

    # walk the factor tree once, collecting per-client trees
    new_loras: List[Params] = [dict() for _ in range(k)]
    residuals: List[Params] = [dict() for _ in range(k)]

    def walk(nodes, out_l, out_r):
        for key in nodes[0]:
            children = [n[key] for n in nodes]
            if _is_factor(children[0]):
                outs = per_matrix(*children)
                for i, (a_new, b_new, resid) in enumerate(outs):
                    out_l[i][key] = {"a": a_new, "b": b_new}
                    out_r[i][key] = resid
            elif isinstance(children[0], dict):
                subs_l = [o.setdefault(key, {}) for o in out_l]
                subs_r = [o.setdefault(key, {}) for o in out_r]
                walk(children, subs_l, subs_r)

    walk(client_loras, new_loras, residuals)
    return new_loras, residuals
