"""Heterogeneous-rank exact aggregation — beyond the paper.

Paper §6: "To extend our method to rank-heterogeneous settings, the
assignments for Aᵢ and Bᵢ must also accommodate rank heterogeneity. Further
investigation is required…". This module supplies one such scheme with the
SAME exactness guarantee as FedEx-LoRA:

1. Ideal update Δ̄ = mean_i(aᵢ bᵢ) is formed ONLY in factored form
   (rank ≤ Σᵢ rᵢ; `core/decompose.py` machinery — never densified server-side
   until fold-in).
2. Client i (capacity rank rᵢ) receives the Eckart–Young-optimal rank-rᵢ
   truncation (aᵢ', bᵢ') of Δ̄ — the best adapters its budget can hold.
3. Its residual ΔWᵢ = Δ̄ − aᵢ'bᵢ' folds into ITS copy of W0 (per-client
   fold-in, as in the paper's keep_local strategy), so every client's
   effective weights equal the ideal FedAvg of products EXACTLY:

       W0 + ΔWᵢ + aᵢ'bᵢ' = W0 + Δ̄        ∀i.

Singular-factor split: aᵢ' = U√S, bᵢ' = √S Vᵀ keeps both factors balanced
(the LoRA-friendly parameterisation).
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import map_factors, _is_factor
from repro.core.decompose import truncated_svd_product

Params = Dict[str, Any]


def _mean_product_factors(factors: List[Params]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Factored mean of products: Δ̄ = L @ R with L=(m, Σrᵢ), R=(Σrᵢ, n)."""
    k = len(factors)
    lefts = [f["a"].astype(jnp.float32) / k for f in factors]
    rights = [f["b"].astype(jnp.float32) for f in factors]
    return jnp.concatenate(lefts, axis=-1), jnp.concatenate(rights, axis=-2)


def hetero_fedex_aggregate(
    client_loras: List[Params],
    client_ranks: Sequence[int],
) -> Tuple[List[Params], List[Params]]:
    """Returns (per-client new adapters, per-client residuals).

    ``client_loras[i]`` may have rank rᵢ ≠ rⱼ. Stacked-layer leaves are
    handled by vmapping the per-matrix computation over leading axes.
    """
    k = len(client_loras)
    assert len(client_ranks) == k

    def per_matrix(*factors):
        def one(fs):
            L, R = _mean_product_factors(list(fs))

            outs = []
            for r_i in client_ranks:
                u, s, vt = truncated_svd_product(L, R, r_i)
                sq = jnp.sqrt(jnp.maximum(s, 0.0))
                a_new = u * sq  # (m, rᵢ)
                b_new = sq[:, None] * vt  # (rᵢ, n)
                resid = L @ R - a_new @ b_new
                outs.append((a_new, b_new, resid))
            return outs

        lead_ndim = factors[0]["a"].ndim - 2
        if lead_ndim == 0:
            return one(factors)
        # vmap over stacked-layer axes, one level at a time
        def vone(*fs_flat):
            fs = [{"a": fs_flat[2 * i], "b": fs_flat[2 * i + 1]} for i in range(k)]
            outs = one(fs)
            return tuple(x for o in outs for x in o)

        fn = vone
        for _ in range(lead_ndim):
            fn = jax.vmap(fn)
        flat = [x for f in factors for x in (f["a"], f["b"])]
        res_flat = fn(*flat)
        return [(res_flat[3 * i], res_flat[3 * i + 1], res_flat[3 * i + 2])
                for i in range(k)]

    # walk the factor tree once, collecting per-client trees
    new_loras: List[Params] = [dict() for _ in range(k)]
    residuals: List[Params] = [dict() for _ in range(k)]

    def walk(nodes, out_l, out_r):
        for key in nodes[0]:
            children = [n[key] for n in nodes]
            if _is_factor(children[0]):
                outs = per_matrix(*children)
                for i, (a_new, b_new, resid) in enumerate(outs):
                    out_l[i][key] = {"a": a_new, "b": b_new}
                    out_r[i][key] = resid
            elif isinstance(children[0], dict):
                subs_l = [o.setdefault(key, {}) for o in out_l]
                subs_r = [o.setdefault(key, {}) for o in out_r]
                walk(children, subs_l, subs_r)

    walk(client_loras, new_loras, residuals)
    return new_loras, residuals
