"""LoRA adapter state: init / target selection / merge.

Layout: factors are ``a: (..., d_in, r)``, ``b: (..., r, d_out)`` with the
adapter update ``ΔW = a @ b`` in our ``x @ W`` convention (paper mapping:
``a = Aᵀ``, ``b = Bᵀ``; see models/common.py). Standard LoRA init (paper
Eq. 10): ``a`` ~ Gaussian, ``b`` = 0, so the adapter starts as a no-op.

The adapter tree MIRRORS the parameter tree at target projections — including
the stacked layer axes introduced by scan-over-layers — so it threads through
``lax.scan`` as xs alongside the params. Targets are matched by module name
anywhere in the tree (e.g. ``q_proj``), which makes the same machinery work
for attention, MLA latents, MLPs, Mamba in/out projections and xLSTM gates.
Per-expert adapters on MoE expert tensors are behind ``lora_cfg.lora_experts``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LoRAConfig, ModelConfig

Params = Dict[str, Any]

# module names adapted per family when the user doesn't override targets
FAMILY_TARGETS = {
    "dense": ("q_proj", "k_proj", "v_proj", "o_proj"),
    "vlm": ("q_proj", "k_proj", "v_proj", "o_proj"),
    "encdec": ("q_proj", "k_proj", "v_proj", "o_proj"),
    "moe": ("q_proj", "k_proj", "v_proj", "o_proj",
            "q_down", "q_up", "kv_down", "k_up", "v_up"),
    "hybrid": ("q_proj", "k_proj", "v_proj", "o_proj", "in_proj", "out_proj"),
    "ssm": ("q_proj", "k_proj", "v_proj", "up_proj", "down_proj", "w_gates"),
}
MLP_TARGETS = ("up_proj", "gate_proj", "down_proj")


def resolve_targets(cfg: ModelConfig, lora_cfg: LoRAConfig) -> Tuple[str, ...]:
    targets = tuple(lora_cfg.target_modules)
    if targets == LoRAConfig().target_modules:  # default → family-specific
        targets = FAMILY_TARGETS[cfg.family]
    if lora_cfg.include_mlp:
        targets = tuple(dict.fromkeys(targets + MLP_TARGETS))
    return targets


def init_lora(rng, params: Params, cfg: ModelConfig, lora_cfg: LoRAConfig) -> Params:
    """Build the adapter tree mirroring ``params`` at target projections."""
    targets = set(resolve_targets(cfg, lora_cfg))
    r = lora_cfg.rank
    counter = [0]

    def fresh_rng():
        counter[0] += 1
        return jax.random.fold_in(rng, counter[0])

    def make_factor(kernel: jnp.ndarray) -> Params:
        *lead, d_in, d_out = kernel.shape
        a = jax.random.normal(fresh_rng(), (*lead, d_in, r), jnp.float32) * 0.02
        b = jnp.zeros((*lead, r, d_out), jnp.float32)
        return {"a": a, "b": b}

    def walk(node: Any) -> Optional[Params]:
        if not isinstance(node, dict):
            return None
        out = {}
        for key, child in node.items():
            if key in targets and isinstance(child, dict) and "kernel" in child:
                if child["kernel"].ndim >= 2:
                    out[key] = make_factor(child["kernel"])
            elif key == "experts" and lora_cfg.lora_experts and isinstance(child, dict):
                sub = {}
                for ek, ev in child.items():
                    if hasattr(ev, "ndim") and ev.ndim >= 3:
                        sub[ek] = make_factor(ev)
                if sub:
                    out["experts"] = sub
            elif isinstance(child, dict):
                sub = walk(child)
                if sub:
                    out[key] = sub
        return out or None

    tree = walk(params)
    return tree or {}


def merge_lora(params: Params, lora: Params, scale: float) -> Params:
    """Fold adapters into kernels: W ← W + scale·(a @ b). For eval/export."""

    def walk(p: Any, l: Any) -> Any:
        if l is None:
            return p
        if isinstance(p, dict):
            out = dict(p)
            for key, lv in l.items():
                if key not in p:
                    continue
                pv = p[key]
                if isinstance(lv, dict) and "a" in lv and "b" in lv:
                    if isinstance(pv, dict) and "kernel" in pv:
                        delta = scale * jnp.matmul(lv["a"], lv["b"])
                        out[key] = dict(pv, kernel=(pv["kernel"].astype(jnp.float32)
                                                    + delta).astype(pv["kernel"].dtype))
                    else:  # raw expert tensor
                        delta = scale * jnp.matmul(lv["a"], lv["b"])
                        out[key] = (pv.astype(jnp.float32) + delta).astype(pv.dtype)
                elif isinstance(lv, dict):
                    out[key] = walk(pv, lv)
            return out
        return p

    return walk(params, lora)


def lora_param_count(lora: Params) -> int:
    import numpy as np

    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(lora))


def zero_like_b(lora: Params) -> Params:
    """Adapter tree with b zeroed (used by the 'reinit' assignment strategy)."""
    def fn(path_leaf):
        return path_leaf

    def walk(node):
        if isinstance(node, dict) and "a" in node and "b" in node:
            return {"a": node["a"], "b": jnp.zeros_like(node["b"])}
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(lora)
