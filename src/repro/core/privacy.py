"""Differentially-private client uploads — the paper's stated future work.

Paper §7: "Testing in privacy-preserving scenarios is a natural extension of
our work. FFA-LoRA demonstrated that noise in differential privacy leads to
greater deviations from ideal updates. Given that our method achieves exact
aggregation… we anticipate similar success in privacy-sensitive applications."

We implement the upload-level mechanism used in that line of work: each
client's adapter DELTA (lora_i − lora_global) is L2-clipped to ``clip`` and
Gaussian noise N(0, σ²·clip²) is added before transmission (central-DP with
per-client sensitivity bounding; σ maps to (ε, δ) via the Gaussian mechanism
for a given number of rounds — accounting is the caller's policy choice).

The key structural point the paper predicts — and our property test verifies
(tests/test_privacy.py) — is that FedEx aggregation stays EXACT with respect
to the noised adapters: the server's residual absorbs whatever the clients
sent, noise included, so DP costs accuracy only through the noise itself, not
through an additional aggregation mismatch (FedIT pays both).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def l2_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_delta(delta: Params, clip: float) -> Tuple[Params, jnp.ndarray]:
    norm = l2_norm(delta)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        delta), norm


def gaussian_noise_like(rng, tree: Params, std: float) -> Params:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    noised = [std * jax.random.normal(k, x.shape, jnp.float32)
              for k, x in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, noised)


def privatize_upload(rng, lora_local: Params, lora_global: Params, *,
                     clip: float, noise_multiplier: float) -> Params:
    """Clip + noise the adapter delta; returns the privatized local adapters.

    noise std = noise_multiplier · clip (per coordinate, Gaussian mechanism).
    """
    delta = jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                         lora_local, lora_global)
    delta, _ = clip_delta(delta, clip)
    noise = gaussian_noise_like(rng, delta, noise_multiplier * clip)
    return jax.tree.map(lambda g, d, n: (g.astype(jnp.float32) + d + n).astype(g.dtype),
                        lora_global, delta, noise)
