from repro.data.synthetic import SyntheticLM, make_batch_for
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.loader import ClientLoader

__all__ = [
    "SyntheticLM",
    "make_batch_for",
    "dirichlet_partition",
    "iid_partition",
    "ClientLoader",
]
