"""Per-client batching over a materialised corpus (host-side, numpy)."""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp


class ClientLoader:
    """Infinite shuffled batch iterator over one client's sequences.

    sequences: (N, seq_len + 1) int32 — inputs are [:, :-1], targets [:, 1:].
    """

    def __init__(self, sequences: np.ndarray, batch_size: int, seed: int = 0):
        if len(sequences) == 0:
            raise ValueError("empty client shard")
        self.sequences = sequences
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(sequences))
        self._cursor = 0

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        n = len(self.sequences)
        idx = []
        while len(idx) < self.batch_size:
            if self._cursor >= n:
                self._order = self.rng.permutation(n)
                self._cursor = 0
            take = min(self.batch_size - len(idx), n - self._cursor)
            idx.extend(self._order[self._cursor : self._cursor + take].tolist())
            self._cursor += take
        seqs = self.sequences[np.asarray(idx)]
        return {
            "tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
            "targets": jnp.asarray(seqs[:, 1:], jnp.int32),
            "loss_mask": jnp.ones(seqs[:, 1:].shape, jnp.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()

    # -- checkpoint/resume (crash-safe round state) ------------------------
    def state_dict(self) -> Dict:
        """Json-able iterator state: a resumed run must draw the exact same
        batch sequence as an uninterrupted one (bitwise round parity)."""
        return {
            "rng": self.rng.bit_generator.state,
            "order": self._order.tolist(),
            "cursor": self._cursor,
        }

    def load_state(self, state: Dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._order = np.asarray(state["order"], dtype=np.int64)
        self._cursor = int(state["cursor"])
