"""Client data partitioning: IID and Dirichlet non-IID task mixtures.

The paper samples client data "at random" (§5 implementation details) in the
3-client cross-silo setting; we additionally support Dirichlet-α non-IID task
mixtures (the standard federated benchmark protocol, [62] in the paper) since
aggregation error is most visible under heterogeneity.
"""

from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(num_items: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(num_items)
    return [np.sort(part) for part in np.array_split(idx, num_clients)]


def dirichlet_partition(task_labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0) -> List[np.ndarray]:
    """Split item indices so each client's task mixture ~ Dirichlet(alpha).

    task_labels: (N,) int task id per item. Smaller alpha → more skew.
    """
    rng = np.random.default_rng(seed)
    num_tasks = int(task_labels.max()) + 1
    client_bins: List[List[int]] = [[] for _ in range(num_clients)]
    for t in range(num_tasks):
        items = np.where(task_labels == t)[0]
        rng.shuffle(items)
        props = rng.dirichlet(np.full(num_clients, alpha))
        # avoid empty clients: floor of one item per client when possible
        splits = (np.cumsum(props) * len(items)).astype(int)[:-1]
        for c, part in enumerate(np.split(items, splits)):
            client_bins[c].extend(part.tolist())
    out = []
    for c in range(num_clients):
        if not client_bins[c]:  # guarantee non-empty
            donor = int(np.argmax([len(b) for b in client_bins]))
            client_bins[c].append(client_bins[donor].pop())
        out.append(np.sort(np.array(client_bins[c], dtype=np.int64)))
    return out
