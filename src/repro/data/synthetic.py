"""Synthetic LM corpora for federated experiments (offline stand-in for GLUE etc.)

Each *task* is a random first-order Markov chain over the vocabulary. A corpus
is a mixture of tasks; non-IID client splits (see partition.py) give each
client a different task mixture — the setting where FedIT's inexact
aggregation visibly hurts and FedEx-LoRA's exact aggregation visibly helps.
A model can genuinely learn these corpora (bigram structure → CE well below
uniform), so convergence orderings are meaningful.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp


class SyntheticLM:
    """Markov-mixture corpus generator.

    >>> ds = SyntheticLM(vocab=64, num_tasks=4, seed=0)
    >>> seqs = ds.sample(task=1, num_sequences=8, seq_len=32)
    >>> seqs.shape
    (8, 33)
    """

    def __init__(self, vocab: int, num_tasks: int = 4, seed: int = 0,
                 concentration: float = 0.3):
        self.vocab = vocab
        self.num_tasks = num_tasks
        rng = np.random.default_rng(seed)
        # per-task transition matrices, rows ~ Dirichlet(concentration)
        self.transitions = np.stack([
            rng.dirichlet(np.full(vocab, concentration), size=vocab)
            for _ in range(num_tasks)
        ])  # (T, V, V)

    def sample(self, task: int, num_sequences: int, seq_len: int,
               seed: Optional[int] = None) -> np.ndarray:
        """Returns token ids (num_sequences, seq_len + 1) — inputs ‖ final target."""
        rng = np.random.default_rng(seed)
        p = self.transitions[task % self.num_tasks]
        out = np.empty((num_sequences, seq_len + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=num_sequences)
        # vectorised chain sampling via inverse-CDF
        cdf = np.cumsum(p, axis=-1)
        for t in range(seq_len):
            u = rng.random(num_sequences)[:, None]
            out[:, t + 1] = (u > cdf[out[:, t]]).sum(axis=-1)
        return np.clip(out, 0, self.vocab - 1)

    def to_batch(self, seqs: np.ndarray) -> Dict[str, jnp.ndarray]:
        return {
            "tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
            "targets": jnp.asarray(seqs[:, 1:], jnp.int32),
            "loss_mask": jnp.ones(seqs[:, 1:].shape, jnp.float32),
        }


def make_batch_for(cfg, batch_size: int, seq_len: int, seed: int = 0
                   ) -> Dict[str, jnp.ndarray]:
    """Random batch with the family-specific extras (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    text_len = seq_len
    batch: Dict[str, jnp.ndarray] = {}
    if cfg.family == "vlm":
        text_len = max(1, seq_len - cfg.vision_tokens)
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(batch_size, cfg.vision_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(batch_size, cfg.enc_seq_len, cfg.d_model)) * 0.02,
            jnp.float32)
    toks = rng.integers(0, cfg.vocab_size, size=(batch_size, text_len + 1))
    batch["tokens"] = jnp.asarray(toks[:, :-1], jnp.int32)
    batch["targets"] = jnp.asarray(toks[:, 1:], jnp.int32)
    batch["loss_mask"] = jnp.ones((batch_size, text_len), jnp.float32)
    return batch
