"""fedsrv — event-driven federation coordinator for FedEx-LoRA rounds.

The seed trainer (core/federated.py) hard-codes the easiest regime: one
process, all k clients every round, uniform weights, no transport. This
subsystem is the orchestration layer for everything else — partial
participation, per-client example counts, dropouts, stragglers, deadlines,
uplink quantization, and FedBuff-style buffered commits — while keeping the
paper's exactness guarantee (Eq. 11–14) over whichever *subset* of clients a
round actually delivers, with non-uniform weights wᵢ = nᵢ/Σnⱼ.

Architecture (mirrors federated.py's header conventions)::

    ClientRegistry ──sample_round(fraction, quorum)──┐
      ClientInfo(id, n_examples, speed)              │
    StragglerModel (seeded latency/dropout)          ▼
    SimClock (deterministic sim-seconds)      RoundCoordinator ──────────┐
                                              │  open round              │
        train_fn(client, lora, rnd)  ◄────────┤  schedule arrivals       │
        (injected by FederatedTrainer)        │  collect until deadline  │
                                              │    ∧ quorum              │
    AdapterCodec (none|fp16|int8) ◄──────────►│  close: weighted exact   │
      every payload crosses the codec         │    aggregation           │
    BytesLedger (measured params/bytes,       │                          │
      reconciled vs core/comm.py analytic)    └── RoundOutcome ──────────┘
                                                   delivered, weights,
    AsyncBufferCoordinator (FedBuff): commits      drops, comm totals
      buffer_size earliest arrivals; staleness
      discounts the weights; residual fold stays
      exact at every commit.

Exactness contract: ``weighted_close(outcome)`` returns (ā,b̄ averages,
ΔW_res) with Σwᵢ aᵢbᵢ = ā b̄ + ΔW_res *by construction* for any normalized
weights — folding scale·ΔW_res into W0 reproduces the weighted ideal update
over the delivered subset bit-for-bit in fp32 (tests/test_fedsrv.py).

Determinism contract: all randomness flows through
``np.random.default_rng([seed, round, client, purpose…])`` (per-purpose
streams — see registry.purpose_rng) and the simulated clock — a scenario,
fault plan included, replays identically across processes (no
PYTHONHASHSEED, no wall clock).

Fault tolerance (fedsrv/faults.py + the defended transport): a seeded
``FaultPlan`` corrupts uplinks between encode and delivery; the codec's
``ValidationPolicy`` quarantines bad content (lane weight-masked to zero —
the close stays exact over the survivors), addressing faults are dropped,
transient decode failures retry with bounded backoff, and a round starved
below quorum degrades gracefully (previous global carried forward).

Process boundary (fedsrv/server.py + fedsrv/client.py + fedsrv/wire.py):
the same defended ingest path behind a stdlib ``ThreadingHTTPServer`` —
``FedClient.submit_delta`` / ``pull_latest`` over HTTP, quarantine/stale/
retry semantics mapped onto 4xx/429 statuses, and the SimClock pinned to
wall time (``now_fn=time.monotonic``) so round deadlines mean real seconds.
"""

from repro.fedsrv.client import FedClient, PullResult
from repro.fedsrv.coordinator import (
    AsyncBufferCoordinator,
    Delivery,
    RoundCoordinator,
    RoundOutcome,
    RoundPolicy,
    UplinkResult,
    weighted_close,
)
from repro.fedsrv.faults import (
    FAULT_KINDS,
    FaultInjector,
    FaultPlan,
    FaultSpec,
)
from repro.fedsrv.server import (
    FederationHTTPServer,
    FederationServer,
    init_global_state,
    start_http_server,
    w0_digest,
)
from repro.fedsrv.registry import (
    ClientInfo,
    ClientRegistry,
    SimClock,
    StragglerModel,
    purpose_rng,
)
from repro.fedsrv.transport import (
    AdapterCodec,
    BytesLedger,
    EncodedTensor,
    LedgerEntry,
    Payload,
    StaleUplinkError,
    TransientTransportError,
    TransportError,
    ValidationPolicy,
)
from repro.fedsrv.wire import payload_from_wire, payload_to_wire

__all__ = [
    "AdapterCodec",
    "AsyncBufferCoordinator",
    "BytesLedger",
    "ClientInfo",
    "ClientRegistry",
    "Delivery",
    "EncodedTensor",
    "FAULT_KINDS",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "FedClient",
    "FederationHTTPServer",
    "FederationServer",
    "LedgerEntry",
    "Payload",
    "PullResult",
    "RoundCoordinator",
    "RoundOutcome",
    "RoundPolicy",
    "SimClock",
    "StaleUplinkError",
    "StragglerModel",
    "TransientTransportError",
    "TransportError",
    "UplinkResult",
    "ValidationPolicy",
    "init_global_state",
    "payload_from_wire",
    "payload_to_wire",
    "purpose_rng",
    "start_http_server",
    "w0_digest",
    "weighted_close",
]
