"""HTTP federation client: ``submit_delta`` / ``pull_latest`` over a socket.

The thin mirror of the server's endpoint table (fedsrv/server.py): encode
with the same :class:`AdapterCodec` the sim coordinator uses, frame with
fedsrv/wire.py, POST, and map HTTP statuses BACK onto the PR-7 transport
error taxonomy — 429/503/connection failures raise (internally)
:class:`TransientTransportError` and go through the same bounded
exponential-backoff retry loop the coordinator runs on its SimClock (real
``time.sleep`` here); 409/410 surface as :class:`StaleUplinkError`; 4xx
rejections surface as :class:`TransportError` with the server's ``reason``.
A caller that already handles the in-process codec's failures handles the
HTTP ones for free.
"""

from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from repro.fedsrv.transport import (AdapterCodec, StaleUplinkError,
                                    TransientTransportError, TransportError)
from repro.fedsrv.wire import payload_from_wire, payload_to_wire
from repro.obs import NULL
from repro.util.logging import get_logger

logger = get_logger("fedsrv.client")

#: statuses worth a bounded retry (server backpressure / transient fabric)
_RETRYABLE = frozenset({429, 503})


@dataclass(frozen=True)
class PullResult:
    """One ``GET /v1/adapters/latest`` response."""

    version: int            # closes the server has performed
    round_id: int           # round currently open server-side
    lora: Any               # decoded global adapter tree
    w0_digest: str          # sha256 over the server's folded base weights
    nbytes: int             # wire frame size (downlink accounting)


class FedClient:
    """One federated client talking to a :class:`FederationServer`.

    ``quantize`` must match what the server aggregates-as-transmitted
    (``FedConfig.quantize_uplink``); ``num_examples`` rides in the
    ``X-Fed-Examples`` header and only matters under examples weighting.
    """

    def __init__(self, base_url: str, client_id: int, *, token: str = "",
                 quantize: str = "none", num_examples: Optional[int] = None,
                 retries: int = 3, backoff: float = 0.1,
                 timeout: float = 30.0, recorder=None):
        self.base_url = base_url.rstrip("/")
        self.client_id = client_id
        self.token = token
        self.codec = AdapterCodec(quantize, recorder=recorder)
        self.num_examples = num_examples
        self.retries = retries
        self.backoff = backoff
        self.timeout = timeout
        self.rec = recorder if recorder is not None else NULL

    # -- plumbing ------------------------------------------------------------
    def _request(self, method: str, path: str, body: Optional[bytes] = None,
                 headers: Optional[Dict[str, str]] = None
                 ) -> Tuple[int, bytes, Dict[str, str]]:
        hdrs = dict(headers or {})
        if self.token:
            hdrs["Authorization"] = f"Bearer {self.token}"
        req = urllib.request.Request(self.base_url + path, data=body,
                                     headers=hdrs, method=method)
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return resp.status, resp.read(), dict(resp.headers)
        except urllib.error.HTTPError as e:
            # non-2xx WITH a response: the status is the answer, not a fault
            return e.code, e.read(), dict(e.headers or {})
        except (urllib.error.URLError, ConnectionError, TimeoutError) as e:
            raise TransientTransportError(
                f"{method} {path}: {e}", client_id=self.client_id,
                reason="connect") from e

    def _json(self, data: bytes) -> Dict[str, Any]:
        try:
            return json.loads(data.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            return {}

    # -- API -----------------------------------------------------------------
    def health(self) -> Dict[str, Any]:
        code, data, _ = self._request("GET", "/v1/healthz")
        if code != 200:
            raise TransientTransportError(f"healthz returned {code}",
                                          client_id=self.client_id,
                                          reason="health")
        return self._json(data)

    def current_round(self) -> int:
        return int(self.health()["round"])

    def metrics(self) -> Dict[str, Any]:
        return self._json(self._request("GET", "/v1/metrics")[1])

    def submit_delta(self, lora: Any, round_id: Optional[int] = None,
                     rank: Optional[int] = None) -> Dict[str, Any]:
        """Encode + frame + POST one adapter delta; bounded-backoff retries
        on 429/503/connection faults (the coordinator's retry budget shape:
        ``backoff · 2^attempt`` sleeps, ``retries`` re-attempts). ``rank``
        declares a ragged (hetero) uplink's LoRA rank — the factor tensors
        travel at their true rank-r width and the server pads to r_max."""
        rid = self.current_round() if round_id is None else int(round_id)
        payload = self.codec.encode(lora, round_id=rid,
                                    client_id=self.client_id,
                                    direction="uplink", rank=rank)
        body = payload_to_wire(payload)
        headers = {"Content-Type": "application/octet-stream"}
        if self.num_examples is not None:
            headers["X-Fed-Examples"] = str(self.num_examples)
        attempt = 0
        while True:
            try:
                code, data, _ = self._request(
                    "POST", f"/v1/rounds/{rid}/deltas", body, headers)
            except TransientTransportError:
                if attempt >= self.retries:
                    raise
                code = None
            if code == 200:
                return self._json(data)
            if code is not None and code not in _RETRYABLE:
                obj = self._json(data)
                reason = str(obj.get("reason", obj.get("error", "rejected")))
                err = StaleUplinkError if code in (409, 410) else TransportError
                raise err(f"POST /v1/rounds/{rid}/deltas → {code}: "
                          f"{obj.get('detail', reason)}",
                          round_id=rid, client_id=self.client_id,
                          reason=reason)
            if code is not None and attempt >= self.retries:
                raise TransportError(
                    f"retry budget exhausted after {attempt + 1} POSTs "
                    f"(last status {code})", round_id=rid,
                    client_id=self.client_id, reason="retries_exhausted")
            delay = self.backoff * (2 ** attempt)
            if self.rec.enabled:
                self.rec.counter("uplink.http_retries").inc()
            logger.debug("client %d: POST retry %d in %.3fs (status=%s)",
                         self.client_id, attempt + 1, delay, code)
            time.sleep(delay)
            attempt += 1

    def pull_latest(self) -> PullResult:
        """GET the merged global adapter; decode through the defended codec
        (finite check applies — a corrupt downlink quarantines client-side)."""
        code, data, headers = self._request("GET", "/v1/adapters/latest")
        if code != 200:
            raise TransportError(f"pull_latest → {code}",
                                 client_id=self.client_id, reason="pull")
        payload = payload_from_wire(data)
        lora = self.codec.decode(payload)
        return PullResult(
            version=int(headers.get("X-Fed-Version", -1)),
            round_id=int(headers.get("X-Fed-Round", -1)),
            lora=lora, w0_digest=headers.get("X-Fed-W0-Digest", ""),
            nbytes=len(data))
