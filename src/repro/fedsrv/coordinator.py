"""Event-driven round coordinator: open → collect → close (weighted, exact).

Synchronous mode (``RoundCoordinator``): a round opens, sampled participants
are scheduled as (arrival_time, client) events from the straggler model, and
the round collects deliveries until the deadline passes WITH the min-quorum
met (deadline=0 → wait for everyone who didn't drop out). Close performs
*weighted* exact aggregation over the delivered subset: wᵢ = nᵢ/Σnⱼ (or
uniform), with the residual identity Σwᵢaᵢbᵢ = āb̄ + ΔW_res preserved exactly
— see core/aggregation.py.

Asynchronous mode (``AsyncBufferCoordinator``): FedBuff-style. Clients launch
against the *current* global adapter version and arrive after their simulated
latency; the server commits whenever ``buffer_size`` deliveries are buffered.
Stale deliveries (trained from an older version v) are discounted by
``(1 + staleness)^(−staleness_alpha)`` on top of their example weight, the
weights renormalized, and an exact residual for the committed subset is folded
at every commit — staleness changes the *weights*, never the exactness of the
weighted identity.

The coordinator is model-agnostic: training is injected as
``train_fn(client: ClientInfo, start_lora, round_id) → lora`` and every
adapter crosses the transport codec (so uplink quantization is part of what
gets aggregated). A BytesLedger entry is recorded per payload.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import aggregation as agg
from repro.fedsrv.registry import (ClientInfo, ClientRegistry, SimClock,
                                   StragglerModel)
from repro.fedsrv.transport import (AdapterCodec, BytesLedger,
                                    StaleUplinkError, TransientTransportError,
                                    TransportError)
from repro.obs import NULL
from repro.util.logging import get_logger
from repro.util.tree import count_params

logger = get_logger("fedsrv")

TrainFn = Callable[[ClientInfo, Any, int], Any]


@dataclass(frozen=True)
class RoundPolicy:
    """Knobs for one round's collection behavior.

    participation — fraction of registered clients sampled per round.
    min_quorum   — deliveries required before the deadline may cut late
                   arrivals (0 → any single delivery suffices).
    deadline     — sim-seconds after round open at which late arrivals are
                   dropped, provided quorum is met (0 → no deadline).
    weighting    — "uniform" (legacy wᵢ=1/k path, bitwise-identical to the
                   seed trainer) or "examples" (wᵢ = nᵢ/Σnⱼ).
    """

    participation: float = 1.0
    min_quorum: int = 0
    deadline: float = 0.0
    weighting: str = "uniform"  # uniform | examples


@dataclass
class Delivery:
    client: ClientInfo
    lora: Any
    launched_at: float
    arrived_at: float
    staleness: int = 0  # async mode: commits elapsed since launch version


@dataclass
class RoundOutcome:
    round_id: int
    sampled: List[int]
    delivered: List[Delivery]
    dropped_out: List[int]          # never reported back
    dropped_deadline: List[int]     # arrived after deadline with quorum met
    weights: Optional[List[float]]  # None → uniform
    opened_at: float
    closed_at: float
    comm: Dict[str, int] = field(default_factory=dict)
    # --- fault outcomes (fedsrv/faults.py + the defended transport) ---
    # (client_id, reason) pairs whose uplink was quarantined (bad content)
    # or dropped (crash / replayed / duplicate address)
    quarantined: List[Tuple[int, str]] = field(default_factory=list)
    # quorum failed after quarantine: the trainer must carry forward the
    # previous global adapter (the round's set was evicted, never closed)
    degraded: bool = False
    retries: int = 0  # transient decode retries spent this round

    @property
    def client_ids(self) -> List[int]:
        return [d.client.client_id for d in self.delivered]


@dataclass
class UplinkResult:
    """What became of one client's uplink (see RoundCoordinator._uplink)."""

    ok: bool
    tree: Any = None        # decoded host tree when ok
    reason: str = ""        # quarantine/drop reason when not ok
    status: str = "delivered"  # delivered | quarantined | dropped
    retries: int = 0


def weighted_close(outcome: RoundOutcome, method: str = "fedex",
                   svd_rank: int = 0) -> Tuple[Any, Optional[Any]]:
    """Close a round: (new global adapter, residual-or-None) over the
    delivered subset with the outcome's weights. Exact for fedex/fedex_svd
    (modulo truncation for svd), inexact-by-design for fedit, exact by
    construction for ffa. ``svd_rank=0`` keeps the config-level "exact"
    meaning: the round closes through the plain (untruncated) fedex path."""
    loras = [d.lora for d in outcome.delivered]
    if not loras:
        raise ValueError(f"round {outcome.round_id} closed with no deliveries")
    w = outcome.weights
    if method == "fedex":
        return agg.fedex_aggregate(loras, w)
    if method == "fedex_svd":
        if svd_rank < 1:  # 0 → exact: never truncate
            return agg.fedex_aggregate(loras, w)
        return agg.fedex_svd_aggregate(loras, svd_rank, w)
    if method == "fedit":
        return agg.fedit_aggregate(loras, w), None
    if method == "ffa":
        return agg.ffa_aggregate(loras, w), None
    raise ValueError(f"unknown method {method!r}")


class RoundCoordinator:
    """Synchronous (per-round) coordinator with sampling/deadline/quorum.

    With the default policy (participation=1, no deadline, no dropout,
    uniform weighting, codec "none") this degenerates to the seed trainer's
    hard-coded loop: every client, client_id order, uniform mean.
    """

    def __init__(self, registry: ClientRegistry,
                 policy: Optional[RoundPolicy] = None,
                 stragglers: Optional[StragglerModel] = None,
                 codec: Optional[AdapterCodec] = None,
                 ledger: Optional[BytesLedger] = None,
                 clock: Optional[SimClock] = None,
                 sink: Optional[Any] = None,
                 recorder: Optional[Any] = None,
                 faults: Optional[Any] = None,
                 uplink_retries: int = 2,
                 retry_backoff: float = 0.05):
        self.registry = registry
        self.policy = policy or RoundPolicy()
        self.stragglers = stragglers or StragglerModel()
        self.codec = codec or AdapterCodec("none")
        self.ledger = ledger or BytesLedger()
        self.clock = clock or SimClock()
        # fault-injection layer (fedsrv/faults.FaultInjector) — None in
        # production paths; when set, every encoded uplink passes through
        # injector.corrupt() before delivery
        self.faults = faults
        # transient decode failures: bounded retry with exponential backoff
        # on the SimClock (retry_backoff · 2^attempt sim-seconds)
        if uplink_retries < 0:
            raise ValueError(f"uplink_retries must be ≥ 0, got {uplink_retries}")
        self.uplink_retries = uplink_retries
        self.retry_backoff = retry_backoff
        # obs recorder (repro.obs): the round lifecycle records nested spans
        # (round.collect → client.train → client.uplink → codec/ring) plus
        # per-round client-count metrics; propagated to the codec so
        # encode/decode byte counts land in the same stream.
        self.rec = recorder if recorder is not None else NULL
        if self.rec.enabled and not self.codec.rec.enabled:
            self.codec.rec = self.rec
        # optional streaming sink (core/engine.RoundBuffers): uplink payloads
        # are decoded INTO preallocated (C_max, …) device stacks as they
        # arrive — the fused round-close engine reads the stacks instead of
        # re-stacking a list of host trees at the deadline.
        self.sink = sink
        self._downlink_params: Optional[int] = None  # adapter tree is static

    # ------------------------------------------------------------------
    def _open_sink(self, candidates: List[int], round_id: int, *,
                   deadline: Optional[float] = None,
                   now: Optional[float] = None) -> None:
        """Assign this round's candidate clients to stack lanes in client-id
        order (stable: the uniform full-participation sum visits lanes in the
        same order the legacy list path visited clients). The round_id keys
        the sink's double-buffer ring: round N+1 uplinks stream into a fresh
        stack set while round N's set is still owned by its in-flight close.
        Zero-candidate rounds never open a set (there is nothing to stream
        and no close will ever take() it).

        ``deadline``/``now`` thread the ring's per-round eviction contract
        through (core/engine.RoundBuffers): when every ring set is in flight,
        open rounds whose deadline has passed are evicted instead of wedging
        the ring — the sync coordinator uses sim-seconds, the FedBuff
        coordinator commit VERSIONS, as the monotonic scale."""
        if self.sink is not None and candidates:
            self.sink.begin_round(
                {cid: i for i, cid in enumerate(sorted(candidates))},
                round_id=round_id, deadline=deadline, now=now)

    def _deliver(self, payload: Any, weight: float = 1.0) -> Tuple[Any, int]:
        """Decode one payload (into the sink when present) with bounded
        retry-with-backoff on transient failures. ``weight`` is the client's
        RAW aggregation weight, folded into a chunked sink's accumulators at
        ingest. Returns (host tree, retries spent); raises
        TransportError/StaleUplinkError when the payload must be
        quarantined/dropped."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    # a transient decode failure is a property of THIS
                    # delivery attempt, not of the (frozen) payload
                    self.faults.check_transient(payload.round_id,
                                                payload.client_id)
                if self.sink is not None:
                    return self.codec.decode_into(payload, self.sink,
                                                  weight=weight), attempt
                return self.codec.decode(payload), attempt
            except TransientTransportError as e:
                if attempt >= self.uplink_retries:
                    raise TransportError(
                        f"retries exhausted after {attempt} backoffs: {e}",
                        round_id=payload.round_id,
                        client_id=payload.client_id,
                        reason="retries_exhausted") from e
                self.clock.advance(self.retry_backoff * (2 ** attempt))
                attempt += 1
                if self.rec.enabled:
                    self.rec.counter("uplink.retries").inc()
                    self.rec.event("uplink.retry", cat="fedsrv",
                                   round=payload.round_id,
                                   client=payload.client_id, attempt=attempt)

    def _uplink(self, lora: Any, round_id: int, client_id: int, *,
                weight: float = 1.0,
                rank: Optional[int] = None) -> UplinkResult:
        """Client → server through the codec; the server aggregates what was
        actually transmitted (quantization included). With a streaming sink
        the decoded leaves additionally go straight into the client's stack
        lane (one decode, shared with the returned host tree). ``weight`` is
        the client's raw aggregation weight at delivery time — a chunked
        sink folds it in at ingest, so it must normalise to the close-time
        weighting (sync: example counts; async: the staleness discount,
        known here because commits drain AFTER the version they discount
        against).

        The defended path: an active fault injector corrupts the payload
        here (between encode and delivery — exactly where a real wire sits);
        validation failures QUARANTINE the uplink (ledger direction
        ``quarantined``, lane left zero for exact exclusion), addressing
        failures and mid-uplink crashes DROP it (direction ``dropped``).

        ``rank`` declares a ragged (hetero) uplink's true LoRA rank: it
        rides the payload header so rank-aware validation applies and the
        ring's slot rank vector records it at ingest.
        """
        with self.rec.span("client.uplink", cat="fedsrv", round=round_id,
                           client=client_id):
            payload = self.codec.encode(lora, round_id=round_id,
                                        client_id=client_id,
                                        direction="uplink", rank=rank)
            kinds: List[str] = []
            if self.faults is not None:
                payload, applied = self.faults.corrupt(payload)
                kinds = [s.kind for s in applied]
            if "crash" in kinds:
                # client died mid-uplink: nothing ever reaches the server
                self.ledger.record(payload, note="fault:crash",
                                   direction="dropped")
                self._note_undelivered(round_id, client_id, "crash",
                                       "dropped")
                return UplinkResult(ok=False, reason="crash",
                                    status="dropped")
            if payload.round_id != round_id and self.sink is None:
                # replayed/misaddressed uplink with no ring to refuse it —
                # the coordinator rejects the address itself
                self.ledger.record(payload, note="drop:replay",
                                   direction="dropped")
                self._note_undelivered(round_id, client_id, "replay",
                                       "dropped")
                return UplinkResult(ok=False, reason="replay",
                                    status="dropped")
            try:
                tree, retries = self._deliver(payload, weight)
            except StaleUplinkError as e:
                self.ledger.record(payload, note=f"drop:{e.reason}",
                                   direction="dropped")
                self._note_undelivered(round_id, client_id, e.reason,
                                       "dropped")
                return UplinkResult(ok=False, reason=e.reason,
                                    status="dropped")
            except TransportError as e:
                self.ledger.record(payload, note=f"quarantine:{e.reason}",
                                   direction="quarantined")
                self._note_undelivered(round_id, client_id, e.reason,
                                       "quarantined")
                return UplinkResult(ok=False, reason=e.reason,
                                    status="quarantined")
            self.ledger.record(payload)
            if "duplicate" in kinds:
                # the duplicate copy consumed wire bytes but the ring drops
                # its lane write — record it, expect the StaleUplinkError
                try:
                    self._deliver(payload)
                except StaleUplinkError:
                    pass
                self.ledger.record(payload, note="fault:duplicate",
                                   direction="dropped")
            return UplinkResult(ok=True, tree=tree, retries=retries)

    def _note_undelivered(self, round_id: int, client_id: int, reason: str,
                          status: str) -> None:
        """Obs + ledger bookkeeping shared by every not-delivered uplink:
        the downlink that fed this client never became aggregate input."""
        self.ledger.reclassify(round_id, client_id, "downlink", "dropped",
                               note=f"fed a {status} uplink")
        if self.rec.enabled:
            self.rec.counter(f"uplink.{status}[{reason}]").inc()
            self.rec.event("uplink.quarantine" if status == "quarantined"
                           else "uplink.drop", cat="fedsrv", round=round_id,
                           client=client_id, reason=reason)

    def _ensure_spec(self, global_lora: Any) -> None:
        """Register the global adapter's per-leaf (path → shape) spec with the
        codec on first use — every honest uplink must match it exactly."""
        v = self.codec.validation
        if v.enabled and v.check_spec and self.codec.spec is None:
            self.codec.register_spec(global_lora)

    def _record_downlink(self, lora: Any, round_id: int, client_id: int) -> None:
        """Downlink is always fp32 and the client trains on the original tree,
        so the ledger entry is recorded analytically (no serialize round-trip)."""
        if self._downlink_params is None:
            self._downlink_params = count_params(lora)
        self.ledger.record_analytic(round_id, "downlink",
                                    self._downlink_params,
                                    client_id=client_id, note="global adapters")

    # ------------------------------------------------------------------
    def run_round(self, round_id: int, train_fn: TrainFn, global_lora: Any
                  ) -> RoundOutcome:
        pol = self.policy
        self._ensure_spec(global_lora)
        participants = self.registry.sample_round(round_id, pol.participation,
                                                  max(1, pol.min_quorum))
        opened = self.clock.now()
        self.rec.event("round.open", cat="fedsrv", round=round_id,
                       sampled=len(participants))

        # schedule the event queue: dropout draws + arrival times
        dropped_out: List[int] = []
        stragglers = 0
        arrivals: List[Tuple[float, ClientInfo]] = []
        for c in participants:
            if self.stragglers.dropped(round_id, c):
                dropped_out.append(c.client_id)
                self.rec.event("client.dropout", cat="fedsrv", round=round_id,
                               client=c.client_id)
                continue
            lat, straggled = self.stragglers.draw(round_id, c)
            stragglers += int(straggled)
            arrivals.append((opened + lat, c))
        arrivals.sort(key=lambda tc: (tc[0], tc[1].client_id))

        # quorum: deliveries required before the deadline may cut stragglers.
        # min_quorum=0 → any delivery suffices (a positive deadline must be
        # able to drop; a round still can't close empty), but without a
        # deadline the round simply waits for every non-dropout.
        quorum = max(1, pol.min_quorum)
        quorum = min(quorum, len(arrivals)) if arrivals else 0

        # streaming close: every non-dropout candidate gets a stack lane up
        # front; late/dropped lanes simply stay masked (weight 0) at close.
        # A policy deadline doubles as the ring-eviction deadline: a round
        # that never closed by its deadline may be evicted from a full ring.
        self._open_sink([c.client_id for _, c in arrivals], round_id,
                        deadline=(opened + pol.deadline
                                  if pol.deadline > 0 else None),
                        now=opened)

        delivered: List[Delivery] = []
        dropped_deadline: List[int] = []
        quarantined: List[Tuple[int, str]] = []
        retries = 0
        with self.rec.span("round.collect", cat="fedsrv", round=round_id,
                           candidates=len(arrivals), quorum=quorum):
            for t, c in arrivals:
                late = pol.deadline > 0 and t > opened + pol.deadline
                if late and len(delivered) >= quorum:
                    dropped_deadline.append(c.client_id)
                    self.rec.event("client.deadline_drop", cat="fedsrv",
                                   round=round_id, client=c.client_id,
                                   arrived_at=t)
                    continue
                # downlink current global, train, uplink the result (codec)
                self._record_downlink(global_lora, round_id, c.client_id)
                with self.rec.span("client.train", cat="fedsrv",
                                   round=round_id, client=c.client_id):
                    lora_c = train_fn(c, global_lora, round_id)
                res = self._uplink(
                    lora_c, round_id, c.client_id,
                    weight=(float(c.num_examples)
                            if pol.weighting == "examples" else 1.0))
                # the arrival consumed sim-time whether or not it delivered
                # — a quarantined uplink and its crash twin leave the clock
                # (and thus every later draw) identical
                self.clock.advance_to(t)
                retries += res.retries
                if res.ok:
                    delivered.append(Delivery(client=c, lora=res.tree,
                                              launched_at=opened,
                                              arrived_at=t))
                else:
                    quarantined.append((c.client_id, res.reason))

        closed = self.clock.now()  # arrival of the last delivery this round
        # stable order: aggregation sums in client_id order (bitwise parity
        # with the seed loop under the trivial policy)
        delivered.sort(key=lambda d: d.client.client_id)

        # graceful degradation: quarantine can starve a round below quorum
        # (impossible in the clean path — quorum is capped to the arrivals
        # that all deliver). Carry-forward semantics: the round never
        # closes, so its sink set is evicted here, never take()n.
        degraded = bool(arrivals) and len(delivered) < quorum
        if degraded:
            self._evict_sink_round(round_id, "degraded: quorum failed "
                                   "after quarantine")
            if self.rec.enabled:
                self.rec.counter("round.degraded").inc()
            self.rec.event("round.degraded", cat="fedsrv", round=round_id,
                           delivered=len(delivered), quorum=quorum,
                           quarantined=len(quarantined))
            logger.warning(
                "round=%d DEGRADED: %d/%d deliveries after quarantine "
                "(quorum %d) — global adapter carried forward", round_id,
                len(delivered), len(arrivals), quorum)

        weights = None
        if pol.weighting == "examples" and delivered:
            weights = self.registry.weights_for(
                [d.client.client_id for d in delivered])
        elif pol.weighting not in ("uniform", "examples"):
            raise ValueError(f"unknown weighting {pol.weighting!r}")

        outcome = RoundOutcome(
            round_id=round_id, sampled=[c.client_id for c in participants],
            delivered=delivered, dropped_out=dropped_out,
            dropped_deadline=dropped_deadline, weights=weights,
            opened_at=opened, closed_at=closed,
            comm=self.ledger.round_totals(round_id),
            quarantined=quarantined, degraded=degraded, retries=retries)
        if self.rec.enabled:
            self.rec.round_set(round_id, sampled=len(participants),
                               delivered=len(delivered),
                               stragglers=stragglers,
                               dropped_out=len(dropped_out),
                               deadline_drops=len(dropped_deadline),
                               quarantined=len(quarantined),
                               retries=retries, degraded=int(degraded),
                               opened_at=round(opened, 3),
                               closed_at=round(closed, 3))
        logger.info(
            "round=%d sampled=%d delivered=%d dropout=%d deadline_drop=%d "
            "quarantined=%d open=%.2fs close=%.2fs", round_id,
            len(participants), len(delivered), len(dropped_out),
            len(dropped_deadline), len(quarantined), opened, closed)
        return outcome

    def _evict_sink_round(self, round_id: int, reason: str) -> None:
        """Evict a degraded round's stack set (if a sink opened one) so the
        ring never wedges on a round nobody will close."""
        if self.sink is not None and round_id in getattr(
                self.sink, "open_rounds", []):
            self.sink.evict(round_id, reason=reason)


class AsyncBufferCoordinator(RoundCoordinator):
    """FedBuff-style buffered commits with staleness-discounted exact folds.

    Each ``run_round`` call is ONE server commit: newly sampled clients are
    launched against the current global version, then the ``buffer_size``
    earliest arrivals (possibly launched several versions ago) are trained
    from their launch-time global snapshot and committed together.
    """

    def __init__(self, registry: ClientRegistry,
                 policy: Optional[RoundPolicy] = None,
                 stragglers: Optional[StragglerModel] = None,
                 codec: Optional[AdapterCodec] = None,
                 ledger: Optional[BytesLedger] = None,
                 clock: Optional[SimClock] = None,
                 buffer_size: int = 2,
                 staleness_alpha: float = 0.5,
                 max_version_lag: int = 1,
                 recorder: Optional[Any] = None,
                 faults: Optional[Any] = None,
                 uplink_retries: int = 2,
                 retry_backoff: float = 0.05):
        super().__init__(registry, policy, stragglers, codec, ledger, clock,
                         recorder=recorder, faults=faults,
                         uplink_retries=uplink_retries,
                         retry_backoff=retry_backoff)
        if buffer_size < 1:
            raise ValueError("buffer_size must be ≥ 1")
        if max_version_lag < 1:
            raise ValueError("max_version_lag must be ≥ 1")
        self.buffer_size = buffer_size
        self.staleness_alpha = staleness_alpha
        # ring eviction: a commit's stack set opened at version v is
        # evictable from a FULL ring once the server version has advanced by
        # max_version_lag — a commit lagging a full version (default lag 1)
        # is abandoned rather than wedging deeper (depth > 2) rings.
        self.max_version_lag = max_version_lag
        self._version = 0
        self._snapshots: Dict[int, Any] = {}  # version → global lora
        # in-flight: (arrival_time, client, launch_version)
        self._inflight: List[Tuple[float, ClientInfo, int]] = []

    def run_round(self, round_id: int, train_fn: TrainFn, global_lora: Any
                  ) -> RoundOutcome:
        pol = self.policy
        self._ensure_spec(global_lora)
        opened = self.clock.now()
        self._snapshots[self._version] = global_lora
        self.rec.event("commit.open", cat="fedsrv", round=round_id,
                       version=self._version, inflight=len(self._inflight))

        # launch newly sampled clients at the current version
        participants = self.registry.sample_round(round_id, pol.participation,
                                                  max(1, pol.min_quorum))
        dropped_out: List[int] = []
        busy = {c.client_id for _, c, _ in self._inflight}
        launched: List[int] = []
        for c in participants:
            if c.client_id in busy:
                continue  # still running an older version's assignment
            if self.stragglers.dropped(round_id, c):
                dropped_out.append(c.client_id)
                self.rec.event("client.dropout", cat="fedsrv", round=round_id,
                               client=c.client_id)
                continue
            t = opened + self.stragglers.latency(round_id, c)
            self._inflight.append((t, c, self._version))
            launched.append(c.client_id)
        self._inflight.sort(key=lambda e: (e[0], e[1].client_id))

        # commit the earliest buffer_size arrivals
        take = min(self.buffer_size, len(self._inflight))
        if take == 0:
            # every sampled client dropped out and nothing is in flight:
            # empty commit — keep the version, let the trainer keep its global
            # (mirrors the sync coordinator's zero-delivery round).
            logger.warning("commit=%d: no clients in flight; empty commit",
                           round_id)
            return RoundOutcome(
                round_id=round_id,
                sampled=[c.client_id for c in participants],
                delivered=[], dropped_out=dropped_out, dropped_deadline=[],
                weights=None, opened_at=opened, closed_at=self.clock.now(),
                comm=self.ledger.round_totals(round_id))
        batch, self._inflight = self._inflight[:take], self._inflight[take:]
        # versions are the FedBuff ring's monotonic scale: this commit's set
        # expires max_version_lag versions from now
        self._open_sink([c.client_id for _, c, _ in batch], round_id,
                        deadline=self._version + self.max_version_lag,
                        now=self._version)

        delivered: List[Delivery] = []
        quarantined: List[Tuple[int, str]] = []
        retries = 0
        with self.rec.span("commit.collect", cat="fedsrv", round=round_id,
                           version=self._version, take=take):
            for t, c, v in batch:
                start = self._snapshots[v]
                self._record_downlink(start, round_id, c.client_id)
                with self.rec.span("client.train", cat="fedsrv",
                                   round=round_id, client=c.client_id,
                                   launch_version=v):
                    lora_c = train_fn(c, start, round_id)
                n = (float(c.num_examples) if pol.weighting == "examples"
                     else 1.0)
                res = self._uplink(
                    lora_c, round_id, c.client_id,
                    weight=n * (1.0 + (self._version - v))
                    ** (-self.staleness_alpha))
                self.clock.advance_to(t)  # sim-time parity (see sync loop)
                retries += res.retries
                if res.ok:
                    delivered.append(Delivery(client=c, lora=res.tree,
                                              launched_at=t, arrived_at=t,
                                              staleness=self._version - v))
                else:
                    quarantined.append((c.client_id, res.reason))
        delivered.sort(key=lambda d: d.client.client_id)

        # graceful degradation: every buffered delivery was quarantined —
        # keep the version (nothing committed), evict the opened set, and
        # let the trainer carry the global forward.
        degraded = not delivered
        if degraded:
            self._evict_sink_round(round_id, "degraded: commit buffer fully "
                                   "quarantined")
            if self.rec.enabled:
                self.rec.counter("round.degraded").inc()
            self.rec.event("round.degraded", cat="fedsrv", round=round_id,
                           delivered=0, quorum=take,
                           quarantined=len(quarantined))
            logger.warning(
                "commit=%d DEGRADED: 0/%d deliveries after quarantine — "
                "version held at %d", round_id, take, self._version)
            return RoundOutcome(
                round_id=round_id,
                sampled=[c.client_id for c in participants],
                delivered=[], dropped_out=dropped_out, dropped_deadline=[],
                weights=None, opened_at=opened, closed_at=self.clock.now(),
                comm=self.ledger.round_totals(round_id),
                quarantined=quarantined, degraded=True, retries=retries)

        # weights: example count × staleness discount, renormalized — the
        # weighted residual identity stays exact for ANY normalized weights.
        raw = []
        for d in delivered:
            n = (d.client.num_examples if pol.weighting == "examples" else 1.0)
            raw.append(n * (1.0 + d.staleness) ** (-self.staleness_alpha))
        total = sum(raw)
        weights: Optional[List[float]] = [x / total for x in raw]

        self._version += 1
        # snapshots older than every in-flight launch can be freed
        live = {v for _, _, v in self._inflight} | {self._version}
        for v in list(self._snapshots):
            if v not in live and v != self._version - 1:
                del self._snapshots[v]

        outcome = RoundOutcome(
            round_id=round_id, sampled=[c.client_id for c in participants],
            delivered=delivered, dropped_out=dropped_out,
            dropped_deadline=[], weights=weights, opened_at=opened,
            closed_at=self.clock.now(),
            comm=self.ledger.round_totals(round_id),
            quarantined=quarantined, retries=retries)
        stale = [d.staleness for d in delivered]
        if self.rec.enabled:
            self.rec.hist("fedsrv.commit_staleness").observe(
                max(stale, default=0))
            self.rec.round_set(round_id, sampled=len(participants),
                               delivered=len(delivered),
                               dropped_out=len(dropped_out),
                               quarantined=len(quarantined),
                               retries=retries,
                               launched=len(launched),
                               inflight=len(self._inflight),
                               version=self._version,
                               staleness_max=max(stale, default=0),
                               staleness_mean=round(
                                   sum(stale) / max(len(stale), 1), 3),
                               opened_at=round(opened, 3),
                               closed_at=round(self.clock.now(), 3))
        logger.info(
            "commit=%d version=%d launched=%d committed=%d inflight=%d "
            "max_staleness=%d", round_id, self._version, len(launched),
            len(delivered), len(self._inflight),
            max((d.staleness for d in delivered), default=0))
        return outcome
