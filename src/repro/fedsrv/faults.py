"""Seeded, composable fault injection for the federation stack.

A :class:`FaultPlan` is a list of :class:`FaultSpec` fault models — each one
names a *kind* of misbehavior, a probability, and the (round, client) scope it
applies to. The :class:`FaultInjector` evaluates the plan deterministically:
every (spec, round, client) coin comes from its own
``np.random.default_rng([seed, round, client, FAULT_STREAM, spec_index])``
SeedSequence stream (see registry.FAULT_STREAM), so fault draws NEVER share a
stream with the straggler model's latency/dropout draws — a client drawn as
dropped cannot shift the fault plan of any other client or round, and the same
plan replays bit-for-bit across participation settings.

Fault kinds (the coordinator's uplink path applies them between
``AdapterCodec.encode`` and delivery):

==============  ===========================================================
``nan``         poison one element of the payload with NaN (int8 payloads
                poison the dequant scale) — quarantined by the finite check
``inf``         same, with +inf
``bitflip``     flip one random bit of one tensor's raw bytes (may or may
                not survive validation — that is the point)
``truncate``    chop trailing bytes off one tensor: wire size no longer
                matches the declared shape → typed ``TransportError`` at
                the decode boundary (never a deep ``reshape`` crash)
``scale``       byzantine client: multiply the update by ``factor`` —
                quarantined only when the codec's norm limit is configured
``replay``      rewrite the payload's round_id to ``round_id − offset``
                (a replayed/misrouted uplink; the ring drops or the
                transport rejects it — it never lands in the live round)
``duplicate``   deliver the same (client, round) payload twice — the ring
                drops the second copy
``crash``       client dies mid-uplink: the payload never arrives
``decode_error``  transient decode failure: the first ``count`` decode
                attempts raise ``TransientTransportError`` (the
                coordinator retries with backoff on the SimClock)
==============  ===========================================================

Plan DSL (``FedConfig.faults`` / ``launch/train.py --faults``): specs are
``;``-separated, each ``kind@prob(key=value,...)`` with ``+``-separated id
lists, e.g.::

    nan@1.0(clients=2,rounds=0);scale@0.5(clients=1+3,factor=1e3);crash@0.1

Omitted ``clients=``/``rounds=`` mean "all"; ``@prob`` defaults to 1.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.fedsrv.registry import FAULT_STREAM, purpose_rng
from repro.fedsrv.transport import (Payload, TransientTransportError)
from repro.obs import NULL

FAULT_KINDS = ("nan", "inf", "bitflip", "truncate", "scale", "replay",
               "duplicate", "crash", "decode_error")
# kinds that mutate the payload itself (vs. flags the coordinator acts on)
PAYLOAD_KINDS = ("nan", "inf", "bitflip", "truncate", "scale", "replay")
# kinds the defended decode MUST catch whenever validation is on — the soak
# harness computes quarantine recall over these (scale joins the set only
# when the codec's norm limit is configured)
DETECTABLE_KINDS = ("nan", "inf", "truncate")
# adapter-VALUE kinds applicable to mesh mode's co-scheduled lanes (no wire
# → no codec/addressing faults there; launch/mesh_train.py screens lanes
# and weight-masks bad ones out of the close)
MESH_KINDS = ("nan", "inf", "scale")


@dataclass(frozen=True)
class FaultSpec:
    """One fault model: a kind, a probability, and its (round, client) scope."""

    kind: str
    prob: float = 1.0
    clients: Optional[Tuple[int, ...]] = None   # None → every client
    rounds: Optional[Tuple[int, ...]] = None    # None → every round
    factor: float = 1e3    # scale: byzantine multiplier
    count: int = 1         # decode_error: failures before success
    offset: int = 1        # replay: rounds to rewind the round_id by

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"fault prob must be in [0, 1], got {self.prob}")
        if self.count < 1:
            raise ValueError(f"fault count must be ≥ 1, got {self.count}")
        if self.offset < 1:
            raise ValueError(f"replay offset must be ≥ 1, got {self.offset}")

    def in_scope(self, round_id: int, client_id: int) -> bool:
        if self.rounds is not None and round_id not in self.rounds:
            return False
        if self.clients is not None and client_id not in self.clients:
            return False
        return True

    def __str__(self) -> str:
        args = []
        if self.clients is not None:
            args.append("clients=" + "+".join(map(str, self.clients)))
        if self.rounds is not None:
            args.append("rounds=" + "+".join(map(str, self.rounds)))
        if self.kind == "scale":
            args.append(f"factor={self.factor:g}")
        if self.kind == "decode_error" and self.count != 1:
            args.append(f"count={self.count}")
        if self.kind == "replay" and self.offset != 1:
            args.append(f"offset={self.offset}")
        out = f"{self.kind}@{self.prob:g}"
        return out + (f"({','.join(args)})" if args else "")


def _parse_ids(text: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in text.split("+") if x != "")


def _parse_spec(text: str) -> FaultSpec:
    text = text.strip()
    args: Dict[str, Any] = {}
    if "(" in text:
        if not text.endswith(")"):
            raise ValueError(f"unbalanced parens in fault spec {text!r}")
        text, arg_text = text[:-1].split("(", 1)
        for item in arg_text.split(","):
            if not item.strip():
                continue
            if "=" not in item:
                raise ValueError(f"fault spec arg {item!r} is not key=value")
            k, v = (s.strip() for s in item.split("=", 1))
            if k == "clients":
                args["clients"] = _parse_ids(v)
            elif k == "rounds":
                args["rounds"] = _parse_ids(v)
            elif k == "factor":
                args["factor"] = float(v)
            elif k == "count":
                args["count"] = int(v)
            elif k == "offset":
                args["offset"] = int(v)
            else:
                raise ValueError(f"unknown fault spec arg {k!r} "
                                 "(clients|rounds|factor|count|offset)")
    kind, _, prob = text.partition("@")
    return FaultSpec(kind=kind.strip(),
                     prob=float(prob) if prob else 1.0, **args)


@dataclass(frozen=True)
class FaultPlan:
    """An ordered, seeded collection of fault models."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse the ``;``-separated plan DSL (see module docstring)."""
        specs = tuple(_parse_spec(s) for s in text.split(";") if s.strip())
        return cls(specs=specs, seed=seed)

    def __str__(self) -> str:
        return ";".join(str(s) for s in self.specs)


class FaultInjector:
    """Evaluates a :class:`FaultPlan` against the uplink stream.

    The coordinator calls :meth:`corrupt` on every encoded uplink payload
    (between ``AdapterCodec.encode`` and delivery) and
    :meth:`check_transient` on every decode attempt. Every decision is a
    deterministic function of ``(plan.seed, round, client, spec index)`` —
    see the module docstring for the rng-stream isolation contract.

    ``injected`` is the ground-truth log (round, client, kind) of every fault
    actually applied — the soak harness scores quarantine precision/recall
    against it.
    """

    def __init__(self, plan: FaultPlan, recorder=None):
        self.plan = plan
        self.rec = recorder if recorder is not None else NULL
        self.injected: List[Dict[str, Any]] = []
        # (round, client) → remaining transient decode failures
        self._transient: Dict[Tuple[int, int], int] = {}

    # ------------------------------------------------------------------
    def _spec_rng(self, round_id: int, client_id: int,
                  spec_index: int) -> np.random.Generator:
        return purpose_rng(self.plan.seed, round_id, client_id,
                           FAULT_STREAM, spec_index)

    def draws(self, round_id: int, client_id: int
              ) -> List[Tuple[int, FaultSpec]]:
        """The (index, spec) pairs active for one (round, client) uplink.

        Pure: no injector state is consumed — calling this twice (or never,
        for a dropped-out client) cannot shift any other draw."""
        out = []
        for i, spec in enumerate(self.plan.specs):
            if not spec.in_scope(round_id, client_id):
                continue
            if spec.prob >= 1.0:
                out.append((i, spec))
            elif spec.prob > 0.0:
                if self._spec_rng(round_id, client_id, i).random() < spec.prob:
                    out.append((i, spec))
        return out

    # ------------------------------------------------------------------
    def corrupt(self, payload: Payload) -> Tuple[Payload, List[FaultSpec]]:
        """Apply the plan to one uplink payload.

        Returns ``(payload', applied)``: payload-level kinds mutate a copy of
        the payload (frozen dataclasses — never the original), flag kinds
        (crash/duplicate/decode_error) are returned for the coordinator to
        act on. Every applied fault lands in :attr:`injected` and emits a
        ``fault.inject`` event / ``fault.injected[kind]`` counter."""
        applied: List[FaultSpec] = []
        for i, spec in self.draws(payload.round_id, payload.client_id):
            # a fresh stream (offset key) for the corruption's own randomness
            # so the activation coin above stays untouched
            rng = purpose_rng(self.plan.seed, payload.round_id,
                              payload.client_id, FAULT_STREAM, i, 1)
            if spec.kind == "nan":
                payload = _poison(payload, np.float32(np.nan), rng)
            elif spec.kind == "inf":
                payload = _poison(payload, np.float32(np.inf), rng)
            elif spec.kind == "bitflip":
                payload = _bitflip(payload, rng)
            elif spec.kind == "truncate":
                payload = _truncate(payload, rng)
            elif spec.kind == "scale":
                payload = _scale(payload, spec.factor)
            elif spec.kind == "replay":
                payload = replace(payload,
                                  round_id=payload.round_id - spec.offset)
            elif spec.kind == "decode_error":
                key = (payload.round_id, payload.client_id)
                self._transient[key] = spec.count
            # crash / duplicate: flags only — the coordinator drops or
            # re-delivers; nothing in the payload changes
            applied.append(spec)
            self.injected.append({"round": payload.round_id
                                  if spec.kind != "replay" else
                                  payload.round_id + spec.offset,
                                  "client": payload.client_id,
                                  "kind": spec.kind})
            if self.rec.enabled:
                self.rec.counter(f"fault.injected[{spec.kind}]").inc()
                self.rec.event("fault.inject", cat="faults",
                               round=self.injected[-1]["round"],
                               client=payload.client_id, kind=spec.kind)
        return payload, applied

    def corrupt_lane(self, round_id: int, client_id: int,
                     leaves: Dict[str, np.ndarray]
                     ) -> Tuple[Dict[str, np.ndarray], List[FaultSpec]]:
        """Mesh-mode value faults on one lane's host arrays (path → array).

        Same activation coins as :meth:`corrupt` (the per-spec streams are
        shared), but only :data:`MESH_KINDS` apply — co-scheduled lanes have
        no wire, so codec/addressing kinds are skipped. Returns fresh arrays
        for corrupted paths; inputs are never mutated."""
        applied: List[FaultSpec] = []
        for i, spec in self.draws(round_id, client_id):
            if spec.kind not in MESH_KINDS:
                continue
            rng = purpose_rng(self.plan.seed, round_id, client_id,
                              FAULT_STREAM, i, 1)
            if spec.kind == "scale":
                leaves = {p: np.asarray(x) * np.float32(spec.factor)
                          for p, x in leaves.items()}
            else:
                value = np.float32(np.nan if spec.kind == "nan" else np.inf)
                path = sorted(leaves)[0]
                arr = np.array(leaves[path])
                if arr.size:
                    arr.reshape(-1)[int(rng.integers(arr.size))] = value
                leaves = {**leaves, path: arr}
            applied.append(spec)
            self.injected.append({"round": round_id, "client": client_id,
                                  "kind": spec.kind})
            if self.rec.enabled:
                self.rec.counter(f"fault.injected[{spec.kind}]").inc()
                self.rec.event("fault.inject", cat="faults", round=round_id,
                               client=client_id, kind=spec.kind)
        return leaves, applied

    def check_transient(self, round_id: int, client_id: int) -> None:
        """Raise ``TransientTransportError`` while this (round, client) still
        owes transient decode failures (consumes one per call)."""
        key = (round_id, client_id)
        remaining = self._transient.get(key, 0)
        if remaining > 0:
            self._transient[key] = remaining - 1
            if self._transient[key] == 0:
                del self._transient[key]
            raise TransientTransportError(
                f"transient decode failure ({remaining} remaining)",
                round_id=round_id, client_id=client_id, reason="transient")


# --------------------------------------------------------------------------
# payload corruption primitives (frozen dataclasses → always copy-on-write)
# --------------------------------------------------------------------------

def _first_path(payload: Payload) -> str:
    return sorted(payload.tensors)[0]


def _poison(payload: Payload, value: np.floating,
            rng: np.random.Generator) -> Payload:
    """Write ``value`` into one element of the first tensor (int8 payloads
    carry no float storage — poison the dequant scale instead)."""
    path = _first_path(payload)
    enc = payload.tensors[path]
    if enc.data.dtype == np.int8:
        enc = replace(enc, scale=float(value))
    else:
        data = enc.data.copy()
        if data.size:
            idx = int(rng.integers(data.size))
            data.reshape(-1)[idx] = data.dtype.type(value)
        enc = replace(enc, data=data)
    return replace(payload, tensors={**payload.tensors, path: enc})


def _scale(payload: Payload, factor: float) -> Payload:
    """Byzantine client: every tensor multiplied by ``factor``."""
    out = {}
    for path, enc in payload.tensors.items():
        if enc.data.dtype == np.int8:
            out[path] = replace(enc, scale=(enc.scale or 1.0) * factor)
        else:
            out[path] = replace(
                enc, data=(enc.data * enc.data.dtype.type(factor)))
    return replace(payload, tensors=out)


def _bitflip(payload: Payload, rng: np.random.Generator) -> Payload:
    paths = sorted(payload.tensors)
    path = paths[int(rng.integers(len(paths)))]
    enc = payload.tensors[path]
    raw = bytearray(enc.data.tobytes())
    if raw:
        byte = int(rng.integers(len(raw)))
        raw[byte] ^= 1 << int(rng.integers(8))
    data = np.frombuffer(bytes(raw),
                         dtype=enc.data.dtype).reshape(enc.data.shape)
    return replace(payload,
                   tensors={**payload.tensors, path: replace(enc, data=data)})


def _truncate(payload: Payload, rng: np.random.Generator) -> Payload:
    """Chop trailing elements off the first tensor's wire data while keeping
    the declared shape — the decode boundary must reject the length mismatch
    (transport satellite), never mis-reshape."""
    path = _first_path(payload)
    enc = payload.tensors[path]
    flat = enc.data.reshape(-1)
    if flat.size < 2:
        return payload
    drop = 1 + int(rng.integers(max(1, flat.size // 4)))
    declared = enc.shape if enc.shape is not None else tuple(enc.data.shape)
    enc = replace(enc, data=flat[:flat.size - drop].copy(), shape=declared)
    return replace(payload, tensors={**payload.tensors, path: enc})
