"""Client registry, participation sampler, and straggler/dropout models.

Everything here is *deterministic given (seed, round, client)*: random draws
use ``np.random.default_rng([seed, round, client])`` (SeedSequence spawning),
which is stable across processes and independent of PYTHONHASHSEED. The
simulated clock is a plain float accumulator — no wall time anywhere, so a
scenario replays bit-for-bit.

Per-purpose rng streams: every independent decision family gets its OWN
SeedSequence key suffix (:func:`purpose_rng`), so consuming — or never
consuming — one family's draw cannot shift another's. Latency/straggler
draws use the bare ``[seed, round, client]`` stream (historical layout,
bitwise-preserved), dropout uses suffix :data:`DROPOUT_STREAM`, and the
fault-injection layer (fedsrv/faults.py) uses :data:`FAULT_STREAM` — a
client drawn as dropped therefore cannot consume or displace a fault-plan
draw, keeping fault plans reproducible across participation settings.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

# SeedSequence key suffixes — one per independent decision family. The
# latency/straggler stream is the UNSUFFIXED historical key (appending a
# suffix would change every existing seeded scenario bitwise).
DROPOUT_STREAM = 1
FAULT_STREAM = 2


def purpose_rng(seed: int, round_id: int, client_id: int,
                *purpose: int) -> np.random.Generator:
    """The rng stream for one (seed, round, client, purpose…) decision.

    ``purpose`` suffixes (e.g. ``DROPOUT_STREAM``, or ``FAULT_STREAM, i`` for
    fault spec *i*) isolate decision families from each other: two streams
    with different suffixes never alias, so draws in one family cannot bleed
    into another no matter which draws a scenario actually consumes."""
    return np.random.default_rng([seed, round_id, client_id, *purpose])


@dataclass(frozen=True)
class ClientInfo:
    """One registered client.

    num_examples drives the aggregation weight wᵢ = nᵢ/Σnⱼ over the round's
    delivered subset; compute_speed scales the straggler model's latency
    (2.0 → twice as fast as the fleet baseline).
    """

    client_id: int
    num_examples: int
    compute_speed: float = 1.0


class SimClock:
    """Deterministic simulated clock (seconds). Monotone, replayable.

    Pass ``now_fn`` (e.g. ``time.monotonic``) to pin the clock to WALL time:
    :meth:`now` then returns elapsed real seconds since construction, so the
    same coordinator/server deadline arithmetic (``deadline = now() + ddl``)
    that drives simulated rounds drives the HTTP federation service
    (fedsrv/server.py) against real sockets. ``advance``/``advance_to``
    still work in wall mode — they raise the monotone floor (a retry backoff
    of 0.5 s means at-least-0.5 s later, which wall time satisfies by
    waiting) — and the timeline stays monotone even if ``now_fn`` jitters.
    """

    def __init__(self, start: float = 0.0, now_fn=None):
        self._t = float(start)
        self._now_fn = now_fn
        # wall origin: maps now_fn()'s epoch onto the simulated axis so a
        # restored/advanced _t stays the floor
        self._wall0 = None if now_fn is None else float(now_fn()) - self._t

    def now(self) -> float:
        if self._now_fn is not None:
            self._t = max(self._t, float(self._now_fn()) - self._wall0)
        return self._t

    def advance_to(self, t: float) -> float:
        self._t = max(self._t, float(t))
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t = self.now() + float(dt)
        return self._t

    # -- checkpoint/resume (crash-safe round state) ------------------------
    def state_dict(self) -> dict:
        return {"t": self.now()}

    def load_state(self, state: dict) -> None:
        """Restore the exact float — a resumed run must replay the same
        arrival timeline bitwise (checkpoint/round_state). In wall mode the
        restored value becomes the new origin: elapsed time accrues on top."""
        self._t = float(state["t"])
        if self._now_fn is not None:
            self._wall0 = float(self._now_fn()) - self._t


@dataclass(frozen=True)
class StragglerModel:
    """Seeded per-(round, client) latency and dropout draws.

    latency = mean_latency / compute_speed · lognormal(σ=jitter), optionally
    inflated by straggler_factor with prob straggler_prob. dropout_prob models
    a client that accepts the round but never reports back.
    """

    mean_latency: float = 1.0
    jitter: float = 0.25
    dropout_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_factor: float = 5.0
    seed: int = 0

    def _rng(self, round_id: int, client_id: int) -> np.random.Generator:
        return purpose_rng(self.seed, round_id, client_id)

    def draw(self, round_id: int, client: ClientInfo) -> "tuple[float, bool]":
        """(latency, is_straggler) for one (round, client) — same rng stream
        as :meth:`latency`, with the straggler coin exposed so the
        coordinator can count stragglers per round (obs metrics)."""
        rng = self._rng(round_id, client.client_id)
        base = self.mean_latency / max(client.compute_speed, 1e-6)
        lat = base * float(np.exp(rng.normal(0.0, self.jitter)))
        straggled = (self.straggler_prob > 0
                     and rng.random() < self.straggler_prob)
        if straggled:
            lat *= self.straggler_factor
        return lat, straggled

    def latency(self, round_id: int, client: ClientInfo) -> float:
        return self.draw(round_id, client)[0]

    def dropped(self, round_id: int, client: ClientInfo) -> bool:
        if self.dropout_prob <= 0:
            return False
        # independent stream (DROPOUT_STREAM suffix) so dropout and latency
        # never alias — and neither bleeds into the fault stream
        rng = purpose_rng(self.seed, round_id, client.client_id,
                          DROPOUT_STREAM)
        return bool(rng.random() < self.dropout_prob)


class ClientRegistry:
    """Registered clients + seeded per-round participation sampling."""

    def __init__(self, clients: Optional[Sequence[ClientInfo]] = None,
                 seed: int = 0):
        self.seed = seed
        self._clients: List[ClientInfo] = list(clients or [])
        ids = [c.client_id for c in self._clients]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate client ids in {ids}")

    # -- registration ------------------------------------------------------
    def register(self, info: ClientInfo) -> None:
        if any(c.client_id == info.client_id for c in self._clients):
            raise ValueError(f"client {info.client_id} already registered")
        self._clients.append(info)

    @classmethod
    def from_loaders(cls, loaders, seed: int = 0,
                     compute_speeds: Optional[Sequence[float]] = None
                     ) -> "ClientRegistry":
        """Registry mirroring a list of ClientLoader shards (nᵢ = shard size)."""
        speeds = list(compute_speeds or [1.0] * len(loaders))
        clients = [ClientInfo(client_id=i, num_examples=len(ld.sequences),
                              compute_speed=speeds[i])
                   for i, ld in enumerate(loaders)]
        return cls(clients, seed=seed)

    # -- views -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._clients)

    @property
    def clients(self) -> List[ClientInfo]:
        return sorted(self._clients, key=lambda c: c.client_id)

    def get(self, client_id: int) -> ClientInfo:
        for c in self._clients:
            if c.client_id == client_id:
                return c
        raise KeyError(client_id)

    def total_examples(self) -> int:
        return sum(c.num_examples for c in self._clients)

    # -- sampling ----------------------------------------------------------
    def sample_round(self, round_id: int, fraction: float = 1.0,
                     min_clients: int = 1) -> List[ClientInfo]:
        """Sample ⌈fraction·k⌉ participants for a round, without replacement.

        Deterministic in (registry seed, round_id). fraction=1.0 returns every
        client, in client_id order — the trivial synchronous policy.
        """
        if not self._clients:
            raise ValueError("empty registry")
        if fraction <= 0:
            raise ValueError(f"participation fraction must be > 0, got {fraction}")
        k = len(self._clients)
        if fraction >= 1.0:
            return self.clients
        m = min(k, max(min_clients, math.ceil(fraction * k)))
        rng = np.random.default_rng([self.seed, round_id])
        idx = sorted(rng.choice(k, size=m, replace=False).tolist())
        ordered = self.clients
        return [ordered[i] for i in idx]

    def weights_for(self, client_ids: Sequence[int]) -> List[float]:
        """Example-count weights wᵢ = nᵢ/Σnⱼ over a participating subset."""
        ns = [self.get(cid).num_examples for cid in client_ids]
        total = sum(ns)
        if total <= 0:
            raise ValueError(f"participating subset {client_ids} has no examples")
        return [n / total for n in ns]
