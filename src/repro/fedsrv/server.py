"""HTTP federation service: the round coordinator behind a real socket.

Everything below the socket is the existing stack — :class:`AdapterCodec`
defended decode (``_decode_flat`` → ``_validate_flat`` → ring scatter via
``decode_into``), the :class:`RoundCloseEngine` single-dispatch close, the
:class:`BytesLedger`, the obs recorder — composed exactly as the in-process
coordinator composes them, which is what makes the clean-twin parity check
in scripts/loadgen.py meaningful: an HTTP round must close BITWISE identical
to an in-process round over the same deliveries.

Endpoints (the Chorus split — ``submit_delta`` up, ``pull_latest`` down):

* ``POST /v1/rounds/{round_id}/deltas`` — one wire-framed uplink payload
  (fedsrv/wire.py). The PR-7 defended-path outcomes map onto HTTP statuses:

  ===========================  ======  ==================================
  outcome                      status  in-process twin
  ===========================  ======  ==================================
  accepted (lane scattered)    200     ``decode_into`` returned
  malformed frame              400     ``TransportError reason="wire"``
  bad/missing bearer token     401     — (auth stub)
  unknown client id            403     — (registry membership)
  stale / replayed / dup lane  409     ``StaleUplinkError`` (dropped)
  serving complete             410     —
  validation quarantine        422     ``TransportError`` (quarantined)
  quota exhausted / busy       429     ``TransientTransportError`` (retry)
  ===========================  ======  ==================================

  429 carries ``Retry-After``; the client's bounded-backoff retry loop is
  the same machinery the sim coordinator runs on its SimClock.
* ``GET /v1/adapters/latest`` — the merged global adapter as a wire frame,
  with ``X-Fed-Version`` (closes so far) and ``X-Fed-W0-Digest`` (sha256
  over the folded base weights, spec order) headers. The digest is the
  residual fold's witness: avg(B)·avg(A) alone cannot distinguish an exact
  FedEx close from naive FedAvg — the folded W0 can.
* ``GET /v1/healthz`` — round/version/delivery progress (also drives
  deadline-expiry checks, so a quorum round closes even with no new POSTs).
* ``GET /v1/metrics`` — obs registry snapshot + per-round records + ledger.

Concurrency: ``ThreadingHTTPServer`` handler threads run decode/validation
in parallel and serialise only at the ring scatter (RoundBuffers' internal
RLock) and the round bookkeeping (``self._lock``). A bounded semaphore
admits at most ``ServeConfig.max_concurrent`` uplink decodes — beyond that
POSTs bounce with 429 instead of growing the heap under a thundering herd.

Deadlines: the server's :class:`SimClock` is constructed with
``now_fn=time.monotonic``, so ``FedConfig.round_deadline`` (sim-seconds in
the coordinator) means WALL seconds here — same arithmetic, real time.
"""

from __future__ import annotations

import hashlib
import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import FedConfig, LoRAConfig, ServeConfig
from repro.core.engine import RoundCloseEngine, collect_w0_leaves
from repro.core.lora import init_lora
from repro.fedsrv.registry import SimClock
from repro.fedsrv.transport import (AdapterCodec, BytesLedger,
                                    StaleUplinkError, TransportError,
                                    ValidationPolicy)
from repro.fedsrv.wire import payload_from_wire, payload_to_wire
from repro.obs import make_recorder
from repro.util.logging import get_logger

logger = get_logger("fedsrv.server")

_DELTAS_RE = re.compile(r"^/v1/rounds/(-?\d+)/deltas$")


def init_global_state(model, lora_cfg: LoRAConfig, seed: int = 0):
    """(params, global_lora) from one seed — the EXACT init recipe of
    ``FederatedTrainer.__post_init__``, factored out so a server process and
    its clean twin (scripts/loadgen.py) derive identical state from
    (arch, lora_cfg, seed) alone."""
    rng = jax.random.key(seed)
    rp, rl = jax.random.split(rng)
    params = model.init(rp)
    global_lora = init_lora(rl, params, model.cfg, lora_cfg)
    if not jax.tree_util.tree_leaves(global_lora):
        raise ValueError("init_lora produced no adapters — check target "
                         "patterns / rank for this arch")
    return params, global_lora


def w0_digest(specs, params) -> str:
    """sha256 over the adapted base (W0) leaves in spec order, fp32 host
    bytes — the cheap cross-process witness that two parameter trees carry
    the same residual folds."""
    h = hashlib.sha256()
    leaves = collect_w0_leaves(specs, params)
    for s in specs:
        h.update(np.asarray(jax.device_get(leaves[s.key]),
                            np.float32).tobytes())
    return h.hexdigest()


def hetero_w0_digest(specs, client_params) -> str:
    """sha256 chain over every client's W0 digest in client-id order — the
    ragged-round witness: a hetero close folds a DIFFERENT residual into each
    client's base, so the single-tree digest cannot certify the fleet."""
    h = hashlib.sha256()
    for p in client_params:
        h.update(bytes.fromhex(w0_digest(specs, p)))
    return h.hexdigest()


class FederationServer:
    """Round lifecycle + defended ingest behind the HTTP handler.

    All federation semantics come from ``fed_cfg`` (clients, rounds, quorum,
    ``round_deadline`` in wall-seconds, weighting, codec, engine backend);
    ``serve_cfg`` adds only the socket surface (port, backpressure bound,
    quota, auth token). Rounds are numbered 0..rounds-1 and every client
    0..num_clients-1 has a lane in each (full-participation candidate set;
    partial delivery is handled by quorum + deadline exactly as in the sim
    coordinator).
    """

    def __init__(self, params, global_lora, *, scale: float,
                 fed_cfg: FedConfig, serve_cfg: Optional[ServeConfig] = None,
                 recorder=None):
        if fed_cfg.engine == "off":
            raise ValueError("--mode serve needs the streaming close engine "
                             "(engine=off is the eager list path)")
        if fed_cfg.method not in ("fedex", "fedex_svd", "hetero"):
            raise ValueError(f"serve mode closes fedex/fedex_svd/hetero "
                             f"rounds, got method={fed_cfg.method!r}")
        self.fed_cfg = fed_cfg
        self.serve_cfg = serve_cfg or ServeConfig()
        self.rec = recorder if recorder is not None \
            else make_recorder(fed_cfg.obs)
        # SimClock in WALL mode: round_deadline means real seconds
        self.clock = SimClock(now_fn=time.monotonic)
        self.codec = AdapterCodec(
            fed_cfg.quantize_uplink, recorder=self.rec,
            validation=ValidationPolicy(enabled=fed_cfg.uplink_validation,
                                        max_norm=fed_cfg.uplink_max_norm))
        self.codec.register_spec(global_lora)
        self.ledger = BytesLedger()
        # ragged-rank serving: hetero closes per-client bases, so the server
        # carries one params tree per client (all aliases of the same arrays
        # until the first hetero close diverges them)
        self.hetero = (fed_cfg.method == "hetero"
                       or bool(fed_cfg.client_ranks))
        self.client_ranks = list(fed_cfg.client_ranks) or None
        if self.hetero:
            eng_method = "hetero"
        elif fed_cfg.method == "fedex_svd" and fed_cfg.svd_rank:
            eng_method = "fedex_svd"
        else:
            eng_method = "fedex"
        self.engine = RoundCloseEngine(
            params, global_lora, c_max=fed_cfg.num_clients, scale=scale,
            method=eng_method, svd_rank=fed_cfg.svd_rank,
            backend=fed_cfg.engine, depth=fed_cfg.ring_depth,
            recorder=self.rec if self.rec.enabled else None,
            chunk=fed_cfg.close_chunk,
            client_ranks=self.client_ranks if self.hetero else None)
        self.params = params
        self.client_params = [params] * fed_cfg.num_clients \
            if self.hetero else None
        self.client_loras: Dict[int, Any] = {}   # cid → rank-r_i adapters
        self.global_lora = global_lora
        self.version = 0            # closes so far; bumps on every close
        self.round_id = 0
        self.done = False
        self._lock = threading.RLock()
        self._uplink_slots = threading.BoundedSemaphore(
            self.serve_cfg.max_concurrent)
        self._quota: Dict[Tuple[int, int], int] = {}   # (round, client) → POSTs
        self._examples: Dict[int, float] = {}          # client → declared n
        self._deadline_at: Optional[float] = None
        # the previous close's DeferredDivergence: resolved lazily at the
        # NEXT close (after that round's uplinks landed), so the ring-write/
        # close-window overlap the obs report proves is real, not staged
        self._pending_div = None
        self._digest_cache: Tuple[int, Optional[str]] = (-1, None)
        self._t_wall0 = time.monotonic()
        self._open_round(0)

    # -- round lifecycle (callers hold self._lock) --------------------------
    def _open_round(self, rid: int) -> None:
        slots = {cid: cid for cid in range(self.fed_cfg.num_clients)}
        ddl = None
        if self.fed_cfg.round_deadline > 0:
            ddl = self.clock.now() + self.fed_cfg.round_deadline
        self.engine.buffers.begin_round(slots, round_id=rid, deadline=ddl,
                                        now=self.clock.now())
        self.round_id = rid
        self._deadline_at = ddl
        logger.info("round %d open (C=%d, deadline=%s)", rid, len(slots),
                    "none" if ddl is None else f"+{self.fed_cfg.round_deadline}s")

    def _resolve_pending(self) -> None:
        if self._pending_div is not None:
            self._pending_div.resolve()
            self._pending_div = None

    def _close_round(self, rid: int) -> None:
        delivered = sorted(self.engine.buffers.delivered_in(rid))
        weights = None
        if self.fed_cfg.weighting == "examples":
            ns = [self._examples.get(c, 1.0) for c in delivered]
            weights = [n / sum(ns) for n in ns]
        # round N-1's host sync happens HERE, after round N's writes
        self._resolve_pending()
        if self.hetero:
            # per-client bases: every delivered client's OWN W0 absorbs its
            # rank-r_i residual; the shared r_max truncation is the downlink
            new_cp, new_loras, self.global_lora, div = \
                self.engine.close_hetero(self.client_params, delivered,
                                         weights, round_id=rid)
            for cid, p in new_cp.items():
                self.client_params[cid] = p
            self.client_loras.update(new_loras)
            self.params = self.client_params[0]
        else:
            self.global_lora, self.params, div = self.engine.close(
                self.params, delivered, weights, round_id=rid)
        self._pending_div = div
        self.version += 1
        if self.rec.enabled:
            self.rec.round_set(rid, delivered=len(delivered),
                               sampled=self.fed_cfg.num_clients)
            self._stamp_round_comm(rid)
            self.rec.event("round.close", cat="server", round=rid,
                           delivered=len(delivered), version=self.version)
        logger.info("round %d closed: %d/%d delivered, version=%d", rid,
                    len(delivered), self.fed_cfg.num_clients, self.version)
        if self.version >= self.fed_cfg.rounds:
            self.done = True
            self._resolve_pending()  # no further writes are coming
        else:
            self._open_round(rid + 1)

    def _maybe_close(self) -> bool:
        """Close the current round if complete (all lanes) or expired with
        quorum. Caller holds self._lock."""
        if self.done:
            return False
        rid = self.round_id
        delivered = self.engine.buffers.delivered_in(rid)
        if len(delivered) >= self.fed_cfg.num_clients:
            self._close_round(rid)
            return True
        if (self._deadline_at is not None
                and self.clock.now() >= self._deadline_at
                and len(delivered) >= max(1, self.fed_cfg.min_quorum)):
            self._close_round(rid)
            return True
        return False

    def tick(self) -> None:
        """Deadline poll — lets a quorum round close with no new POSTs."""
        with self._lock:
            self._maybe_close()

    def finalize(self) -> None:
        """Resolve any outstanding divergence handle (blocks on the device)
        — call before writing metrics/trace so every closed round record
        carries close_block_us + divergence."""
        with self._lock:
            self._resolve_pending()

    # -- accounting ---------------------------------------------------------
    def _stamp_round_comm(self, rid: int) -> None:
        """Copy the ledger's per-round comm totals onto the obs round record
        (caller holds self._lock). Called at close AND again from any
        accounting that lands after the close — a handler thread whose
        ``write_flat`` made the round complete can be accounted behind the
        thread that closed it, so the record must converge, not freeze."""
        tot = self.ledger.round_totals(rid)
        self.rec.round_set(rid,
                           uplink_bytes=tot["uplink_bytes"],
                           uplink_params=tot["uplink_params"],
                           downlink_bytes=tot["downlink_bytes"],
                           downlink_params=tot["downlink_params"])

    def _account(self, payload, body_len: int, header_len: int,
                 direction: str, note: str) -> None:
        """Ledger + uplink.http_* counters for one parsed POST. The payload
        octets go under ``direction`` (uplink / quarantined / dropped); the
        HTTP request line + headers + wire-frame envelope go under the
        separate ``http_overhead`` direction so per-param reconciliation
        stays exact (they are real socket bytes, but zero params)."""
        overhead = (body_len - payload.nbytes) + header_len
        self.ledger.record(payload, note=note, direction=direction)
        self.ledger.record_raw(payload.round_id, "http_overhead", overhead,
                               client_id=payload.client_id,
                               note="frame+headers")
        if self.rec.enabled:
            self.rec.counter("uplink.http_requests").inc()
            self.rec.counter("uplink.http_bytes").inc(body_len + header_len)
            self.rec.counter("uplink.http_overhead_bytes").inc(overhead)
            if payload.round_id < self.round_id or self.done:
                self._stamp_round_comm(payload.round_id)  # late account

    # -- request handlers ---------------------------------------------------
    def handle_submit(self, path_round: int, body: bytes, header_len: int,
                      token: Optional[str], examples: Optional[float]
                      ) -> Tuple[int, Dict[str, Any], Dict[str, str]]:
        """One uplink POST → (status, json body, extra headers)."""
        rec = self.rec
        cfg = self.serve_cfg
        if cfg.token and token != cfg.token:
            if rec.enabled:
                rec.counter("uplink.http_rejected[auth]").inc()
            return 401, {"error": "auth",
                         "detail": "missing or bad bearer token"}, {}
        try:
            payload = payload_from_wire(body)
        except TransportError as e:
            if rec.enabled:
                rec.counter("uplink.http_rejected[wire]").inc()
            return 400, {"error": "wire", "detail": str(e)}, {}
        cid = payload.client_id
        if not 0 <= cid < self.fed_cfg.num_clients:
            if rec.enabled:
                rec.counter("uplink.http_rejected[unknown_client]").inc()
            return 403, {"error": "unknown_client", "client": cid}, {}
        if payload.round_id != path_round:
            if rec.enabled:
                rec.counter("uplink.http_rejected[wire]").inc()
            return 400, {"error": "wire",
                         "detail": f"payload round {payload.round_id} != "
                                   f"path round {path_round}"}, {}
        with self._lock:
            if self.done:
                return 410, {"error": "done",
                             "detail": "all rounds served"}, {}
            self._maybe_close()  # a passed deadline closes before we route
            q = self._quota.get((path_round, cid), 0)
            if q >= cfg.quota_per_round:
                if rec.enabled:
                    rec.counter("uplink.http_rejected[quota]").inc()
                return 429, {"error": "quota",
                             "detail": f"{q} POSTs for (round {path_round}, "
                                       f"client {cid})"}, \
                    {"Retry-After": "1"}
            self._quota[(path_round, cid)] = q + 1
            if examples is not None:
                self._examples[cid] = float(examples)
        # backpressure: bounded concurrent decodes — never block the handler
        if not self._uplink_slots.acquire(blocking=False):
            if rec.enabled:
                rec.counter("uplink.http_rejected[busy]").inc()
            return 429, {"error": "busy",
                         "detail": "uplink decode slots exhausted"}, \
                {"Retry-After": "0.1"}
        try:
            weight = None
            if self.fed_cfg.weighting == "examples" and examples is not None:
                weight = float(examples)
            # defended path: _decode_flat → _validate_flat → ring scatter;
            # decode/validate run CONCURRENTLY across handler threads, only
            # the scatter serialises (RoundBuffers' ring lock)
            self.codec.decode_into(payload, self.engine.buffers,
                                   weight=weight)
        except StaleUplinkError as e:
            with self._lock:
                self._account(payload, len(body), header_len, "dropped",
                              f"drop:{e.reason}")
            return 409, {"error": "stale", "reason": e.reason}, {}
        except TransportError as e:
            with self._lock:
                self._account(payload, len(body), header_len, "quarantined",
                              f"quarantine:{e.reason}")
                if rec.enabled:
                    rec.counter(f"uplink.quarantined[{e.reason}]").inc()
            return 422, {"error": "quarantined", "reason": e.reason}, {}
        finally:
            self._uplink_slots.release()
        with self._lock:
            self._account(payload, len(body), header_len, "uplink",
                          "http uplink")
            delivered = len(self.engine.buffers.delivered_in(path_round)) \
                if path_round == self.round_id and not self.done else None
            closed = self._maybe_close()
            return 200, {"status": "accepted", "round": path_round,
                         "delivered": delivered, "closed": closed,
                         "version": self.version}, {}

    def handle_latest(self) -> Tuple[int, bytes, Dict[str, str]]:
        with self._lock:
            version = self.version
            tree = self.global_lora
            digest = self._current_digest()
            rid = self.round_id
        payload = self.codec.encode(tree, round_id=version, client_id=-1,
                                    direction="downlink")
        body = payload_to_wire(payload)
        with self._lock:
            self.ledger.record(payload, note="pull_latest")
            self.ledger.record_raw(version, "http_overhead",
                                   len(body) - payload.nbytes,
                                   note="frame (downlink)")
            if self.rec.enabled:
                self.rec.counter("downlink.http_requests").inc()
                self.rec.counter("downlink.http_bytes").inc(len(body))
        return 200, body, {"X-Fed-Version": str(version),
                           "X-Fed-Round": str(rid),
                           "X-Fed-W0-Digest": digest}

    def _current_digest(self) -> str:
        ver, cached = self._digest_cache
        if ver != self.version or cached is None:
            cached = hetero_w0_digest(self.engine.specs, self.client_params) \
                if self.hetero \
                else w0_digest(self.engine.specs, self.params)
            self._digest_cache = (self.version, cached)
        return cached

    def handle_healthz(self) -> Tuple[int, Dict[str, Any]]:
        with self._lock:
            self._maybe_close()
            delivered = None
            if not self.done:
                delivered = len(
                    self.engine.buffers.delivered_in(self.round_id))
            return 200, {
                "status": "done" if self.done else "serving",
                "round": self.round_id,
                "version": self.version,
                "rounds": self.fed_cfg.rounds,
                "delivered": delivered,
                "expected": self.fed_cfg.num_clients,
                "uptime_s": round(time.monotonic() - self._t_wall0, 3),
            }

    def handle_metrics(self) -> Tuple[int, Dict[str, Any]]:
        with self._lock:
            out: Dict[str, Any] = {
                "ledger": self.ledger.totals(),
                "version": self.version,
                "rounds_closed": self.version,
            }
            if self.rec.enabled:
                out.update(self.rec.metrics.snapshot(),
                           rounds=self.rec.round_records())
            return 200, out


class FederationHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    # a wedged client socket must not hold a handler thread forever
    timeout = 30

    def __init__(self, addr, fed: FederationServer):
        self.fed = fed
        super().__init__(addr, _Handler)


class _Handler(BaseHTTPRequestHandler):
    server_version = "fedsrv/1.0"
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # route chatter to our logger
        logger.debug("%s %s", self.address_string(), fmt % args)

    # -- response plumbing ---------------------------------------------------
    def _send(self, code: int, body: bytes, ctype: str,
              headers: Optional[Dict[str, str]] = None) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, obj: Dict[str, Any],
                   headers: Optional[Dict[str, str]] = None) -> None:
        self._send(code, json.dumps(obj).encode("utf-8"),
                   "application/json", headers)

    def _header_len(self) -> int:
        # measured HTTP framing: request line + raw header block (the
        # http_overhead ledger direction and the uplink.http_* counters
        # reconcile against this, satellite fix)
        return len(self.requestline) + 2 + len(bytes(self.headers))

    def _token(self) -> Optional[str]:
        auth = self.headers.get("Authorization", "")
        return auth[len("Bearer "):] if auth.startswith("Bearer ") else None

    # -- routes --------------------------------------------------------------
    def do_GET(self):
        fed = self.server.fed
        with fed.rec.span("http.request", cat="http", method="GET",
                          path=self.path):
            if self.path == "/v1/healthz":
                code, obj = fed.handle_healthz()
                self._send_json(code, obj)
            elif self.path == "/v1/metrics":
                code, obj = fed.handle_metrics()
                self._send_json(code, obj)
            elif self.path == "/v1/adapters/latest":
                code, body, headers = fed.handle_latest()
                self._send(code, body, "application/octet-stream", headers)
            else:
                self._send_json(404, {"error": "not_found",
                                      "path": self.path})

    def do_POST(self):
        fed = self.server.fed
        m = _DELTAS_RE.match(self.path)
        with fed.rec.span("http.request", cat="http", method="POST",
                          path=self.path):
            if m is None:
                self._send_json(404, {"error": "not_found",
                                      "path": self.path})
                return
            length = int(self.headers.get("Content-Length", 0) or 0)
            body = self.rfile.read(length)
            examples = self.headers.get("X-Fed-Examples")
            code, obj, headers = fed.handle_submit(
                int(m.group(1)), body, self._header_len(),
                token=self._token(),
                examples=float(examples) if examples else None)
            self._send_json(code, obj, headers)


def start_http_server(fed: FederationServer, host: str = "127.0.0.1",
                      port: int = 0) -> FederationHTTPServer:
    """Bind + serve on a daemon thread; returns the bound server (its
    ``server_address[1]`` is the actual port — pass 0 for ephemeral)."""
    httpd = FederationHTTPServer((host, port), fed)
    t = threading.Thread(target=httpd.serve_forever, name="fedsrv-http",
                         daemon=True)
    t.start()
    logger.info("fedsrv listening on http://%s:%d", *httpd.server_address)
    return httpd
