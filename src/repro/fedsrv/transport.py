"""Adapter transport: payload serialization, uplink quantization, bytes ledger.

The coordinator never hands raw trees between "client" and "server": every
adapter crosses through :class:`AdapterCodec`, so uplink quantization (fp16 /
int8) actually changes the numbers the server aggregates — exactness claims
are then made about what was *transmitted*, as in a real deployment.

The :class:`BytesLedger` records every payload (params + bytes, per round and
direction) and can be reconciled against the analytic per-round parameter
counts of ``core/comm.py::round_comm_params`` — the ledger is the measured
twin of that closed-form accounting.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs import NULL
from repro.util.tree import flatten_with_paths, unflatten_from_paths

CODECS = ("none", "fp16", "int8")


@dataclass(frozen=True)
class EncodedTensor:
    data: np.ndarray            # fp32 / fp16 / int8 storage
    scale: Optional[float]      # int8 dequant scale (absmax/127), else None

    @property
    def nbytes(self) -> int:
        return int(self.data.nbytes) + (4 if self.scale is not None else 0)

    @property
    def num_params(self) -> int:
        return int(self.data.size)


@dataclass(frozen=True)
class Payload:
    """One serialized adapter tree in flight (uplink delta or downlink global)."""

    round_id: int
    client_id: int
    direction: str              # "uplink" | "downlink"
    codec: str
    tensors: Dict[str, EncodedTensor]

    @property
    def num_params(self) -> int:
        return sum(t.num_params for t in self.tensors.values())

    @property
    def nbytes(self) -> int:
        return sum(t.nbytes for t in self.tensors.values())


class AdapterCodec:
    """Encode/decode adapter trees with optional uplink factor quantization.

    * ``none`` — fp32 passthrough (4 B/param).
    * ``fp16`` — half-precision factors (2 B/param), decode upcasts to fp32.
    * ``int8`` — per-tensor symmetric absmax quantization (1 B/param + one
      fp32 scale per tensor).
    """

    def __init__(self, quantize: str = "none", recorder=None):
        if quantize not in CODECS:
            raise ValueError(f"quantize must be one of {CODECS}, got {quantize!r}")
        self.quantize = quantize
        # obs recorder (repro.obs): encode/decode spans + per-direction byte
        # counters. The coordinator propagates its own recorder here.
        self.rec = recorder if recorder is not None else NULL

    def _encode_leaf(self, x, codec: str) -> EncodedTensor:
        arr = np.asarray(x, dtype=np.float32)
        if codec == "none":
            return EncodedTensor(arr, None)
        if codec == "fp16":
            return EncodedTensor(arr.astype(np.float16), None)
        absmax = float(np.max(np.abs(arr))) if arr.size else 0.0
        scale = absmax / 127.0 if absmax > 0 else 1.0
        q = np.clip(np.rint(arr / scale), -127, 127).astype(np.int8)
        return EncodedTensor(q, scale)

    def encode(self, tree: Any, *, round_id: int, client_id: int,
               direction: str = "uplink") -> Payload:
        codec = self.quantize if direction == "uplink" else "none"
        with self.rec.span("codec.encode", cat="transport", round=round_id,
                           client=client_id, codec=codec):
            tensors = {path: self._encode_leaf(leaf, codec)
                       for path, leaf in flatten_with_paths(tree).items()}
        payload = Payload(round_id=round_id, client_id=client_id,
                          direction=direction, codec=codec, tensors=tensors)
        if self.rec.enabled:
            self.rec.counter(f"transport.{direction}_bytes").inc(payload.nbytes)
            self.rec.counter(f"transport.{direction}_payloads").inc()
        return payload

    def _decode_flat(self, payload: Payload) -> Dict[str, np.ndarray]:
        flat = {}
        for path, enc in payload.tensors.items():
            if enc.scale is not None:
                flat[path] = enc.data.astype(np.float32) * enc.scale
            else:
                flat[path] = enc.data.astype(np.float32)
        return flat

    def decode(self, payload: Payload) -> Any:
        return unflatten_from_paths(self._decode_flat(payload))

    def decode_into(self, payload: Payload, buffers: Any) -> Any:
        """Decode straight into a streaming sink (core/engine.RoundBuffers).

        The dequantized leaves are scattered into the sink's preallocated
        ``(C_max, …)`` device stacks at the payload's client lane as the
        delivery arrives — the round close reads the stacks, so there is no
        burst of stacking work at the deadline. The payload's ``round_id``
        selects the stack SET in the sink's double-buffer ring, so round
        N+1 uplinks stream into a fresh set while round N's close still owns
        the previous one. The sink aggregates exactly what was transmitted
        (quantization included), like :meth:`decode`. Also returns the host
        tree (one decode, shared) so the coordinator's ``Delivery.lora``
        stays inspectable by diagnostics and tests.
        """
        with self.rec.span("codec.decode", cat="transport",
                           round=payload.round_id, client=payload.client_id,
                           codec=payload.codec, nbytes=payload.nbytes):
            flat = self._decode_flat(payload)
            buffers.write_flat(payload.client_id, flat,
                               round_id=payload.round_id)
        return unflatten_from_paths(flat)


@dataclass
class LedgerEntry:
    round_id: int
    direction: str
    client_id: int
    params: int
    nbytes: int
    codec: str
    note: str = ""


class BytesLedger:
    """Per-round communication ledger (measured params + bytes)."""

    def __init__(self):
        self.entries: List[LedgerEntry] = []

    def record(self, payload: Payload, note: str = "") -> None:
        self.entries.append(LedgerEntry(
            round_id=payload.round_id, direction=payload.direction,
            client_id=payload.client_id, params=payload.num_params,
            nbytes=payload.nbytes, codec=payload.codec, note=note))

    def record_analytic(self, round_id: int, direction: str, params: int,
                        bytes_per_param: int = 4, client_id: int = -1,
                        note: str = "") -> None:
        """Account a payload we model analytically (e.g. the factored residual
        broadcast, whose params come from decompose.factored_residual_params)."""
        self.entries.append(LedgerEntry(
            round_id=round_id, direction=direction, client_id=client_id,
            params=int(params), nbytes=int(params) * bytes_per_param,
            codec="none", note=note))

    # -- views -------------------------------------------------------------
    def round_totals(self, round_id: int) -> Dict[str, int]:
        tot = {"uplink_params": 0, "uplink_bytes": 0,
               "downlink_params": 0, "downlink_bytes": 0}
        for e in self.entries:
            if e.round_id != round_id:
                continue
            tot[f"{e.direction}_params"] += e.params
            tot[f"{e.direction}_bytes"] += e.nbytes
        return tot

    def totals(self) -> Dict[str, int]:
        rounds = {e.round_id for e in self.entries}
        out = {"uplink_params": 0, "uplink_bytes": 0,
               "downlink_params": 0, "downlink_bytes": 0}
        for r in rounds:
            for key, v in self.round_totals(r).items():
                out[key] += v
        return out

    def reconcile(self, round_id: int, analytic: Dict[str, int]
                  ) -> Dict[str, Any]:
        """Compare measured param counts against core/comm.py's closed form.

        analytic: the dict returned by ``round_comm_params`` (uplink/downlink
        PARAM counts for the round). Bytes are codec-dependent so only params
        are reconciled. Returns per-direction measured/analytic/match.
        """
        got = self.round_totals(round_id)
        out: Dict[str, Any] = {}
        for direction in ("uplink", "downlink"):
            measured = got[f"{direction}_params"]
            expected = int(analytic.get(direction, 0))
            out[direction] = {"measured": measured, "analytic": expected,
                              "match": measured == expected}
        out["ok"] = all(out[d]["match"] for d in ("uplink", "downlink"))
        return out

    def summary_lines(self) -> List[str]:
        rounds = sorted({e.round_id for e in self.entries})
        lines = [f"{'round':>5} {'up_params':>10} {'up_bytes':>10} "
                 f"{'down_params':>11} {'down_bytes':>10}"]
        for r in rounds:
            t = self.round_totals(r)
            lines.append(f"{r:>5} {t['uplink_params']:>10} {t['uplink_bytes']:>10} "
                         f"{t['downlink_params']:>11} {t['downlink_bytes']:>10}")
        t = self.totals()
        lines.append(f"{'all':>5} {t['uplink_params']:>10} {t['uplink_bytes']:>10} "
                     f"{t['downlink_params']:>11} {t['downlink_bytes']:>10}")
        return lines
