"""Adapter transport: payload serialization, uplink quantization, bytes ledger.

The coordinator never hands raw trees between "client" and "server": every
adapter crosses through :class:`AdapterCodec`, so uplink quantization (fp16 /
int8) actually changes the numbers the server aggregates — exactness claims
are then made about what was *transmitted*, as in a real deployment.

The :class:`BytesLedger` records every payload (params + bytes, per round and
direction) and can be reconciled against the analytic per-round parameter
counts of ``core/comm.py::round_comm_params`` — the ledger is the measured
twin of that closed-form accounting. Uplinks that never reach the close —
quarantined by validation, or dropped by the ring as stale/replayed/duplicate
— are recorded under their own ``quarantined``/``dropped`` directions, so
``reconcile()`` stays honest under faults: only *delivered* bytes count as
uplink/downlink traffic.

The defended ingest path: :meth:`AdapterCodec.decode_into` (and
:meth:`~AdapterCodec.decode`) validate every decoded payload against the
codec's :class:`ValidationPolicy` — declared-shape-vs-wire-length at the
decode boundary, per-leaf shape check against the registered adapter spec
(:meth:`AdapterCodec.register_spec`), a finite check, and an optional
∞-norm outlier limit. Failures raise a typed :class:`TransportError` with
(round, client) context so the coordinator can QUARANTINE the uplink — the
lane stays zero and the engine's zero-weight masking excludes it exactly —
instead of scattering poison into the donated device stacks.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import NULL
from repro.util.tree import flatten_with_paths, unflatten_from_paths

CODECS = ("none", "fp16", "int8")


class TransportError(RuntimeError):
    """A payload failed decode/validation — quarantine it (round/client
    context travels with the error; ``reason`` is the short metric label)."""

    def __init__(self, message: str, *, round_id=None, client_id=None,
                 reason: str = "corrupt"):
        super().__init__(
            f"round={round_id} client={client_id} [{reason}]: {message}")
        self.round_id = round_id
        self.client_id = client_id
        self.reason = reason


class TransientTransportError(TransportError):
    """A decode failure worth retrying (the coordinator backs off on its
    SimClock and re-attempts up to its retry budget)."""


class StaleUplinkError(TransportError):
    """The payload's ADDRESS is bad — replayed/unknown round_id, or a
    duplicate (client, round) lane — so the ring refused it. Dropped, not
    quarantined: the bytes never threatened a live lane."""


@dataclass(frozen=True)
class EncodedTensor:
    data: np.ndarray            # fp32 / fp16 / int8 wire storage
    scale: Optional[float]      # int8 dequant scale (absmax/127), else None
    # declared logical shape; None → data.shape. A corrupted/truncated wire
    # buffer keeps its declared shape, so the decode boundary can detect the
    # length mismatch instead of mis-reshaping (fedsrv/faults.py exercises
    # this).
    shape: Optional[Tuple[int, ...]] = None

    @property
    def declared_shape(self) -> Tuple[int, ...]:
        return self.shape if self.shape is not None else tuple(self.data.shape)

    @property
    def nbytes(self) -> int:
        return int(self.data.nbytes) + (4 if self.scale is not None else 0)

    @property
    def num_params(self) -> int:
        return int(self.data.size)


@dataclass(frozen=True)
class Payload:
    """One serialized adapter tree in flight (uplink delta or downlink global).

    ``rank`` is the DECLARED LoRA rank of a ragged (hetero) uplink: the
    factor leaves on the wire are the client's true rank-r tensors, and the
    defended decode pads them to the registered r_max spec with zeros before
    scatter. ``None`` means uniform-rank (legacy wire frames parse to None).
    """

    round_id: int
    client_id: int
    direction: str              # "uplink" | "downlink"
    codec: str
    tensors: Dict[str, EncodedTensor]
    rank: Optional[int] = None  # declared ragged rank (hetero), None=uniform

    @property
    def num_params(self) -> int:
        return sum(t.num_params for t in self.tensors.values())

    @property
    def nbytes(self) -> int:
        return sum(t.nbytes for t in self.tensors.values())


@dataclass(frozen=True)
class ValidationPolicy:
    """What the defended decode checks (quarantine on failure).

    ``max_norm`` is the ∞-norm outlier limit per decoded leaf (byzantine-
    scaled uplinks); 0 disables it. ``check_spec`` only bites once an
    adapter spec is registered via :meth:`AdapterCodec.register_spec`.
    """

    enabled: bool = True
    check_finite: bool = True
    check_spec: bool = True
    max_norm: float = 0.0


class AdapterCodec:
    """Encode/decode adapter trees with optional uplink factor quantization.

    * ``none`` — fp32 passthrough (4 B/param).
    * ``fp16`` — half-precision factors (2 B/param), decode upcasts to fp32.
    * ``int8`` — per-tensor symmetric absmax quantization (1 B/param + one
      fp32 scale per tensor).

    Decoding is DEFENDED (see module docstring): wire-length-vs-declared-
    shape at the decode boundary, then the :class:`ValidationPolicy` checks.
    All failures raise :class:`TransportError` (or a subclass) carrying the
    payload's (round, client) identity.
    """

    def __init__(self, quantize: str = "none", recorder=None,
                 validation: Optional[ValidationPolicy] = None):
        if quantize not in CODECS:
            raise ValueError(f"quantize must be one of {CODECS}, got {quantize!r}")
        self.quantize = quantize
        # obs recorder (repro.obs): encode/decode spans + per-direction byte
        # counters. The coordinator propagates its own recorder here.
        self.rec = recorder if recorder is not None else NULL
        self.validation = validation if validation is not None \
            else ValidationPolicy()
        # path → expected decoded leaf shape (register_spec)
        self.spec: Optional[Dict[str, Tuple[int, ...]]] = None
        # cumulative ingest throughput (decode_into only): wire bytes landed
        # in the sink over wall time since the first ingest, surfaced as the
        # uplink.ingest_bytes_per_s gauge
        self._ingest_bytes = 0
        self._ingest_t0: Optional[int] = None
        # the HTTP server decodes uplinks from many handler threads at once;
        # the throughput accumulator is the only read-modify-write shared
        # state in the codec, so it gets its own lock
        self._ingest_lock = threading.Lock()

    def register_spec(self, tree: Any) -> None:
        """Pin the expected adapter structure (path → shape). Decoded uplinks
        must match it exactly — extra/missing leaves or shape drift are
        quarantined, never scattered into the ``(C_max, …)`` stacks."""
        self.spec = {path: tuple(np.shape(leaf))
                     for path, leaf in flatten_with_paths(tree).items()}

    def _encode_leaf(self, x, codec: str) -> EncodedTensor:
        arr = np.asarray(x, dtype=np.float32)
        if codec == "none":
            return EncodedTensor(arr, None)
        if codec == "fp16":
            return EncodedTensor(arr.astype(np.float16), None)
        absmax = float(np.max(np.abs(arr))) if arr.size else 0.0
        scale = absmax / 127.0 if absmax > 0 else 1.0
        q = np.clip(np.rint(arr / scale), -127, 127).astype(np.int8)
        return EncodedTensor(q, scale)

    def encode(self, tree: Any, *, round_id: int, client_id: int,
               direction: str = "uplink",
               rank: Optional[int] = None) -> Payload:
        codec = self.quantize if direction == "uplink" else "none"
        with self.rec.span("codec.encode", cat="transport", round=round_id,
                           client=client_id, codec=codec):
            tensors = {path: self._encode_leaf(leaf, codec)
                       for path, leaf in flatten_with_paths(tree).items()}
        payload = Payload(round_id=round_id, client_id=client_id,
                          direction=direction, codec=codec, tensors=tensors,
                          rank=None if rank is None else int(rank))
        if self.rec.enabled:
            self.rec.counter(f"transport.{direction}_bytes").inc(payload.nbytes)
            self.rec.counter(f"transport.{direction}_payloads").inc()
        return payload

    def _decode_flat(self, payload: Payload) -> Dict[str, np.ndarray]:
        """Dequantize the wire tensors; the FIRST defense line lives here:
        a wire buffer whose element count disagrees with its declared shape
        raises a typed :class:`TransportError` with (round, client) context
        — never a deep ``np.frombuffer`` crash or a silent mis-reshape."""
        flat = {}
        for path, enc in payload.tensors.items():
            declared = enc.declared_shape
            expected = int(np.prod(declared, dtype=np.int64)) if declared \
                else 1
            if int(enc.data.size) != expected:
                raise TransportError(
                    f"{path}: wire buffer has {enc.data.size} elements "
                    f"({enc.data.nbytes} B) but declares shape {declared} "
                    f"({expected} elements)",
                    round_id=payload.round_id, client_id=payload.client_id,
                    reason="bytes")
            arr = enc.data.reshape(declared)
            if enc.scale is not None:
                flat[path] = arr.astype(np.float32) * enc.scale
            else:
                flat[path] = arr.astype(np.float32)
        return flat

    def _validate_flat(self, payload: Payload,
                       flat: Dict[str, np.ndarray]) -> None:
        """The ValidationPolicy stage: spec/shape, finite, ∞-norm limit."""
        v = self.validation
        if not v.enabled:
            return
        ctx = dict(round_id=payload.round_id, client_id=payload.client_id)
        spec = self.spec
        if v.check_spec and spec is not None:
            # dict-view equality is O(n) key hashing with no allocation; the
            # sorted diffs are only built to format the failure message
            if flat.keys() != spec.keys():
                missing = sorted(set(spec) - set(flat))
                extra = sorted(set(flat) - set(spec))
                raise TransportError(
                    f"adapter tree mismatch vs registered spec "
                    f"(missing={missing}, extra={extra})",
                    reason="spec", **ctx)
            r = payload.rank
            for path, arr in flat.items():
                want, got = spec[path], tuple(arr.shape)
                ax = self._rank_axis(path) if r is not None else None
                if ax is None:
                    if got != want:
                        raise TransportError(
                            f"{path}: shape {got} != registered {want}",
                            reason="shape", **ctx)
                    continue
                # Ragged (hetero) uplink: the factor's rank axis carries the
                # client's declared rank — zero-padding to the registered
                # r_max happens at decode, AFTER validation. Already-padded
                # tensors pass too (masked columns contribute exactly zero).
                r_max = want[len(want) + ax]
                if not 1 <= r <= r_max:
                    raise TransportError(
                        f"{path}: declared rank {r} outside [1, {r_max}] "
                        f"(registered r_max)", reason="rank", **ctx)
                if len(got) != len(want) or any(
                        g != w for i, (g, w) in enumerate(zip(got, want))
                        if i != len(want) + ax):
                    raise TransportError(
                        f"{path}: shape {got} != registered {want}",
                        reason="shape", **ctx)
                if got[ax] not in (r, r_max):
                    raise TransportError(
                        f"{path}: rank axis has {got[ax]} columns, matching "
                        f"neither declared rank {r} nor registered r_max "
                        f"{r_max}", reason="rank", **ctx)
        check_finite, max_norm = v.check_finite, v.max_norm
        total = 0.0
        for path, arr in flat.items():
            # one float64 reduction per leaf, one finite check per payload:
            # any NaN/±Inf propagates into the running sum (cancelling ±Inf
            # makes NaN), and float64 accumulation of finite fp32/fp16
            # leaves cannot overflow — no O(size) bool temp, no per-leaf
            # isfinite dispatch
            if check_finite:
                total += float(arr.sum(dtype=np.float64))
            if max_norm > 0 and arr.size \
                    and float(np.max(np.abs(arr))) > max_norm:
                raise TransportError(
                    f"{path}: ∞-norm {float(np.max(np.abs(arr))):.3g} "
                    f"exceeds limit {max_norm:g}", reason="norm", **ctx)
        if check_finite and not np.isfinite(total):
            # quarantine slow path: re-scan to name the offending leaf
            for path, arr in flat.items():
                if not np.all(np.isfinite(arr)):
                    raise TransportError(
                        f"{path}: non-finite values in payload",
                        reason="nonfinite", **ctx)
            raise TransportError("non-finite values in payload",
                                 reason="nonfinite", **ctx)

    @staticmethod
    def _rank_axis(path: str) -> Optional[int]:
        """Which axis of a factor leaf is the LoRA rank axis: a is (…, m, r)
        → −1, b is (…, r, n) → −2. Non-factor leaves return None (they must
        match the registered spec exactly even on ragged uplinks)."""
        leaf = path.rsplit("/", 1)[-1]
        if leaf == "a":
            return -1
        if leaf == "b":
            return -2
        return None

    def _pad_ragged(self, payload: Payload,
                    flat: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Zero-pad a VALIDATED ragged payload's factor leaves up to the
        registered r_max spec shapes. The engine's per-lane rank mask zeroes
        exactly the padded columns, so padding is the semantic identity
        (tests/test_engine_hetero.py proves masking == padding bitwise)."""
        if payload.rank is None or self.spec is None:
            return flat
        out = {}
        for path, arr in flat.items():
            want = self.spec.get(path)
            if want is not None and tuple(arr.shape) != want:
                arr = np.pad(arr, [(0, w - g) for g, w in
                                   zip(arr.shape, want)])
            out[path] = arr
        return out

    def decode(self, payload: Payload) -> Any:
        flat = self._decode_flat(payload)
        self._validate_flat(payload, flat)
        return unflatten_from_paths(self._pad_ragged(payload, flat))

    def decode_into(self, payload: Payload, buffers: Any, *,
                    weight: Optional[float] = None) -> Any:
        """Decode straight into a streaming sink (core/engine.RoundBuffers).

        The dequantized leaves are scattered into the sink's preallocated
        ``(C_max, …)`` device stacks at the payload's client lane as the
        delivery arrives — the round close reads the stacks, so there is no
        burst of stacking work at the deadline. The payload's ``round_id``
        selects the stack SET in the sink's double-buffer ring, so round
        N+1 uplinks stream into a fresh set while round N's close still owns
        the previous one. The sink aggregates exactly what was transmitted
        (quantization included), like :meth:`decode`. Also returns the host
        tree (one decode, shared) so the coordinator's ``Delivery.lora``
        stays inspectable by diagnostics and tests.

        Defended: validation runs BEFORE the scatter, so a quarantined
        payload never touches a stack lane (raises
        :class:`TransportError`). A payload the ring refuses — unknown or
        already-closed/evicted round_id, duplicate (client, round) lane —
        raises :class:`StaleUplinkError` (an addressing failure: dropped,
        not quarantined).

        ``weight`` is the client's RAW aggregation weight, forwarded to the
        sink — a chunked sink folds it into the running accumulators at
        ingest (the close later normalises by the total), so stream-time and
        close-time weighting must agree (the chunked close cross-checks).
        """
        with self.rec.span("codec.decode", cat="transport",
                           round=payload.round_id, client=payload.client_id,
                           codec=payload.codec, nbytes=payload.nbytes):
            flat = self._decode_flat(payload)
            self._validate_flat(payload, flat)
            flat = self._pad_ragged(payload, flat)
            # forward the declared rank only when set, so uniform payloads
            # keep working against sinks predating the rank= kwarg
            rank_kw = {} if payload.rank is None else {"rank": payload.rank}
            try:
                landed = buffers.write_flat(payload.client_id, flat,
                                            round_id=payload.round_id,
                                            weight=weight, **rank_kw)
            except KeyError as e:
                raise StaleUplinkError(
                    f"unroutable round_id: {e}", round_id=payload.round_id,
                    client_id=payload.client_id, reason="unroutable") from e
            if not landed:
                raise StaleUplinkError(
                    "ring refused the write (stale/evicted round or "
                    "duplicate lane)", round_id=payload.round_id,
                    client_id=payload.client_id, reason="stale")
        now = time.perf_counter_ns()
        with self._ingest_lock:
            if self._ingest_t0 is None:
                self._ingest_t0 = now
            self._ingest_bytes += payload.nbytes
            ingest_bytes, t0 = self._ingest_bytes, self._ingest_t0
        if self.rec.enabled:
            elapsed_s = max((now - t0) / 1e9, 1e-9)
            self.rec.gauge("uplink.ingest_bytes_per_s").set(
                round(ingest_bytes / elapsed_s, 1))
        return unflatten_from_paths(flat)


@dataclass
class LedgerEntry:
    round_id: int
    direction: str
    client_id: int
    params: int
    nbytes: int
    codec: str
    note: str = ""


class BytesLedger:
    """Per-round communication ledger (measured params + bytes).

    Directions are open-ended: besides ``uplink``/``downlink``, faulty
    payloads are accounted under ``quarantined`` (validation rejected the
    content) and ``dropped`` (crashed mid-uplink, or the ring refused a
    replayed/duplicate address; also the downlink that fed a client who
    never delivered). ``reconcile()`` compares only the delivered
    uplink/downlink params against the analytic form — which is exactly why
    the faulty bytes must NOT hide in those buckets.
    """

    def __init__(self):
        self.entries: List[LedgerEntry] = []

    def record(self, payload: Payload, note: str = "",
               direction: Optional[str] = None) -> None:
        """Record one payload; ``direction`` overrides the payload's own
        (e.g. a quarantined uplink is recorded as ``quarantined`` — the
        bytes crossed the wire but never became aggregate input)."""
        self.entries.append(LedgerEntry(
            round_id=payload.round_id,
            direction=direction or payload.direction,
            client_id=payload.client_id, params=payload.num_params,
            nbytes=payload.nbytes, codec=payload.codec, note=note))

    def reclassify(self, round_id: int, client_id: int, direction: str,
                   new_direction: str, note: str = "") -> bool:
        """Re-bucket the latest matching entry (e.g. the downlink that fed a
        client whose uplink was then quarantined → ``dropped``). Returns
        whether a matching entry was found."""
        for e in reversed(self.entries):
            if (e.round_id == round_id and e.client_id == client_id
                    and e.direction == direction):
                e.direction = new_direction
                if note:
                    e.note = (e.note + "; " + note) if e.note else note
                return True
        return False

    def record_analytic(self, round_id: int, direction: str, params: int,
                        bytes_per_param: int = 4, client_id: int = -1,
                        note: str = "") -> None:
        """Account a payload we model analytically (e.g. the factored residual
        broadcast, whose params come from decompose.factored_residual_params)."""
        self.entries.append(LedgerEntry(
            round_id=round_id, direction=direction, client_id=client_id,
            params=int(params), nbytes=int(params) * bytes_per_param,
            codec="none", note=note))

    def record_raw(self, round_id: int, direction: str, nbytes: int,
                   client_id: int = -1, note: str = "") -> None:
        """Account raw non-payload octets (params=0): HTTP request line +
        headers + wire frame envelope. These bytes crossed the socket but
        carry no adapter parameters, so they live under their own direction
        (``http_overhead``) — folding them into ``uplink_bytes`` would
        silently break the bytes-per-param story ``reconcile()`` audits."""
        self.entries.append(LedgerEntry(
            round_id=round_id, direction=direction, client_id=client_id,
            params=0, nbytes=int(nbytes), codec="raw", note=note))

    # -- views -------------------------------------------------------------
    def round_totals(self, round_id: int) -> Dict[str, int]:
        """Per-direction ``{direction}_params``/``{direction}_bytes`` sums.
        The four uplink/downlink keys are always present (zero-filled);
        fault directions (``dropped``/``quarantined``) appear only when a
        round actually recorded them."""
        tot = {"uplink_params": 0, "uplink_bytes": 0,
               "downlink_params": 0, "downlink_bytes": 0}
        for e in self.entries:
            if e.round_id != round_id:
                continue
            kp, kb = f"{e.direction}_params", f"{e.direction}_bytes"
            tot[kp] = tot.get(kp, 0) + e.params
            tot[kb] = tot.get(kb, 0) + e.nbytes
        return tot

    def totals(self) -> Dict[str, int]:
        rounds = {e.round_id for e in self.entries}
        out = {"uplink_params": 0, "uplink_bytes": 0,
               "downlink_params": 0, "downlink_bytes": 0}
        for r in rounds:
            for key, v in self.round_totals(r).items():
                out[key] = out.get(key, 0) + v
        return out

    def reconcile(self, round_id: int, analytic: Dict[str, int]
                  ) -> Dict[str, Any]:
        """Compare measured param counts against core/comm.py's closed form.

        analytic: the dict returned by ``round_comm_params`` (uplink/downlink
        PARAM counts for the round). Bytes are codec-dependent so only params
        are reconciled. Returns per-direction measured/analytic/match.
        """
        got = self.round_totals(round_id)
        out: Dict[str, Any] = {}
        for direction in ("uplink", "downlink"):
            measured = got[f"{direction}_params"]
            expected = int(analytic.get(direction, 0))
            out[direction] = {"measured": measured, "analytic": expected,
                              "match": measured == expected}
        out["ok"] = all(out[d]["match"] for d in ("uplink", "downlink"))
        return out

    # -- checkpoint/resume (crash-safe round state) ------------------------
    def state_dict(self) -> List[Dict[str, Any]]:
        import dataclasses as _dc
        return [_dc.asdict(e) for e in self.entries]

    def load_state(self, state: List[Dict[str, Any]]) -> None:
        self.entries = [LedgerEntry(**d) for d in state]

    def summary_lines(self) -> List[str]:
        rounds = sorted({e.round_id for e in self.entries})
        lines = [f"{'round':>5} {'up_params':>10} {'up_bytes':>10} "
                 f"{'down_params':>11} {'down_bytes':>10}"]
        for r in rounds:
            t = self.round_totals(r)
            lines.append(f"{r:>5} {t['uplink_params']:>10} {t['uplink_bytes']:>10} "
                         f"{t['downlink_params']:>11} {t['downlink_bytes']:>10}")
        t = self.totals()
        lines.append(f"{'all':>5} {t['uplink_params']:>10} {t['uplink_bytes']:>10} "
                     f"{t['downlink_params']:>11} {t['downlink_bytes']:>10}")
        return lines
