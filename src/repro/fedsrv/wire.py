"""Wire framing for HTTP transport: ``Payload`` ↔ bytes.

The in-process coordinator hands :class:`~repro.fedsrv.transport.Payload`
objects around directly; the HTTP federation service (fedsrv/server.py) needs
them as octets. The frame is deliberately dumb — no pickle, no compression:

    ``b"FDX1"`` · u32 header length (big-endian) · JSON header · raw buffers

The JSON header carries the payload identity (round/client/direction/codec)
plus one descriptor per tensor ``{path, dtype, shape, declared, scale,
nbytes}`` in buffer order; the raw tensor bytes follow back-to-back in that
same order. ``declared`` round-trips :class:`EncodedTensor.shape` so the
PR-7 decode boundary (``_decode_flat``'s wire-length-vs-declared-shape
check) keeps working across the socket — a truncated buffer still DECLARES
its full logical shape and is quarantined, never mis-reshaped.

:func:`payload_from_wire` is the defended twin of :func:`payload_to_wire`:
every malformation — bad magic, truncated header or body, unknown dtype,
buffer length disagreeing with the descriptor — raises a typed
:class:`TransportError` with ``reason="wire"`` so the server maps it to
HTTP 400 and counts it, instead of crashing a handler thread.
"""

from __future__ import annotations

import json
import struct
from typing import Any, Dict

import numpy as np

from repro.fedsrv.transport import EncodedTensor, Payload, TransportError

MAGIC = b"FDX1"
_HDR = struct.Struct(">I")          # u32 big-endian JSON header length
# wire dtype allowlist — matches the codec tiers (none/fp16/int8)
_DTYPES = {"float32": np.float32, "float16": np.float16, "int8": np.int8}

#: fixed framing overhead per payload, before the JSON header
FRAME_OVERHEAD = len(MAGIC) + _HDR.size


def _wire_error(msg: str, round_id=None, client_id=None) -> TransportError:
    return TransportError(msg, round_id=round_id, client_id=client_id,
                          reason="wire")


def payload_to_wire(payload: Payload) -> bytes:
    """Serialize a payload to one self-describing frame."""
    descs = []
    chunks = []
    for path, enc in payload.tensors.items():
        arr = np.ascontiguousarray(enc.data)
        descs.append({
            "path": path,
            "dtype": arr.dtype.name,
            "shape": list(arr.shape),
            "declared": None if enc.shape is None else list(enc.shape),
            "scale": enc.scale,
            "nbytes": int(arr.nbytes),
        })
        chunks.append(arr.tobytes())
    hdr: Dict[str, Any] = {
        "round_id": payload.round_id,
        "client_id": payload.client_id,
        "direction": payload.direction,
        "codec": payload.codec,
        "tensors": descs,
    }
    if payload.rank is not None:
        # ragged (hetero) uplink: declared LoRA rank travels in the header;
        # uniform payloads omit the key so pre-hetero frames stay bytewise
        # identical
        hdr["rank"] = int(payload.rank)
    header = json.dumps(hdr, separators=(",", ":")).encode("utf-8")
    return b"".join([MAGIC, _HDR.pack(len(header)), header] + chunks)


def payload_from_wire(data: bytes) -> Payload:
    """Parse one frame back into a :class:`Payload` (defended — see module
    docstring). The returned tensors view the input buffer (no copy); the
    codec's decode ``astype`` materialises fp32 later."""
    if len(data) < FRAME_OVERHEAD or data[:len(MAGIC)] != MAGIC:
        raise _wire_error("bad magic / truncated frame "
                          f"({len(data)} B)")
    (hlen,) = _HDR.unpack_from(data, len(MAGIC))
    body_at = FRAME_OVERHEAD + hlen
    if len(data) < body_at:
        raise _wire_error(f"truncated header: declares {hlen} B, "
                          f"frame has {len(data) - FRAME_OVERHEAD}")
    try:
        header: Dict[str, Any] = json.loads(
            data[FRAME_OVERHEAD:body_at].decode("utf-8"))
        round_id = int(header["round_id"])
        client_id = int(header["client_id"])
        direction = str(header["direction"])
        codec = str(header["codec"])
        rank = header.get("rank")   # absent on pre-hetero frames → None
        rank = None if rank is None else int(rank)
        descs = header["tensors"]
        assert isinstance(descs, list)
    except (ValueError, KeyError, TypeError, AssertionError,
            UnicodeDecodeError) as e:
        raise _wire_error(f"malformed JSON header: {e}") from e

    tensors: Dict[str, EncodedTensor] = {}
    off = body_at
    for d in descs:
        try:
            path = str(d["path"])
            dtype = _DTYPES[d["dtype"]]
            shape = tuple(int(s) for s in d["shape"])
            declared = d.get("declared")
            declared = None if declared is None \
                else tuple(int(s) for s in declared)
            scale = d.get("scale")
            scale = None if scale is None else float(scale)
            nbytes = int(d["nbytes"])
        except (ValueError, KeyError, TypeError) as e:
            raise _wire_error(f"malformed tensor descriptor: {e}",
                              round_id, client_id) from e
        want = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        if nbytes != want:
            raise _wire_error(
                f"{path}: descriptor nbytes={nbytes} disagrees with "
                f"dtype/shape ({want} B)", round_id, client_id)
        if off + nbytes > len(data):
            raise _wire_error(
                f"{path}: truncated body (need {nbytes} B at offset {off}, "
                f"frame is {len(data)} B)", round_id, client_id)
        arr = np.frombuffer(data, dtype=dtype, count=int(
            np.prod(shape, dtype=np.int64)), offset=off).reshape(shape)
        off += nbytes
        tensors[path] = EncodedTensor(arr, scale, declared)
    if off != len(data):
        raise _wire_error(f"trailing garbage: {len(data) - off} B past the "
                          "last tensor", round_id, client_id)
    return Payload(round_id=round_id, client_id=client_id,
                   direction=direction, codec=codec, tensors=tensors,
                   rank=rank)
