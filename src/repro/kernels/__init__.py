"""Pallas TPU kernels for the perf-critical compute of FedEx-LoRA training:

* lora_matmul     — fused base+adapter projection (every LoRA'd matmul)
* fedex_residual  — the paper's aggregation residual, fused into the W0 update
* flash_swa       — sliding-window flash attention (mixtral/gemma3 long ctx)

Each ships a pure-jnp oracle in ref.py and a jit wrapper in ops.py.
Validated with interpret=True on CPU; the BlockSpec tiling targets TPU v5e
VMEM/MXU geometry (128-aligned tiles).
"""

from repro.kernels.ops import fedex_fold, lora_dense, swa_attention

__all__ = ["fedex_fold", "lora_dense", "swa_attention"]
