"""Pallas TPU kernels for the perf-critical compute of FedEx-LoRA training:

* lora_matmul     — fused base+adapter projection (every LoRA'd matmul)
* fedex_residual  — the paper's aggregation residual, fused into the W0 update
                    (uniform OR weighted/masked via a scalar-prefetch vector),
                    plus three masked siblings sharing its tiling:
                    product_fold (signed Σ s_c·a_c b_c — reinit close and the
                    factored rank-r' svd-residual fold), perclient_fold
                    (keep_local per-client residuals, all lanes in one pass)
                    and hetero_fold (rank-masked ragged lanes + shared
                    truncated own factors — the hetero close)
* factor_mean     — weighted client-mean of stacked adapter factors (ā, b̄)
* flash_swa       — sliding-window flash attention (mixtral/gemma3 long ctx)

Each ships a pure-jnp oracle in ref.py and a jit wrapper in ops.py.
Validated with interpret=True on CPU; the BlockSpec tiling targets TPU v5e
VMEM/MXU geometry (128-aligned tiles). Tile-indivisible shapes are zero-padded
inside the kernels and sliced back (exact for every product involved).

Which path runs where: ``core/aggregation.py`` is the eager jnp ground truth;
``core/engine.py`` composes fedex_residual + factor_mean into the single
jitted round-close program (jnp twin on CPU, Pallas on TPU). The uniform path
of each kernel mirrors the aggregation operators op-for-op, so it is bitwise
identical to the *jitted* ground truth (the eager path differs by ≤2 ulp
where XLA contracts mul+add to FMA inside fused programs).
"""

from repro.kernels.ops import (factor_mean, fedex_fold, hetero_fold,
                               lora_dense, perclient_fold, product_accum,
                               product_fold, swa_attention)

__all__ = ["factor_mean", "fedex_fold", "hetero_fold", "lora_dense",
           "perclient_fold", "product_accum", "product_fold",
           "swa_attention"]
