"""Weighted LoRA factor mean Pallas kernel:  x̄ = Σ_c w_c · x_c  over a
stacked client axis.

The round-close engine (core/engine.py) aggregates the global adapter factors
ā = Σ w_c a_c and b̄ = Σ w_c b_c from ``(C_max, …)``-stacked client buffers.
This kernel performs that reduction tile-by-tile with the per-client weight
vector delivered through scalar prefetch (SMEM), so the weights are resident
before the tile loop starts and zero-weight lanes act as a participation
mask — ragged rounds reuse the one compiled program, only the vector changes.

``weights=None`` takes the uniform path: the client sum is unrolled in slot
order and divided by C at the end, mirroring ``core/aggregation.py``'s
``tree_mean`` (``sum(...)/k``) op-for-op, which keeps the uniform path bitwise
identical to the jitted jnp ground truth.

Factors are small relative to W0 (m·r + r·n ≪ m·n) so this is VPU-bound; the
value of fusing it into the round-close program is dispatch count and HBM
re-reads, not FLOPs. Tile-indivisible shapes are zero-padded and sliced back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_axis as _pad_axis


def _kernel(x_ref, o_ref, *, num_clients: int):
    x = x_ref[...].astype(jnp.float32)  # (C, bm, bn)
    acc = x[0]
    for c in range(1, num_clients):  # static unroll: C is small (cross-silo)
        acc = acc + x[c]
    o_ref[...] = acc / num_clients


def _kernel_weighted(w_ref, x_ref, o_ref, *, num_clients: int):
    x = x_ref[...].astype(jnp.float32)  # (C, bm, bn)
    acc = jnp.zeros_like(x[0])
    for c in range(num_clients):
        acc += w_ref[c] * x[c]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lora_factor_mean(stack: jnp.ndarray, weights: jnp.ndarray | None = None, *,
                     bm: int = 256, bn: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """stack: (C, m, n) → (m, n) f32 weighted mean over the client axis.

    ``weights`` — optional (C,) f32 normalized weight vector (zeros mask
    non-delivered lanes); ``None`` → uniform 1/C mean (slot-order sum, /C).
    """
    c, m, n = stack.shape
    bm, bn = min(bm, m), min(bn, n)
    xp = _pad_axis(_pad_axis(stack, bm, 1), bn, 2)
    mp, np_ = xp.shape[1:]
    grid = (mp // bm, np_ // bn)

    if weights is None:
        return pl.pallas_call(
            functools.partial(_kernel, num_clients=c),
            grid=grid,
            in_specs=[pl.BlockSpec((c, bm, bn), lambda i, j: (0, i, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(xp)[:m, :n]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((c, bm, bn), lambda i, j, *_: (0, i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_weighted, num_clients=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), xp)[:m, :n]
