"""FedEx-LoRA residual fold-in Pallas kernel (the paper's Eq. 12+14, fused).

Computes  W0 + scale·( mean_c(a_c @ b_c) − ā @ b̄ )  tile-by-tile: for each
MXU-aligned (bm, bn) output tile, the stacked client factors stream through
VMEM once and the dense m×n residual is NEVER materialised in HBM (the naive
host path builds the full ΔW_res then adds — an extra 2·m·n f32 HBM round
trip per adapted matrix per round; at deepseek-v2 scale that is ~5 GB of
avoidable traffic per aggregation).

The client mean over C is unrolled inside the kernel (C = cross-silo client
count, 3–16 — small); ā/b̄ tiles are recomputed per tile from the same VMEM
slabs, trading negligible FLOPs for zero extra memory traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w0_ref, a_ref, b_ref, o_ref, *, scale: float, num_clients: int):
    a = a_ref[...].astype(jnp.float32)  # (C, bm, r)
    b = b_ref[...].astype(jnp.float32)  # (C, r, bn)
    inv_c = 1.0 / num_clients
    mean_prod = jnp.zeros((a.shape[1], b.shape[2]), jnp.float32)
    for c in range(num_clients):  # static unroll: C is small (cross-silo)
        mean_prod += jnp.dot(a[c], b[c], preferred_element_type=jnp.float32)
    mean_prod *= inv_c
    abar = a.sum(0) * inv_c
    bbar = b.sum(0) * inv_c
    residual = mean_prod - jnp.dot(abar, bbar, preferred_element_type=jnp.float32)
    o_ref[...] = w0_ref[...].astype(jnp.float32) + scale * residual


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def fedex_residual_apply(w0: jnp.ndarray, a_stack: jnp.ndarray,
                         b_stack: jnp.ndarray, *, scale: float = 1.0,
                         bm: int = 256, bn: int = 256,
                         interpret: bool = False) -> jnp.ndarray:
    """w0: (m, n), a_stack: (C, m, r), b_stack: (C, r, n) → (m, n) f32."""
    m, n = w0.shape
    c, _, r = a_stack.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, f"({m},{n}) not divisible by ({bm},{bn})"

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, num_clients=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((c, bm, r), lambda i, j: (0, i, 0)),
            pl.BlockSpec((c, r, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(w0, a_stack, b_stack)
