"""FedEx-LoRA residual fold-in Pallas kernels (the paper's Eq. 12+14, fused).

The flagship kernel computes  W0 + scale·( Σ_c w_c·(a_c @ b_c) − ā @ b̄ )
tile-by-tile, where ā = Σ_c w_c·a_c (and likewise b̄): for each MXU-aligned
(bm, bn) output tile, the stacked client factors stream through VMEM once and
the dense m×n residual is NEVER materialised in HBM (the naive host path
builds the full ΔW_res then adds — an extra 2·m·n f32 HBM round trip per
adapted matrix per round; at deepseek-v2 scale that is ~5 GB of avoidable
traffic per aggregation).

Two weighting modes:

* ``weights=None`` — the historical uniform mean. The kernel unrolls the
  client sum in slot order and divides by C at the end, mirroring
  ``core/aggregation.py``'s ``sum(...)/k`` op-for-op so the uniform path stays
  bitwise identical to the jnp ground truth.
* ``weights=(C,) f32`` — per-client weight vector delivered through scalar
  prefetch (SMEM, available before the tile loop starts). Zero-weight lanes
  act as a **participation mask**: stacks padded to a fixed ``C_max`` compile
  ONCE and serve every round — ragged quorums, partial participation and
  example-count weighting all reuse the same program, they only change the
  vector.

Three masked variants share the tiling and the scalar-prefetch weight vector,
covering the remaining round-close methods of the engine (core/engine.py).
Their padded public wrappers are ``kernels/ops.py::product_fold`` and
``perclient_fold`` (as ``fedex_fold`` wraps :func:`fedex_residual_apply`) —
the engine and every caller go through those:

* :func:`product_fold_apply` (→ ``ops.product_fold``) — W0 +
  scale·Σ_c s_c·(a_c @ b_c) with a SIGNED per-lane vector and no
  mean-product subtraction. s = w closes a ``reinit`` round (the full ideal
  update folds into W0, paper Table 5); a single lane with s = [1] folds a
  factored rank-r' truncated residual (the fedex_svd close) without the
  dense ΔW ever reaching HBM.
* :func:`perclient_fold_apply` (→ ``ops.perclient_fold``) — the
  ``keep_local`` close: every lane's own update
  W0_c + scale·(Σ_j w_j a_j b_j − a_c b_c)  in ONE pass. The ideal tile
  Σ_j w_j a_j b_j is accumulated once per output tile and the per-lane
  own-product is recomputed from the resident VMEM slabs (r is small, so the
  extra FLOPs are negligible vs re-streaming C dense residuals from HBM).
* :func:`hetero_fold_apply` (→ ``ops.hetero_fold``) — the ``hetero`` close:
  perclient_fold with ragged ranks. A SECOND scalar-prefetch vector carries
  each lane's true rank; padded rank columns are masked to exact zero inside
  the tile loop, and every lane's own-product comes from the SHARED
  rank-r_max truncation factors masked down to its own rank.

Tile-indivisible shapes (whisper/qwen head dims, odd vocab slices) are padded
to the next (bm, bn) multiple with zeros and sliced back — zero rows/columns
of a/b contribute nothing to any product, so padding is exact.

The client sum over C is unrolled inside the kernels (C = cross-silo client
count, 3–32 — small); ā/b̄ tiles are recomputed per tile from the same VMEM
slabs, trading negligible FLOPs for zero extra memory traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_axis as _pad_axis


def _kernel(w0_ref, a_ref, b_ref, o_ref, *, scale: float, num_clients: int):
    """Uniform path: mean in slot order then /C (bitwise twin of sum(...)/k)."""
    a = a_ref[...].astype(jnp.float32)  # (C, bm, r)
    b = b_ref[...].astype(jnp.float32)  # (C, r, bn)
    mean_prod = jnp.dot(a[0], b[0], preferred_element_type=jnp.float32)
    abar, bbar = a[0], b[0]
    for c in range(1, num_clients):  # static unroll: C is small (cross-silo)
        mean_prod += jnp.dot(a[c], b[c], preferred_element_type=jnp.float32)
        abar = abar + a[c]
        bbar = bbar + b[c]
    mean_prod = mean_prod / num_clients
    abar = abar / num_clients
    bbar = bbar / num_clients
    residual = mean_prod - jnp.dot(abar, bbar, preferred_element_type=jnp.float32)
    o_ref[...] = w0_ref[...].astype(jnp.float32) + scale * residual


def _kernel_weighted(w_ref, w0_ref, a_ref, b_ref, o_ref, *, scale: float,
                     num_clients: int):
    """Weighted/masked path: w_ref is the (C,) scalar-prefetch weight vector.

    Zero-weight lanes (masked / non-delivered slots) contribute exactly 0 to
    every sum, so a C_max-padded stack closes any ragged round.
    """
    a = a_ref[...].astype(jnp.float32)  # (C, bm, r)
    b = b_ref[...].astype(jnp.float32)  # (C, r, bn)
    mean_prod = jnp.zeros((a.shape[1], b.shape[2]), jnp.float32)
    abar = jnp.zeros_like(a[0])
    bbar = jnp.zeros_like(b[0])
    for c in range(num_clients):  # static unroll: C is small
        wc = w_ref[c]
        mean_prod += wc * jnp.dot(a[c], b[c], preferred_element_type=jnp.float32)
        abar += wc * a[c]
        bbar += wc * b[c]
    residual = mean_prod - jnp.dot(abar, bbar, preferred_element_type=jnp.float32)
    o_ref[...] = w0_ref[...].astype(jnp.float32) + scale * residual


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def fedex_residual_apply(w0: jnp.ndarray, a_stack: jnp.ndarray,
                         b_stack: jnp.ndarray,
                         weights: jnp.ndarray | None = None, *,
                         scale: float = 1.0,
                         bm: int = 256, bn: int = 256,
                         interpret: bool = False) -> jnp.ndarray:
    """w0: (m, n), a_stack: (C, m, r), b_stack: (C, r, n) → (m, n) f32.

    ``weights`` — optional (C,) f32 normalized weight vector (zeros mask
    non-delivered lanes). ``None`` → uniform 1/C mean, bitwise identical to
    the unweighted jnp operators.
    """
    m, n = w0.shape
    c, _, r = a_stack.shape
    bm, bn = min(bm, m), min(bn, n)
    # pad to the next (bm, bn) multiple — zero rows/cols are exact no-ops for
    # every product in the residual; slice the tile-aligned result back.
    w0p = _pad_axis(_pad_axis(w0, bm, 0), bn, 1)
    ap = _pad_axis(a_stack, bm, 1)
    bp = _pad_axis(b_stack, bn, 2)
    mp, np_ = w0p.shape

    grid = (mp // bm, np_ // bn)
    if weights is None:
        return pl.pallas_call(
            functools.partial(_kernel, scale=scale, num_clients=c),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                pl.BlockSpec((c, bm, r), lambda i, j: (0, i, 0)),
                pl.BlockSpec((c, r, bn), lambda i, j: (0, 0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(w0p, ap, bp)[:m, :n]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
            pl.BlockSpec((c, bm, r), lambda i, j, *_: (0, i, 0)),
            pl.BlockSpec((c, r, bn), lambda i, j, *_: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_weighted, scale=scale, num_clients=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), w0p, ap, bp)[:m, :n]


# --------------------------------------------------------------------------
# signed product fold: W0 + scale·Σ_c s_c·(a_c @ b_c)  (no mean subtraction)
# --------------------------------------------------------------------------

def _kernel_product(s_ref, w0_ref, a_ref, b_ref, o_ref, *, scale: float,
                    num_clients: int):
    """s_ref is a SIGNED (C,) scalar-prefetch vector: s = w folds the ideal
    update (reinit close); s = w − e_i folds client i's keep_local residual;
    one lane with s = [1] folds a factored low-rank residual (svd close).
    Zero lanes vanish — the same participation-mask contract as the weighted
    residual kernel."""
    a = a_ref[...].astype(jnp.float32)  # (C, bm, r)
    b = b_ref[...].astype(jnp.float32)  # (C, r, bn)
    acc = jnp.zeros((a.shape[1], b.shape[2]), jnp.float32)
    for c in range(num_clients):  # static unroll: C is small
        acc += s_ref[c] * jnp.dot(a[c], b[c], preferred_element_type=jnp.float32)
    o_ref[...] = w0_ref[...].astype(jnp.float32) + scale * acc


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def product_fold_apply(w0: jnp.ndarray, a_stack: jnp.ndarray,
                       b_stack: jnp.ndarray, signs: jnp.ndarray, *,
                       scale: float = 1.0, bm: int = 256, bn: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """w0: (m, n), a_stack: (C, m, r), b_stack: (C, r, n), signs: (C,) f32
    (may be negative) → (m, n) f32 = W0 + scale·Σ_c s_c·a_c b_c."""
    m, n = w0.shape
    c, _, r = a_stack.shape
    bm, bn = min(bm, m), min(bn, n)
    w0p = _pad_axis(_pad_axis(w0, bm, 0), bn, 1)
    ap = _pad_axis(a_stack, bm, 1)
    bp = _pad_axis(b_stack, bn, 2)
    mp, np_ = w0p.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
            pl.BlockSpec((c, bm, r), lambda i, j, *_: (0, i, 0)),
            pl.BlockSpec((c, r, bn), lambda i, j, *_: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_product, scale=scale, num_clients=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(signs.astype(jnp.float32), w0p, ap, bp)[:m, :n]


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def product_accum_apply(acc: jnp.ndarray, a_stack: jnp.ndarray,
                        b_stack: jnp.ndarray, signs: jnp.ndarray, *,
                        scale: float = 1.0, bm: int = 256, bn: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """acc: (m, n) f32, a_stack: (C, m, r), b_stack: (C, r, n), signs: (C,)
    f32 → (m, n) f32 = acc + scale·Σ_c s_c·a_c b_c.

    The read-modify-write twin of :func:`product_fold_apply` for chunked
    streaming closes (core/engine.py chunked ring mode): the running
    accumulator plays W0's role in the same ``_kernel_product`` body, and
    ``input_output_aliases`` hands the accumulator buffer to the output so
    folding chunk k updates it IN PLACE — no second dense m×n allocation per
    partial fold, which is the whole point of chunking.
    """
    m, n = acc.shape
    c, _, r = a_stack.shape
    bm, bn = min(bm, m), min(bn, n)
    accp = _pad_axis(_pad_axis(acc, bm, 0), bn, 1)
    ap = _pad_axis(a_stack, bm, 1)
    bp = _pad_axis(b_stack, bn, 2)
    mp, np_ = accp.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
            pl.BlockSpec((c, bm, r), lambda i, j, *_: (0, i, 0)),
            pl.BlockSpec((c, r, bn), lambda i, j, *_: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_product, scale=scale, num_clients=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        # operand 1 = the padded accumulator (0 is the scalar-prefetch sign
        # vector): alias it to the output for the in-place update
        input_output_aliases={1: 0},
        interpret=interpret,
    )(signs.astype(jnp.float32), accp, ap, bp)[:m, :n]


# --------------------------------------------------------------------------
# per-client fold: the keep_local close, all lanes in one pass
# --------------------------------------------------------------------------

def _kernel_perclient(w_ref, w0_ref, a_ref, b_ref, o_ref, *, scale: float,
                      num_clients: int):
    """o[c] = w0[c] + scale·(Σ_j w_j a_j b_j − a_c b_c): the ideal tile is
    accumulated ONCE, then each lane's own product is recomputed from the
    same VMEM slabs — per-lane sign vectors (w − e_c) without C passes."""
    a = a_ref[...].astype(jnp.float32)  # (C, bm, r)
    b = b_ref[...].astype(jnp.float32)  # (C, r, bn)
    ideal = jnp.zeros((a.shape[1], b.shape[2]), jnp.float32)
    for c in range(num_clients):  # static unroll: C is small
        ideal += w_ref[c] * jnp.dot(a[c], b[c], preferred_element_type=jnp.float32)
    for c in range(num_clients):
        own = jnp.dot(a[c], b[c], preferred_element_type=jnp.float32)
        o_ref[c, :, :] = w0_ref[c].astype(jnp.float32) + scale * (ideal - own)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def perclient_fold_apply(w0_stack: jnp.ndarray, a_stack: jnp.ndarray,
                         b_stack: jnp.ndarray, weights: jnp.ndarray, *,
                         scale: float = 1.0, bm: int = 256, bn: int = 256,
                         interpret: bool = False) -> jnp.ndarray:
    """w0_stack: (C, m, n), a_stack: (C, m, r), b_stack: (C, r, n),
    weights: (C,) f32 → (C, m, n) f32 with lane c = W0_c + scale·(ideal −
    a_c b_c). Masked (zero-weight) lanes still produce a lane (W0_c +
    scale·ideal when their factors are zero) — callers discard non-delivered
    lanes, exactly as the engine's C_max padding contract prescribes."""
    c, m, n = w0_stack.shape
    r = a_stack.shape[-1]
    bm, bn = min(bm, m), min(bn, n)
    w0p = _pad_axis(_pad_axis(w0_stack, bm, 1), bn, 2)
    ap = _pad_axis(a_stack, bm, 1)
    bp = _pad_axis(b_stack, bn, 2)
    mp, np_ = w0p.shape[1:]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((c, bm, bn), lambda i, j, *_: (0, i, j)),
            pl.BlockSpec((c, bm, r), lambda i, j, *_: (0, i, 0)),
            pl.BlockSpec((c, r, bn), lambda i, j, *_: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((c, bm, bn), lambda i, j, *_: (0, i, j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_perclient, scale=scale, num_clients=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, mp, np_), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), w0p, ap, bp)[:, :m, :n]


# --------------------------------------------------------------------------
# hetero fold: rank-masked per-client fold, shared truncated own factors
# --------------------------------------------------------------------------

def _kernel_hetero(w_ref, rk_ref, w0_ref, a_ref, b_ref, oa_ref, ob_ref,
                   o_ref, *, scale: float, num_clients: int):
    """o[c] = w0[c] + scale·(Σ_j w_j·(a_j∘mask_j) b_j − (A'∘mask_c) B').

    TWO scalar-prefetch vectors ride in SMEM: the (C,) f32 weight vector and
    the (C,) int32 TRUE-rank vector (−1 = full rank). Rank columns of a_j
    past rank_j are zeroed before every product — one-sided masking
    suffices, since zeroing a's column k already kills the k-th rank-1 term
    of a@b — and each lane's own-product uses the SHARED rank-r_max
    truncated factors (A', B') masked down to its own rank: the
    leading-slice Eckart–Young truncation without per-lane shapes, so ONE
    compiled program serves every rank mix in the fleet.
    """
    a = a_ref[...].astype(jnp.float32)    # (C, bm, r)
    b = b_ref[...].astype(jnp.float32)    # (C, r, bn)
    oa = oa_ref[...].astype(jnp.float32)  # (bm, r)
    ob = ob_ref[...].astype(jnp.float32)  # (r, bn)
    r = a.shape[-1]
    # 2-D iota: TPU vector units have no 1-D iota (mosaic lowering rule)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
    ideal = jnp.zeros((a.shape[1], b.shape[2]), jnp.float32)
    for c in range(num_clients):  # static unroll: C is small
        rk = jnp.where(rk_ref[c] < 0, r, rk_ref[c])
        mask = (iota < rk).astype(jnp.float32)  # (1, r): exact 0/1
        ideal += w_ref[c] * jnp.dot(a[c] * mask, b[c],
                                    preferred_element_type=jnp.float32)
    for c in range(num_clients):
        rk = jnp.where(rk_ref[c] < 0, r, rk_ref[c])
        mask = (iota < rk).astype(jnp.float32)
        own = jnp.dot(oa * mask, ob, preferred_element_type=jnp.float32)
        o_ref[c, :, :] = w0_ref[c].astype(jnp.float32) + scale * (ideal - own)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def hetero_fold_apply(w0_stack: jnp.ndarray, a_stack: jnp.ndarray,
                      b_stack: jnp.ndarray, weights: jnp.ndarray,
                      ranks: jnp.ndarray, own_a: jnp.ndarray,
                      own_b: jnp.ndarray, *, scale: float = 1.0,
                      bm: int = 256, bn: int = 256,
                      interpret: bool = False) -> jnp.ndarray:
    """w0_stack: (C, m, n), a_stack: (C, m, r), b_stack: (C, r, n),
    weights: (C,) f32, ranks: (C,) int32 (−1 = full rank), own_a: (m, r),
    own_b: (r, n) → (C, m, n) f32 with lane c = W0_c + scale·(ideal −
    (A'∘mask_c) B'). Zero-weight AND zero-rank lanes both vanish from the
    ideal; callers discard non-delivered lanes (the C_max contract)."""
    c, m, n = w0_stack.shape
    r = a_stack.shape[-1]
    bm, bn = min(bm, m), min(bn, n)
    w0p = _pad_axis(_pad_axis(w0_stack, bm, 1), bn, 2)
    ap = _pad_axis(a_stack, bm, 1)
    bp = _pad_axis(b_stack, bn, 2)
    oap = _pad_axis(own_a, bm, 0)
    obp = _pad_axis(own_b, bn, 1)
    mp, np_ = w0p.shape[1:]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((c, bm, bn), lambda i, j, *_: (0, i, j)),
            pl.BlockSpec((c, bm, r), lambda i, j, *_: (0, i, 0)),
            pl.BlockSpec((c, r, bn), lambda i, j, *_: (0, 0, j)),
            pl.BlockSpec((bm, r), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((r, bn), lambda i, j, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((c, bm, bn), lambda i, j, *_: (0, i, j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_hetero, scale=scale, num_clients=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, mp, np_), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), ranks.astype(jnp.int32), w0p, ap, bp,
      oap, obp)[:, :m, :n]
