"""Flash attention with optional sliding window — Pallas TPU kernel.

The long-context path for mixtral (SWA-4096) and gemma3 (1024-window local
layers). Grid (batch·heads, Q blocks, KV blocks); online softmax carried in
VMEM scratch (m, l, acc); KV blocks entirely outside the (causal ∩ window)
band are skipped via ``pl.when`` so a 4k window over a 512k context touches
only O(window) KV per query block, not O(S).

The jnp twin is models/attention.blockwise_attention (used by the lowering
paths); kernels/ref.flash_swa_ref is the materialised oracle for tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int, nkv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    # is any (q, k) pair in this block-pair visible?
    #   causal: k_start <= q_end;  window: k_end > q_start - window
    q_end = q_start + bq - 1
    k_end = k_start + bk - 1
    relevant = True
    if causal:
        relevant = k_start <= q_end
    if window:
        relevant = relevant & (k_end > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_swa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0, bq: int = 256,
              bk: int = 256, interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (BH, S, D) → (BH, S, D) attention output (q dtype)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq, bk = min(bq, sq), min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nkv = sq // bq, sk // bk
    scale = d ** -0.5

    grid = (bh, nq, nkv)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nkv=nkv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
