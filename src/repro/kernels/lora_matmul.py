"""Fused LoRA matmul Pallas kernel:  y = x @ W + scale · (x @ a) @ b.

TPU adaptation of the paper's "LoRA efficiency" argument (DESIGN §2): the
naive formulation launches three GEMMs with an HBM round-trip for the rank-r
intermediate ``x @ a``. Here the intermediate lives in a VMEM scratch pinned
across the K-stream — the adapter path adds ~zero HBM traffic on top of the
base GEMM (r ≤ 64 ≪ the 128-lane tile).

Tiling: grid (M/bm, N/bn, K/bk); x and W stream through VMEM in MXU-aligned
(128-multiple) tiles; f32 accumulation in the output tile; the rank-r ``x@a``
partial accumulates in scratch and is folded in with ``b`` on the last K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_axis as _pad_axis


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, xa_ref, *, scale: float, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    o_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] += scale * jnp.dot(
            xa_ref[...].astype(b_ref.dtype), b_ref[...],
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk", "interpret"))
def lora_matmul(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                *, scale: float = 1.0, bm: int = 128, bn: int = 128,
                bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x: (M, K), w: (K, N), a: (K, r), b: (r, N) → (M, N) f32.

    Tile-indivisible (M, N, K) are zero-padded to the next (bm, bn, bk)
    multiple and the result sliced back — zero K-rows/columns add nothing to
    either the base or the adapter product, so odd model dims (whisper/qwen
    head dims) run the fused path instead of crashing.
    """
    m, kdim = x.shape
    _, n = w.shape
    r = a.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    x = _pad_axis(_pad_axis(x, bm, 0), bk, 1)
    w = _pad_axis(_pad_axis(w, bk, 0), bn, 1)
    a = _pad_axis(a, bk, 0)
    b = _pad_axis(b, bn, 1)
    mp, kp = x.shape
    np_ = w.shape[1]
    nk = kp // bk

    grid = (mp // bm, np_ // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)[:m, :n]
