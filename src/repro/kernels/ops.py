"""Jit'd public wrappers around the Pallas kernels.

On this CPU container, kernels execute with ``interpret=True`` (Pallas
reference interpreter); on TPU the same calls compile to Mosaic. The wrappers
pick tile sizes and handle batching/GQA reshapes; the kernels themselves
zero-pad tile-indivisible shapes and slice back (kernels/padding.py), so
every shape takes the fused path — ref.py remains the allclose oracle for
tests, with flash_swa's wrapper the one remaining ref fallback (window
geometry, not tiling).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.factor_mean import lora_factor_mean
from repro.kernels.fedex_residual import (fedex_residual_apply,
                                          hetero_fold_apply,
                                          perclient_fold_apply,
                                          product_accum_apply,
                                          product_fold_apply)
from repro.kernels.flash_swa import flash_swa
from repro.kernels.lora_matmul import lora_matmul

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
DEFAULT_INTERPRET = not _ON_TPU


def lora_dense(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
               scale: float, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused LoRA projection for arbitrary leading dims of x. Returns x-dtype."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    # the kernel zero-pads tile-indivisible dims internally; keep whole-array
    # blocks for small odd shapes to avoid pointless padding work
    bm = 128 if m % 128 == 0 else (m if m <= 512 else 128)
    bn = 128 if n % 128 == 0 else (n if n <= 512 else 128)
    bk = 128 if kdim % 128 == 0 else (kdim if kdim <= 512 else 128)
    y = lora_matmul(x2, w, a, b, scale=scale, bm=bm, bn=bn, bk=bk,
                    interpret=interpret)
    return y.reshape(*lead, n).astype(x.dtype)


def fedex_fold(w0: jnp.ndarray, a_stack: jnp.ndarray, b_stack: jnp.ndarray,
               scale: float, *, weights: Optional[jnp.ndarray] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """W0 + scale·ΔW_res, fused & tiled. Handles stacked-layer leading axes.

    ``weights`` — optional (C,) normalized client weights; zeros mask
    non-delivered lanes of a C_max-padded stack (fedsrv ragged rounds).
    The kernel zero-pads tile-indivisible (m, n) internally, so odd model
    dims (whisper/qwen head dims) take the fused path instead of falling
    back to the dense jnp oracle.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if w0.ndim > 2:  # stacked layers: vmap over the leading axes
        return jax.vmap(lambda w, a, b: fedex_fold(w, a, b, scale,
                                                   weights=weights,
                                                   interpret=interpret)
                        )(w0, a_stack, b_stack)
    m, n = w0.shape
    bm = 256 if m % 256 == 0 else (128 if m % 128 == 0 else min(m, 512))
    bn = 256 if n % 256 == 0 else (128 if n % 128 == 0 else min(n, 512))
    out = fedex_residual_apply(w0, a_stack, b_stack, weights, scale=scale,
                               bm=bm, bn=bn, interpret=interpret)
    return out.astype(w0.dtype)


def _fold_tiles(m: int, n: int) -> tuple:
    bm = 256 if m % 256 == 0 else (128 if m % 128 == 0 else min(m, 512))
    bn = 256 if n % 256 == 0 else (128 if n % 128 == 0 else min(n, 512))
    return bm, bn


def product_fold(w0: jnp.ndarray, a_stack: jnp.ndarray, b_stack: jnp.ndarray,
                 signs: jnp.ndarray, scale: float, *,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """W0 + scale·Σ_c s_c·a_c b_c, fused & tiled — SIGNED per-lane vector,
    no mean-product subtraction. The engine's reinit close (s = w) and the
    factored rank-r' residual fold of the fedex_svd close (one lane, s=[1])
    both route here. Layout matches ``fedex_fold``: stacked-layer leading
    axes come first, the client axis sits immediately before (m, r)/(r, n).
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if w0.ndim > 2:  # stacked layers: vmap over the leading axes
        return jax.vmap(lambda w, a, b: product_fold(w, a, b, signs, scale,
                                                     interpret=interpret)
                        )(w0, a_stack, b_stack)
    bm, bn = _fold_tiles(*w0.shape)
    out = product_fold_apply(w0, a_stack, b_stack, signs, scale=scale,
                             bm=bm, bn=bn, interpret=interpret)
    return out.astype(w0.dtype)


def product_accum(acc: jnp.ndarray, a_stack: jnp.ndarray,
                  b_stack: jnp.ndarray, signs: jnp.ndarray, scale: float, *,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """acc + scale·Σ_c s_c·a_c b_c with the accumulator ALIASED to the output
    (read-modify-write). The chunked close's partial-fold primitive: same
    layout contract as ``product_fold`` (stacked-layer axes lead, client axis
    immediately before (m, r)/(r, n)), but folding into a running dense
    accumulator instead of W0 — each chunk pays one pass, never a fresh m×n.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if acc.ndim > 2:  # stacked layers: vmap over the leading axes
        return jax.vmap(lambda w, a, b: product_accum(w, a, b, signs, scale,
                                                      interpret=interpret)
                        )(acc, a_stack, b_stack)
    bm, bn = _fold_tiles(*acc.shape)
    return product_accum_apply(acc, a_stack, b_stack, signs, scale=scale,
                               bm=bm, bn=bn, interpret=interpret)


def perclient_fold(w0_stack: jnp.ndarray, a_stack: jnp.ndarray,
                   b_stack: jnp.ndarray, weights: jnp.ndarray, scale: float, *,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Keep_local close: lane c gets W0_c + scale·(Σ_j w_j a_j b_j − a_c b_c),
    all lanes in one tiled pass. Unlike the other folds the CLIENT axis leads
    every input/output — (C, …, m, n) / (C, …, m, r) — matching the engine's
    streamed stacks natively; stacked-layer axes in between are vmapped.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if w0_stack.ndim > 3:  # (C, L, ..., m, n): vmap over the layer axes
        return jax.vmap(lambda w, a, b: perclient_fold(w, a, b, weights, scale,
                                                       interpret=interpret),
                        in_axes=1, out_axes=1)(w0_stack, a_stack, b_stack)
    bm, bn = _fold_tiles(*w0_stack.shape[1:])
    out = perclient_fold_apply(w0_stack, a_stack, b_stack, weights,
                               scale=scale, bm=bm, bn=bn, interpret=interpret)
    return out.astype(w0_stack.dtype)


def hetero_fold(w0_stack: jnp.ndarray, a_stack: jnp.ndarray,
                b_stack: jnp.ndarray, weights: jnp.ndarray,
                ranks: jnp.ndarray, own_a: jnp.ndarray, own_b: jnp.ndarray,
                scale: float, *,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Hetero close: lane c gets W0_c + scale·(Σ_j w_j (a_j∘mask_j) b_j −
    (A'∘mask_c) B'), all lanes in one tiled pass. Layout follows
    ``perclient_fold`` (client axis leads, layer axes vmapped in between);
    ``ranks`` is the (C,) int32 TRUE-rank vector (−1 = full rank) riding as
    a second scalar-prefetch operand, and (own_a, own_b) are the SHARED
    rank-r_max truncation factors every lane masks down to its own rank.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if w0_stack.ndim > 3:  # (C, L, ..., m, n): vmap over the layer axes
        return jax.vmap(lambda w, a, b, oa, ob: hetero_fold(
            w, a, b, weights, ranks, oa, ob, scale, interpret=interpret),
            in_axes=(1, 1, 1, 0, 0), out_axes=1)(
            w0_stack, a_stack, b_stack, own_a, own_b)
    bm, bn = _fold_tiles(*w0_stack.shape[1:])
    out = hetero_fold_apply(w0_stack, a_stack, b_stack, weights, ranks,
                            own_a, own_b, scale=scale, bm=bm, bn=bn,
                            interpret=interpret)
    return out.astype(w0_stack.dtype)


def factor_mean(stack: jnp.ndarray, weights: Optional[jnp.ndarray] = None, *,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Σ_c w_c·x_c over the leading client axis of a stacked factor, tiled.

    Handles stacked-layer leading axes between the client axis and the final
    (m, n) factor dims by vmapping the 3-D kernel. Uniform (``weights=None``)
    sums in slot order then divides — the tree_mean twin.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if stack.ndim > 3:  # (C, L, ..., m, n): move layer axes out, vmap
        return jax.vmap(lambda s: factor_mean(s, weights, interpret=interpret),
                        in_axes=1)(stack)
    return lora_factor_mean(stack, weights, interpret=interpret)


def swa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """(B, S, H, D) GQA-aware wrapper over the flash_swa kernel."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:  # GQA: repeat kv heads (kernel sees BH streams)
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    sk = kf.shape[1]
    bq = 256 if sq % 256 == 0 else (128 if sq % 128 == 0 else (sq if sq <= 512 else 0))
    bk = 256 if sk % 256 == 0 else (128 if sk % 128 == 0 else (sk if sk <= 512 else 0))
    if 0 in (bq, bk):
        out = ref.flash_swa_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = flash_swa(qf, kf, vf, causal=causal, window=window, bq=bq, bk=bk,
                        interpret=interpret)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3).astype(q.dtype)
