"""Jit'd public wrappers around the Pallas kernels.

On this CPU container, kernels execute with ``interpret=True`` (Pallas
reference interpreter); on TPU the same calls compile to Mosaic. The wrappers
pad to tile multiples, handle batching/GQA reshapes, and fall back to the
ref.py oracles when a shape can't be tiled sensibly.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fedex_residual import fedex_residual_apply
from repro.kernels.flash_swa import flash_swa
from repro.kernels.lora_matmul import lora_matmul

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
DEFAULT_INTERPRET = not _ON_TPU


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def lora_dense(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
               scale: float, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused LoRA projection for arbitrary leading dims of x. Returns x-dtype."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    bm = 128 if m % 128 == 0 else (m if m <= 512 else 0)
    bn = 128 if n % 128 == 0 else (n if n <= 512 else 0)
    bk = 128 if kdim % 128 == 0 else (kdim if kdim <= 512 else 0)
    if 0 in (bm, bn, bk):
        y = ref.lora_matmul_ref(x2, w, a, b, scale)
    else:
        y = lora_matmul(x2, w, a, b, scale=scale, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)
    return y.reshape(*lead, n).astype(x.dtype)


def fedex_fold(w0: jnp.ndarray, a_stack: jnp.ndarray, b_stack: jnp.ndarray,
               scale: float, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """W0 + scale·ΔW_res, fused & tiled. Handles stacked-layer leading axes."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    if w0.ndim > 2:  # stacked layers: vmap over the leading axes
        return jax.vmap(lambda w, a, b: fedex_fold(w, a, b, scale,
                                                   interpret=interpret)
                        )(w0, a_stack, b_stack)
    m, n = w0.shape
    bm = 256 if m % 256 == 0 else (128 if m % 128 == 0 else (m if m <= 1024 else 0))
    bn = 256 if n % 256 == 0 else (128 if n % 128 == 0 else (n if n <= 1024 else 0))
    if 0 in (bm, bn):
        return ref.fedex_residual_ref(w0, a_stack, b_stack, scale).astype(w0.dtype)
    out = fedex_residual_apply(w0, a_stack, b_stack, scale=scale, bm=bm, bn=bn,
                               interpret=interpret)
    return out.astype(w0.dtype)


def swa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """(B, S, H, D) GQA-aware wrapper over the flash_swa kernel."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:  # GQA: repeat kv heads (kernel sees BH streams)
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    sk = kf.shape[1]
    bq = 256 if sq % 256 == 0 else (128 if sq % 128 == 0 else (sq if sq <= 512 else 0))
    bk = 256 if sk % 256 == 0 else (128 if sk % 128 == 0 else (sk if sk <= 512 else 0))
    if 0 in (bq, bk):
        out = ref.flash_swa_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = flash_swa(qf, kf, vf, causal=causal, window=window, bq=bq, bk=bk,
                        interpret=interpret)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3).astype(q.dtype)
