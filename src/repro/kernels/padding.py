"""Shared zero-padding helper for the tiled kernels.

Zero rows/columns are exact no-ops for every product these kernels compute
(base GEMM, adapter products, residual terms, factor means), so padding to
the next tile multiple and slicing back changes nothing numerically.
"""

from __future__ import annotations

import jax.numpy as jnp


def pad_axis(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``mult``."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
