"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, scale: float) -> jnp.ndarray:
    """y = x @ w + scale * (x @ a) @ b, accumulated in f32."""
    base = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    adapter = jnp.dot(jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32)),
                      b.astype(jnp.float32))
    return base + scale * adapter


def fedex_residual_ref(w0: jnp.ndarray, a_stack: jnp.ndarray,
                       b_stack: jnp.ndarray, scale: float,
                       weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """W0 + scale·(Σ_c w_c·(a_c @ b_c) − ā @ b̄)  with  ā = Σ_c w_c·a_c.

    a_stack: (C, m, r), b_stack: (C, r, n), w0: (m, n); ``weights=None`` →
    uniform w_c = 1/C. Zero weights mask non-delivered lanes of a padded stack.
    """
    af = a_stack.astype(jnp.float32)
    bf = b_stack.astype(jnp.float32)
    if weights is None:
        mean_prod = jnp.einsum("cmr,crn->mn", af, bf) / af.shape[0]
        abar = af.mean(0)
        bbar = bf.mean(0)
    else:
        w = jnp.asarray(weights, jnp.float32)
        mean_prod = jnp.einsum("c,cmr,crn->mn", w, af, bf)
        abar = jnp.einsum("c,cmr->mr", w, af)
        bbar = jnp.einsum("c,crn->rn", w, bf)
    return w0.astype(jnp.float32) + scale * (mean_prod - abar @ bbar)


def product_fold_ref(w0: jnp.ndarray, a_stack: jnp.ndarray,
                     b_stack: jnp.ndarray, signs: jnp.ndarray,
                     scale: float) -> jnp.ndarray:
    """W0 + scale·Σ_c s_c·(a_c @ b_c) — signed per-lane weights, no mean
    subtraction (reinit / factored low-rank folds)."""
    s = jnp.asarray(signs, jnp.float32)
    acc = jnp.einsum("c,cmr,crn->mn", s, a_stack.astype(jnp.float32),
                     b_stack.astype(jnp.float32))
    return w0.astype(jnp.float32) + scale * acc


def perclient_fold_ref(w0_stack: jnp.ndarray, a_stack: jnp.ndarray,
                       b_stack: jnp.ndarray, weights: jnp.ndarray,
                       scale: float) -> jnp.ndarray:
    """Lane c: W0_c + scale·(Σ_j w_j a_j b_j − a_c b_c) — the keep_local
    per-client residual folds over a stacked client axis."""
    w = jnp.asarray(weights, jnp.float32)
    af = a_stack.astype(jnp.float32)
    bf = b_stack.astype(jnp.float32)
    ideal = jnp.einsum("c,cmr,crn->mn", w, af, bf)
    own = jnp.einsum("cmr,crn->cmn", af, bf)
    return w0_stack.astype(jnp.float32) + scale * (ideal[None] - own)


def factor_mean_ref(stack: jnp.ndarray,
                    weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Σ_c w_c · x_c over the leading client axis (uniform 1/C when None)."""
    xf = stack.astype(jnp.float32)
    if weights is None:
        return xf.mean(0)
    w = jnp.asarray(weights, jnp.float32)
    return jnp.tensordot(w, xf, axes=(0, 0))


def flash_swa_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Materialised attention oracle. q,k,v: (BH, S, D)."""
    _, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
