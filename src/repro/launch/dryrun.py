import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
production mesh(es) with ShapeDtypeStruct inputs — no allocation — and extract
memory / cost / collective statistics for EXPERIMENTS.md §Dry-run / §Roofline.

The two lines above MUST precede any other import: jax locks the device count
on first initialisation. 512 placeholder host devices back both the 16×16
single-pod mesh (256) and the 2×16×16 multi-pod mesh (512).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, LoRAConfig, TrainConfig, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_per_step
from repro.launch.steps import (
    abstract_cache,
    abstract_state,
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import build_model
from repro.sharding import (batch_spec, cache_spec, data_axes, param_spec,
                            param_spec_serving, tree_shardings)
from repro.sharding import act
from repro.util.logging import get_logger

logger = get_logger("dryrun")

SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

# microbatch split for the train_4k global batch of 256 (activation memory)
TRAIN_MICROBATCHES = 8


def should_skip(cfg, shape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: 500k decode skipped per assignment "
                "(see DESIGN.md §4)")
    return None


def _sharding_tree(tree, mesh, fn, *args):
    return tree_shardings(tree, mesh, fn, *args)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, moe_impl: str = "ragged",
            extra_tags: Optional[Dict] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                           "moe_impl": moe_impl}
    if extra_tags:
        rec.update(extra_tags)

    skip = should_skip(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        dp = data_axes(mesh)
        model = build_model(cfg, moe_impl=moe_impl)
        lora_cfg = LoRAConfig(rank=16, alpha=32)
        params, lora, opt_state = abstract_state(model, cfg, lora_cfg)

        # decode shapes use the weight-stationary serving layout (§Perf it. 7)
        pspec_fn = param_spec_serving if shape.is_decode else param_spec
        p_sh = _sharding_tree(params, mesh, pspec_fn)
        l_sh = _sharding_tree(lora, mesh, pspec_fn)
        o_sh = jax.tree.map(
            lambda s: s, jax.eval_shape(lambda l: l, lora))  # placeholder
        from repro.optim import init_adamw
        o_sh = {
            "step": NamedSharding(mesh, P()),
            "mu": _sharding_tree(opt_state.mu, mesh, param_spec),
            "nu": _sharding_tree(opt_state.nu, mesh, param_spec),
        }
        from repro.optim.adamw import AdamWState
        o_sh = AdamWState(step=o_sh["step"], mu=o_sh["mu"], nu=o_sh["nu"])

        batch = input_specs(cfg, shape)
        b_sh = _sharding_tree(batch, mesh, batch_spec, dp)
        scalar_sh = NamedSharding(mesh, P())

        act.configure(dp, "model", mesh.shape["model"])
        with mesh:
            if shape.kind == "train":
                step_fn = make_train_step(model, lora_cfg,
                                          TrainConfig(total_steps=1000),
                                          num_microbatches=TRAIN_MICROBATCHES)
                step_spec = jax.ShapeDtypeStruct((), jnp.int32)
                jitted = jax.jit(step_fn,
                                 in_shardings=(p_sh, l_sh, o_sh, b_sh, scalar_sh))
                lowered = jitted.lower(params, lora, opt_state, batch, step_spec)
            elif shape.kind == "prefill":
                cache = abstract_cache(model, shape.global_batch, shape.seq_len)
                c_sh = _sharding_tree(cache, mesh, cache_spec, dp)
                step_fn = make_prefill_step(model, lora_cfg)
                jitted = jax.jit(step_fn, in_shardings=(p_sh, l_sh, b_sh, c_sh))
                lowered = jitted.lower(params, lora, batch, cache)
            else:  # decode
                cache = abstract_cache(model, shape.global_batch, shape.seq_len)
                c_sh = _sharding_tree(cache, mesh, cache_spec, dp)
                step_fn = make_decode_step(model, lora_cfg)
                tok_spec = batch["tokens"]
                tok_sh = _sharding_tree({"tokens": tok_spec}, mesh, batch_spec, dp)["tokens"]
                pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
                jitted = jax.jit(step_fn,
                                 in_shardings=(p_sh, l_sh, tok_sh, c_sh, scalar_sh))
                lowered = jitted.lower(params, lora, tok_spec, cache, pos_spec)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        act.reset()

        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)

        # ---- memory -------------------------------------------------------
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
                "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory"] = {"error": str(e)[:200]}

        # ---- cost + collectives (loop-aware HLO accounting) -----------------
        try:
            xla_cost = compiled.cost_analysis()
            if isinstance(xla_cost, (list, tuple)):
                xla_cost = xla_cost[0]
            rec["xla_cost_flops"] = float((xla_cost or {}).get("flops", 0.0))
        except Exception as e:
            rec["cost_error"] = str(e)[:200]
        costs = hlo_analyze(compiled.as_text())
        compute_s = costs.flops / PEAK_FLOPS
        memory_s = costs.bytes_accessed / HBM_BW
        collective_s = costs.total_collective_bytes / ICI_BW
        dominant = max((("compute", compute_s), ("memory", memory_s),
                        ("collective", collective_s)), key=lambda kv: kv[1])[0]
        mflops = model_flops_per_step(cfg, shape)
        n_dev = mesh.size
        rec["roofline"] = {
            "flops": costs.flops,
            "hbm_bytes": costs.bytes_accessed,
            "collective_bytes": costs.total_collective_bytes,
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "collectives": costs.collective_bytes,
            "collective_counts": costs.collective_counts,
            "model_flops_global": mflops,
            "model_flops_per_device": mflops / n_dev,
            "useful_flops_ratio": (mflops / n_dev) / costs.flops if costs.flops else None,
        }
        logger.info(
            "%s × %s × %s: OK compile=%.1fs flops/dev=%.3e coll=%.3e B dominant=%s useful=%.2f",
            arch, shape_name, mesh_tag, t_compile, costs.flops,
            costs.total_collective_bytes, dominant,
            (mflops / n_dev) / costs.flops if costs.flops else -1)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {str(e)[:500]}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        logger.error("%s × %s × %s: FAILED %s", arch, shape_name, mesh_tag,
                     rec["error"][:200])
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all' (assigned pool)")
    ap.add_argument("--shape", default="all", choices=("all",) + SHAPE_NAMES)
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--moe-impl", default="dense", choices=("ragged", "dense"),
                    help="dense partitions cleanly under GSPMD (§Perf it.5); "
                         "ragged is FLOP-proportional for single-host runs")
    ap.add_argument("--out", default="", help="append JSON-lines records here")
    args = ap.parse_args()

    archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_NAMES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, multi_pod=mp, moe_impl=args.moe_impl)
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run summary: {ok} ok, {sk} skipped, {err} failed / {len(records)} total")
    if err:
        for r in records:
            if r["status"] == "error":
                print(f"  FAIL {r['arch']} × {r['shape']} × {r['mesh']}: {r['error'][:160]}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
