"""Loop-aware HLO cost accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, but our
stacks are scan-over-layers (× microbatch scan × flash-KV scan), so FLOPs /
bytes / collective bytes would be undercounted by 1–3 orders of magnitude.
This module parses the optimized HLO text, builds the computation call graph,
derives per-while trip counts from the loop-condition constants, and sums

* dot FLOPs                       (2 · |out| · contraction)
* per-instruction bytes accessed  (operands + outputs — HBM-traffic proxy;
                                   fusion-internal computations are opaque so
                                   nothing double-counts)
* collective bytes by kind        (all-gather / all-reduce / reduce-scatter /
                                   all-to-all / collective-permute)

each multiplied by the product of enclosing trip counts. Trip counts come
from the largest integer constant in the loop's condition computation —
exact for scan-canonical loops (iter < N), the only loops jax emits here.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(pred|[a-z]\d+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-_]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# bytes-accounting skips bookkeeping opcodes
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes_list(type_str: str) -> Tuple[int, List[List[int]]]:
    total = 0
    dim_lists = []
    for m in _SHAPE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",")] if dims_s.strip() else []
        numel = 1
        for d in dims:
            numel *= d
        total += numel * _DTYPE_BYTES[dt]
        dim_lists.append(dims)
    return total, dim_lists


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str
    out_bytes: int
    out_dims: List[int]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)
    max_const: int = 0


def _is_header(line: str) -> Optional[str]:
    s = line.strip()
    if not s.endswith("{") or ") -> " not in s:
        return None
    if s.startswith("ENTRY"):
        s2 = s[len("ENTRY"):].strip()
        m = re.match(r"%?([\w\.\-_]+)", s2)
        return "ENTRY:" + m.group(1) if m else None
    if s.startswith("%"):
        m = re.match(r"%([\w\.\-_]+)", s)
        return m.group(1) if m else None
    return None


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            h = _is_header(line)
            if h:
                if h.startswith("ENTRY:"):
                    h = h[len("ENTRY:"):]
                    entry = h
                current = Computation(h)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        mi = _INSTR.match(line)
        if mi:
            name, type_str, opcode, rest = mi.groups()
            out_bytes, dim_lists = _shape_bytes_list(type_str)
            out_dims = dim_lists[0] if dim_lists else []
            current.instrs.append(Instr(name, type_str, opcode, rest,
                                        out_bytes, out_dims))
            current.shapes[name] = type_str
        for mc in _CONST_INT.finditer(line):
            current.max_const = max(current.max_const, int(mc.group(1)))
    return comps, entry


def _calls(instr: Instr) -> List[Tuple[str, str]]:
    out = []
    for m in re.finditer(r"(condition|body|calls|to_apply)=%?([\w\.\-_]+)", instr.rest):
        out.append((m.group(1), m.group(2)))
    m = re.search(r"branch_computations=\{([^}]*)\}", instr.rest)
    if m:
        for name in re.findall(r"%([\w\.\-_]+)", m.group(1)):
            out.append(("branch", name))
    return out


def compute_multipliers(comps: Dict[str, Computation], entry: str
                        ) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """Returns (multiplier per computation, is_fusion_context per computation)."""
    mult: Dict[str, float] = defaultdict(float)
    fusion_ctx: Dict[str, bool] = {entry: False}
    mult[entry] = 1.0
    for _ in range(128):  # call graph is a DAG; fixpoint converges fast
        changed = False
        for cname, comp in comps.items():
            cm = mult.get(cname, 0.0)
            if cm == 0.0:
                continue
            in_fusion = fusion_ctx.get(cname, False)
            for instr in comp.instrs:
                for kind, callee in _calls(instr):
                    if callee not in comps:
                        continue
                    if kind == "body":
                        cond = None
                        mcond = re.search(r"condition=%?([\w\.\-_]+)", instr.rest)
                        if mcond:
                            cond = mcond.group(1)
                        trips = max(comps[cond].max_const, 1) if (
                            cond and cond in comps) else 1
                        add = cm * trips
                        f = in_fusion
                    elif kind in ("condition", "branch"):
                        add = cm
                        f = in_fusion
                    else:  # calls / to_apply → fusion-internal
                        add = cm
                        f = True
                    if mult.get(callee, 0.0) < add:
                        mult[callee] = add
                        changed = True
                    if fusion_ctx.get(callee, True) and not f:
                        if fusion_ctx.get(callee) is not False:
                            fusion_ctx[callee] = False
                            changed = True
                    elif callee not in fusion_ctx:
                        fusion_ctx[callee] = f
                        changed = True
        if not changed:
            break
    return dict(mult), fusion_ctx


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_numel = 1
    for d in instr.out_dims:
        out_numel *= d
    contract = 1
    mc = _CONTRACT.search(instr.rest)
    operand_part = instr.rest.split(")")[0]
    operands = _OPERAND.findall(operand_part)
    if mc and operands:
        lhs_type = comp.shapes.get(operands[0], "")
        _, dim_lists = _shape_bytes_list(lhs_type)
        if dim_lists:
            lhs_dims = dim_lists[0]
            for idx_s in mc.group(1).split(","):
                if idx_s.strip():
                    i = int(idx_s)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
    return 2.0 * out_numel * contract


def _instr_bytes(instr: Instr, comp: Computation) -> float:
    """HBM-traffic estimate for one instruction execution.

    Key subtleties (all verified against granite-8b dumps):
    * while/conditional/call move no data themselves — bodies account for it.
    * dynamic-update-slice (op OR fusion root — XLA names fusions by root):
      bufferized in place; traffic ≈ 2 × the updated SLICE, which for scan-ys
      buffers is out_bytes / leading_dim. Counting the full stacked buffer per
      iteration overstates by the trip count (≈ 1000× for deep stacks).
    * dynamic-slice / gather: reads ≈ output size, not the full operand.
    """
    opcode = instr.opcode
    name = instr.name
    if opcode in ("while", "conditional", "call", "custom-call"):
        return 0.0
    operand_part = instr.rest.split(")")[0]
    operands = _OPERAND.findall(operand_part)

    is_dus = (opcode in ("dynamic-update-slice", "scatter")
              or (opcode == "fusion" and "dynamic-update-slice" in name)
              or (opcode == "fusion" and "scatter" in name))
    is_ds = (opcode in ("dynamic-slice", "gather")
             or (opcode == "fusion" and not is_dus
                 and ("dynamic-slice" in name or "gather" in name)))

    if is_dus:
        lead = instr.out_dims[0] if instr.out_dims else 1
        return 2.0 * instr.out_bytes / max(lead, 1)
    if is_ds:
        return 2.0 * instr.out_bytes

    b = float(instr.out_bytes)
    cap = 4.0 * max(instr.out_bytes, 1)
    for op_name in operands:
        t = comp.shapes.get(op_name)
        if t:
            ob, _ = _shape_bytes_list(t)
            if opcode == "fusion":
                # fusions that slice a big stacked buffer internally would
                # otherwise charge the FULL buffer per loop iteration; cap
                # each operand at 4× the output (covers kInput reductions
                # while bounding the slice-inside-fusion overcount).
                ob = min(ob, cap)
            b += ob
    return b


@dataclass
class HloCosts:
    flops: float
    bytes_accessed: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloCosts:
    comps, entry = parse_module(text)
    if entry is None or entry not in comps:
        return HloCosts(0.0, 0.0, {}, {})
    mult, fusion_ctx = compute_multipliers(comps, entry)

    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_counts: Dict[str, float] = defaultdict(float)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = fusion_ctx.get(cname, True)
        for instr in comp.instrs:
            if instr.opcode in ("dot", "dot-general", "convolution"):
                flops += m * _dot_flops(instr, comp)
            if not in_fusion and instr.opcode not in _FREE_OPS:
                bytes_accessed += m * _instr_bytes(instr, comp)
            base = instr.opcode.replace("-start", "")
            if base in _COLLECTIVES and not instr.opcode.endswith("-done"):
                coll_bytes[base] += m * instr.out_bytes
                coll_counts[base] += m

    return HloCosts(flops=flops, bytes_accessed=bytes_accessed,
                    collective_bytes=dict(coll_bytes),
                    collective_counts=dict(coll_counts))
