"""Production mesh construction (TPU v5e; 256 chips/pod, optionally 2 pods).

A FUNCTION (not module-level state) so importing never touches jax device
initialisation — the dry-run sets XLA_FLAGS before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_local_mesh(model_parallel: int = 1):
    """Dev mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
