"""Production mesh construction (TPU v5e; 256 chips/pod, optionally 2 pods).

A FUNCTION (not module-level state) so importing never touches jax device
initialisation — the dry-run sets XLA_FLAGS before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_local_mesh(model_parallel: int = 1):
    """Dev mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_client_mesh(c_max: int, model_parallel: int = 1):
    """Mesh for co-scheduled federated rounds: a leading ``client`` axis.

    Client-stacked adapters/optimizer state/batches shard their leading
    ``(C_max, …)`` axis over ``client`` (sharding/specs.client_stack_spec)
    and base params replicate across it, so per-client local training and
    the masked weighted round close each run as ONE pjit'd program with the
    close's client-axis reductions lowered to psum-mean collectives.

    The client axis is sized to the largest divisor of ``c_max`` that the
    available device count supports — C_max lanes spread lane-per-device-
    group when it divides, and fall back toward 1 (fully replicated lanes,
    e.g. single-device CPU tests: same program, trivial collectives)
    otherwise. ``model_parallel`` carves an inner ``model`` axis off the
    remaining devices for tensor-parallel lanes.
    """
    if c_max < 1:
        raise ValueError(f"c_max must be ≥ 1, got {c_max}")
    devices = jax.devices()
    avail = len(devices) // model_parallel
    if avail < 1:
        raise RuntimeError(
            f"model_parallel={model_parallel} exceeds the {len(devices)} "
            "available devices")
    n_client = 1
    for d in range(min(c_max, avail), 0, -1):
        if c_max % d == 0:
            n_client = d
            break
    used = n_client * model_parallel
    return jax.make_mesh((n_client, model_parallel), ("client", "model"),
                         devices=devices[:used])
