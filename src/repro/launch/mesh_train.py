"""Mesh-mode federated rounds: co-scheduled clients, one pjit'd close.

The host-orchestrated trainer (core/federated.py) runs clients sequentially
and closes rounds through the streaming engine. THIS module is the
datacenter twin (``launch/train.py --mode mesh``): client state is STACKED
on a leading ``(C_max, …)`` axis sharded over a ``client`` mesh axis
(launch/mesh.make_client_mesh + sharding/specs.client_stack_spec), and each
phase of a round is ONE pjit'd program:

* **local training** — ``make_mesh_round_fn`` vmaps a client's whole
  ``local_steps`` scan over the client axis, so every client's AdamW steps
  for the round run in a single compiled program (lanes co-scheduled on the
  mesh; base params replicated across the client axis, adapters/optimizer
  state/batches lane-sharded).
* **the round close** — the engine's weighted close program
  (core/engine.make_close_fn, jnp backend) compiled over the client-sharded
  stacks: weighted factor means, the exact residual fold into W0 and the §6
  divergence. Under GSPMD the ``Σ_c w_c·…`` reductions over the sharded
  client axis lower to psum-mean collectives — the masked psum-mean.

Partial participation / weighting contract (same C_max padding contract as
the streaming engine): lane c always belongs to client c; a round's sampled
subset and its weights enter ONLY through the ``(C_max,)`` weight vector —
zero weight masks a lane exactly (its factors vanish from every sum), so a
50 % sampled round, an example-weighted round and a full uniform round all
reuse the SAME compiled close program. One program per (method, shapes)
signature, asserted via the close's compile-cache count in
tests/test_mesh_round.py. Non-sampled lanes still train (the hardware lanes
exist either way — their updates are simply masked at the close); their
compute is the padding cost, not a correctness concern.

Numerics: mesh mode always takes the engine's weighted branch (there is no
bitwise-uniform branch here — a uniform round is just the uniform weight
vector), which matches the eager weighted oracle to tight float32 tolerance
(≤ ~1e-5; see docs/architecture.md for the full contract table).

Overlap: the close returns its divergence as a
core/engine.DeferredDivergence device handle — the mesh loop resolves it at
the next round boundary, never inside the close.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import FedConfig, LoRAConfig, TrainConfig
from repro.core import aggregation as agg
from repro.core.engine import (DeferredDivergence, build_factor_specs,
                               collect_w0_leaves, fold_back_w0, make_close_fn)
from repro.core.federated import (RoundRecord, evaluate_on_batches,
                                  make_eval_fn, resolve_divergences)
from repro.core.lora import init_lora
from repro.optim import adamw_update, clip_by_global_norm, init_adamw, lr_at
from repro.sharding import client_stack_spec
from repro.util.logging import get_logger
from repro.util.tree import flatten_with_paths, unflatten_from_paths

logger = get_logger("mesh_train")

Params = Dict[str, Any]

MESH_METHODS = ("fedex", "fedex_svd")


# --------------------------------------------------------------------------
# the stacked local-training program (one pjit'd program per round)
# --------------------------------------------------------------------------

def make_mesh_round_fn(model, lora_scale: float,
                       train_cfg: TrainConfig,
                       masked: bool = False) -> Callable:
    """One round of local training for ALL lanes in a single jitted program.

    ``round_fn(params, lora_stack, batches, lrs)`` scans a lane's
    ``local_steps`` of clipped AdamW (identical math to
    core/federated.make_local_step) and vmaps the scan over the leading
    client axis; ``batches`` leaves are ``(C_max, steps, B, …)``, ``lrs`` is
    the precomputed ``(steps,)`` schedule slice (shared by every lane, like
    the host trainer). Returns ``(new_lora_stack, losses (C_max, steps))``.
    Base ``params`` broadcast unsharded across lanes; the adapter stack and
    batches shard over the client axis where the caller placed them so XLA
    partitions lane compute across the mesh.

    ``masked=True`` compiles the uneven-budget variant:
    ``round_fn(params, lora_stack, batches, lrs, budgets)`` takes a
    per-lane ``(C_max,)`` int step-budget vector and freezes lane c's
    adapter/optimizer state once ``t ≥ budgets[c]`` (``jnp.where`` selects
    on every leaf — the scan stays co-scheduled, dead iterations are the
    padding cost). A frozen lane's reported losses repeat its last live
    loss, so ``losses[:, -1]`` remains "the lane's final training loss".
    The default path compiles WITHOUT the masking selects and is
    bitwise-unchanged.
    """

    def one_lane(params, lora, batches, lrs, budget):
        opt_state = init_adamw(lora)

        def body(carry, xs):
            lora, opt_state, t, last = carry
            batch, lr = xs

            def loss_fn(l):
                return model.loss(params, batch, lora=l,
                                  lora_scale=lora_scale)

            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora)
            grads, _ = clip_by_global_norm(grads, train_cfg.grad_clip)
            new_lora, new_opt = adamw_update(
                grads, opt_state, lora, learning_rate=lr,
                beta1=train_cfg.beta1, beta2=train_cfg.beta2,
                eps=train_cfg.eps, weight_decay=train_cfg.weight_decay)
            if masked:
                live = t < budget
                sel = lambda n, o: jnp.where(live, n, o)  # noqa: E731
                new_lora = jax.tree.map(sel, new_lora, lora)
                new_opt = jax.tree.map(sel, new_opt, opt_state)
                loss = jnp.where(live, loss, last)
            return (new_lora, new_opt, t + 1, loss), loss

        (lora, _, _, _), losses = jax.lax.scan(
            body, (lora, opt_state, jnp.int32(0), jnp.float32(0.0)),
            (batches, lrs))
        return lora, losses

    if masked:
        def round_fn(params, lora_stack, batches, lrs, budgets):
            return jax.vmap(one_lane, in_axes=(None, 0, 0, None, 0))(
                params, lora_stack, batches, lrs, budgets)
    else:
        def round_fn(params, lora_stack, batches, lrs):
            return jax.vmap(one_lane, in_axes=(None, 0, 0, None, None))(
                params, lora_stack, batches, lrs, jnp.int32(0))

    return jax.jit(round_fn)


# --------------------------------------------------------------------------
# the mesh close: the engine's weighted program over client-sharded stacks
# --------------------------------------------------------------------------

class MeshRoundCloser:
    """Masked psum-mean round close for mesh mode.

    Wraps the engine's weighted close program (core/engine.make_close_fn,
    jnp backend — its client-axis einsum reductions are what GSPMD lowers to
    collectives over the ``client`` mesh axis) with the mesh-mode lane
    contract: lane c IS client c, and a round's participation pattern lives
    entirely in the ``(C_max,)`` weight vector, so every round of a run —
    full, sampled, weighted — hits ONE compiled program per (method, shapes)
    signature (``compiled_programs`` exposes the cache count for the tests).

    The close returns the divergence as a :class:`DeferredDivergence` — no
    host sync inside the close; resolve at the next round boundary.
    """

    def __init__(self, mesh, params: Params, lora_template: Params, *,
                 c_max: int, scale: float, method: str = "fedex",
                 svd_rank: int = 0, donate: bool = False, recorder=None):
        if method not in MESH_METHODS:
            raise ValueError(
                f"mesh mode closes {MESH_METHODS} rounds, got {method!r} "
                "(the §6 assignment strategies are host-orchestrated — "
                "see core/federated.py)")
        from repro.obs import NULL
        self.mesh = mesh
        self.c_max = c_max
        self.method = method
        self.rec = recorder if recorder is not None else NULL
        self.specs = build_factor_specs(params, lora_template)
        self._close = make_close_fn(self.specs, scale=scale, c_max=c_max,
                                    method=method, svd_rank=svd_rank,
                                    backend="jnp", donate=donate)

    # ------------------------------------------------------------------
    @property
    def compiled_programs(self) -> int:
        """How many close programs have been compiled (the padding-contract
        promise is that this stays at 1 per (method, shapes) signature no
        matter how participation or weights vary across rounds)."""
        return self._close._cache_size()

    def stack_shardings(self, stacks: Dict[str, jnp.ndarray]):
        """path → NamedSharding placing each (C_max, …) stack's leading axis
        on the ``client`` mesh axis (divisibility-guarded)."""
        return {p: NamedSharding(self.mesh, client_stack_spec(p, x, self.mesh))
                for p, x in stacks.items()}

    def shard_stacks(self, stacks: Dict[str, jnp.ndarray]
                     ) -> Dict[str, jnp.ndarray]:
        shardings = self.stack_shardings(stacks)
        return {p: jax.device_put(stacks[p], shardings[p]) for p in stacks}

    def weight_vector(self, client_ids: Sequence[int],
                      weights: Optional[Sequence[float]] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(C_max,) weight vector + 0/1 mask for the sampled subset.

        Lane c ≡ client c (mesh mode co-schedules every lane); non-sampled
        lanes get weight 0 — the participation mask. Uniform-over-subset
        when ``weights`` is None."""
        if not client_ids:
            raise ValueError("cannot close a round with no participants")
        ids = sorted(client_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate client ids in {list(client_ids)}")
        if ids[0] < 0 or ids[-1] >= self.c_max:
            raise ValueError(f"client ids {ids} outside [0, {self.c_max})")
        mask = np.zeros(self.c_max, np.float32)
        mask[ids] = 1.0
        w = np.zeros(self.c_max, np.float32)
        norm = agg.normalize_weights(weights, len(ids))
        if norm is None:
            w[ids] = 1.0 / len(ids)
        else:
            # norm[i] belongs to client_ids[i] — pair in the CALLER's order
            # (lane c ≡ client c regardless of how the subset was listed)
            for cid, wi in zip(client_ids, norm):
                w[cid] = wi
        return w, mask

    # ------------------------------------------------------------------
    def close(self, params: Params, stacks: Dict[str, jnp.ndarray],
              client_ids: Sequence[int],
              weights: Optional[Sequence[float]] = None, *, round_id=None
              ) -> Tuple[Params, Params, DeferredDivergence]:
        """Close a mesh round over the sampled subset.

        ``stacks`` is the flattened client-stacked adapter tree (path →
        ``(C_max, …)``, e.g. a round_fn output through
        :func:`flatten_with_paths`). Returns ``(global_lora, new_params,
        divergence)`` exactly like the streaming engine's close, with the
        divergence deferred."""
        w, mask = self.weight_vector(client_ids, weights)
        w0_leaves = collect_w0_leaves(self.specs, params)
        rec = self.rec
        if rec.enabled:
            import time as _time
            before = self._close._cache_size()
            t0 = _time.perf_counter_ns()
            with rec.span("close.dispatch", cat="engine", round=round_id,
                          method=self.method, mesh=True):
                new_w0, glob, div = self._close(
                    w0_leaves, stacks, jnp.asarray(w), jnp.asarray(mask),
                    uniform=False)
            dispatch_us = (_time.perf_counter_ns() - t0) / 1e3
            compiled = self._close._cache_size() > before
            sig = f"mesh:{self.method}"
            rec.counter(
                f"engine.compile_{'miss' if compiled else 'hit'}[{sig}]").inc()
            rec.hist("engine.close_dispatch_us").observe(dispatch_us)
            if round_id is not None:
                rec.round_set(round_id, method=self.method,
                              close_dispatch_us=round(dispatch_us, 1),
                              compile_miss=int(compiled))
        else:
            new_w0, glob, div = self._close(w0_leaves, stacks, jnp.asarray(w),
                                            jnp.asarray(mask), uniform=False)
        new_params = fold_back_w0(self.specs, params, new_w0)
        flat = {}
        for s in self.specs:
            flat[s.key + "/a"] = glob[s.key]["a"]
            flat[s.key + "/b"] = glob[s.key]["b"]
        return (unflatten_from_paths(flat), new_params,
                DeferredDivergence(div, round_id,
                                   recorder=rec if rec.enabled else None))


# --------------------------------------------------------------------------
# the mesh-mode federated loop
# --------------------------------------------------------------------------

@dataclass
class MeshFederatedTrainer:
    """Mesh-mode orchestration: every round is two pjit'd programs.

    The loop mirrors core/federated.FederatedTrainer's record format but
    replaces host-side orchestration with the stacked programs above:
    sampling draws a per-round subset (seeded, like the fedsrv registry),
    ALL lanes run the local-training program from the broadcast global
    adapter, and the masked weighted close folds the exact residual server-
    side. Divergence handles resolve at round boundaries (overlap-aware).
    """

    model: Any
    lora_cfg: LoRAConfig
    fed_cfg: FedConfig
    train_cfg: TrainConfig
    client_loaders: List[Any]
    eval_batches: List[Dict] = field(default_factory=list)
    seed: int = 0
    mesh: Any = None
    # obs recorder (repro.obs). None → built from fed_cfg.obs.
    recorder: Any = None

    def __post_init__(self):
        from repro.launch.mesh import make_client_mesh

        if self.recorder is None:
            from repro.obs import make_recorder
            self.recorder = make_recorder(self.fed_cfg.obs)

        fc = self.fed_cfg
        if fc.method not in MESH_METHODS:
            raise ValueError(f"--mode mesh supports {MESH_METHODS}, "
                             f"got {fc.method!r}")
        rng = jax.random.key(self.seed)
        rp, rl = jax.random.split(rng)
        if self.mesh is None:
            self.mesh = make_client_mesh(fc.num_clients)
        # commit base params REPLICATED on the mesh up front: the close's
        # updated W0 leaves come back committed, and matching shardings on
        # round 0 keep every round on the same compiled program (the
        # one-program-per-signature contract)
        from jax.sharding import PartitionSpec as P
        self.params = jax.device_put(
            self.model.init(rp),
            NamedSharding(self.mesh, P()))
        self.global_lora = init_lora(rl, self.params, self.model.cfg,
                                     self.lora_cfg)
        if not self.global_lora:
            raise ValueError("no LoRA targets matched — check target_modules")
        self.scale = self.lora_cfg.scale
        method = fc.method
        svd_rank = fc.svd_rank
        if method == "fedex_svd" and not svd_rank:
            method = "fedex"  # svd_rank=0 means exact (config contract)
        self.closer = MeshRoundCloser(
            self.mesh, self.params, self.global_lora,
            c_max=fc.num_clients, scale=self.scale, method=method,
            svd_rank=svd_rank, recorder=self.recorder)
        # uneven per-lane step budgets compile the masked-scan variant;
        # the default budget-free path keeps its bitwise-unchanged program
        self._budgets = (jnp.asarray(fc.client_local_steps, jnp.int32)
                         if fc.client_local_steps else None)
        self.round_fn = make_mesh_round_fn(self.model, self.scale,
                                           self.train_cfg,
                                           masked=self._budgets is not None)
        self.eval_fn = make_eval_fn(self.model, self.scale)
        self.history: List[RoundRecord] = []
        self._total_steps = fc.rounds * fc.local_steps
        self._examples = [len(l.sequences) for l in self.client_loaders]
        # mesh fault injection (fedsrv/faults.py): only the adapter-VALUE
        # kinds apply — co-scheduled lanes cross no wire, so codec and
        # addressing faults have nothing to corrupt. Faulty lanes are
        # quarantined by DROPPING their ids from the close's subset: the
        # weight vector masks them to exact zero, same program, exact close
        # over the survivors.
        self.fault_injector = None
        if fc.faults:
            from repro.fedsrv.faults import (MESH_KINDS, FaultInjector,
                                             FaultPlan)
            plan = FaultPlan.parse(fc.faults, seed=fc.seed)
            self.fault_injector = FaultInjector(plan,
                                                recorder=self.recorder)
            skipped = sorted({s.kind for s in plan.specs
                              if s.kind not in MESH_KINDS})
            if skipped:
                logger.warning(
                    "mesh mode applies value faults %s only; plan kind(s) %s "
                    "need a wire/ring and are skipped", MESH_KINDS,
                    ", ".join(skipped))

    # ------------------------------------------------------------------
    def _sample_round(self, rnd: int) -> Tuple[List[int],
                                               Optional[List[float]]]:
        """Seeded participant subset + optional example-count weights."""
        fc = self.fed_cfg
        k = fc.num_clients
        n = max(1, int(round(fc.participation * k)))
        rng = np.random.default_rng((self.seed, rnd))
        ids = sorted(rng.choice(k, size=n, replace=False).tolist())
        weights = None
        if fc.weighting == "examples":
            weights = [float(self._examples[c % len(self._examples)])
                       for c in ids]
        return ids, weights

    def _stack_batches(self, steps: int) -> Dict[str, jnp.ndarray]:
        """(C_max, steps, B, …) batch stacks, lane c fed by loader c."""
        per_lane = []
        for c in range(self.fed_cfg.num_clients):
            loader = self.client_loaders[c % len(self.client_loaders)]
            per_lane.append([loader.next_batch() for _ in range(steps)])
        return jax.tree.map(
            lambda *leaves: jnp.stack(leaves),
            *[jax.tree.map(lambda *xs: jnp.stack(xs), *lane)
              for lane in per_lane])

    def _shard_client_tree(self, tree):
        """Place each (C_max, …) leaf's leading axis on the client mesh axis
        so the training program's lanes partition across the mesh."""
        return jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(self.mesh,
                                 client_stack_spec("", x, self.mesh))),
            tree)

    def _resolve_divergences(self) -> None:
        resolve_divergences(self.history)

    def _screen_lanes(self, rnd: int, stacks: Dict[str, Any],
                      ids: List[int], weights: Optional[List[float]]):
        """Apply the round's mesh value faults, then quarantine bad lanes.

        A lane fails the screen when any leaf is non-finite or (with
        ``uplink_max_norm`` set) its ∞-norm exceeds the limit; failing ids
        are dropped from the subset so the close's weight vector masks them
        to exact zero. Returns (stacks', survivors, weights', quarantined)."""
        fc = self.fed_cfg
        host = {p: np.array(x) for p, x in stacks.items()}
        survivors: List[int] = []
        surv_w: List[float] = []
        quarantined: List[Tuple[int, str]] = []
        for j, cid in enumerate(ids):
            lane = {p: host[p][cid] for p in host}
            lane2, applied = self.fault_injector.corrupt_lane(rnd, cid, lane)
            if applied:
                for p in host:
                    host[p][cid] = lane2[p]
            bad = ""
            for p in host:
                if not np.isfinite(host[p][cid]).all():
                    bad = "nonfinite"
                    break
                if (fc.uplink_max_norm > 0
                        and np.abs(host[p][cid]).max() > fc.uplink_max_norm):
                    bad = "norm"
                    break
            if bad:
                # zero the lane, don't just mask it: 0·NaN = NaN, so a
                # poisoned lane must never reach the close's weighted sums
                # (mirrors the streaming sink, where a quarantined uplink
                # never writes its lane)
                for p in host:
                    host[p][cid] = 0
                quarantined.append((cid, bad))
                if self.recorder.enabled:
                    self.recorder.counter(f"uplink.quarantined[{bad}]").inc()
                self.recorder.event("uplink.quarantine", cat="fedsrv",
                                    round=rnd, client=cid, reason=bad)
            else:
                survivors.append(cid)
                if weights is not None:
                    surv_w.append(weights[j])
        return (host, survivors,
                surv_w if weights is not None else None, quarantined)

    # ------------------------------------------------------------------
    def run(self) -> List[RoundRecord]:
        fc = self.fed_cfg
        c = fc.num_clients
        step0 = 0
        for rnd in range(fc.rounds):
            lrs = jnp.asarray([
                lr_at(step0 + s, base_lr=self.train_cfg.learning_rate,
                      total_steps=self._total_steps,
                      warmup_ratio=self.train_cfg.warmup_ratio,
                      kind=self.train_cfg.schedule)
                for s in range(fc.local_steps)], jnp.float32)
            ids, weights = self._sample_round(rnd)
            n_sampled = len(ids)

            # downlink broadcast: every lane starts from the global adapter
            lora_stack = self._shard_client_tree(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (c,) + x.shape),
                self.global_lora))
            batches = self._shard_client_tree(
                self._stack_batches(fc.local_steps))
            with self.recorder.span("mesh.train_round", cat="trainer",
                                    round=rnd, lanes=c):
                if self._budgets is not None:
                    new_stack, losses = self.round_fn(
                        self.params, lora_stack, batches, lrs, self._budgets)
                else:
                    new_stack, losses = self.round_fn(self.params, lora_stack,
                                                      batches, lrs)
            # round boundary: the PREVIOUS close's divergence resolves only
            # after this round's training program has been dispatched, so
            # the in-flight close overlaps lane compute (mesh-mode twin of
            # the host trainer's resolve-after-uplinks ordering)
            self._resolve_divergences()

            stacks_flat = dict(flatten_with_paths(new_stack))
            quarantined: List[Tuple[int, str]] = []
            if self.fault_injector is not None:
                stacks_flat, ids, weights, quarantined = self._screen_lanes(
                    rnd, stacks_flat, ids, weights)
            if not ids:
                # every sampled lane quarantined: degraded round — the
                # global adapter and base params carry forward unchanged
                div: Any = 0.0
                if self.recorder.enabled:
                    self.recorder.counter("round.degraded").inc()
                self.recorder.event("round.degraded", cat="fedsrv",
                                    round=rnd, delivered=0,
                                    quarantined=len(quarantined))
                logger.warning("round=%d DEGRADED: every lane quarantined; "
                               "global carried forward", rnd)
            else:
                stacks = self.closer.shard_stacks(stacks_flat)
                with self.recorder.span("round.close", cat="trainer",
                                        round=rnd, mesh=True):
                    self.global_lora, self.params, div = self.closer.close(
                        self.params, stacks, ids, weights, round_id=rnd)

            step0 += fc.local_steps
            with self.recorder.span("round.eval", cat="trainer", round=rnd,
                                    batches=len(self.eval_batches)):
                ev_loss, ev_acc = self._evaluate()
            if self.recorder.enabled:
                self.recorder.round_set(rnd, sampled=n_sampled,
                                        delivered=len(ids),
                                        quarantined=len(quarantined),
                                        degraded=int(not ids),
                                        eval_loss=round(ev_loss, 6),
                                        eval_acc=round(ev_acc, 6))
            if self.recorder.enabled and self.fault_injector is not None:
                finite = all(
                    bool(np.isfinite(np.asarray(x, np.float32)).all())
                    for x in jax.tree.leaves(self.global_lora))
                self.recorder.round_set(rnd, global_finite=int(finite))
            lane_losses = np.asarray(losses)[:, -1]
            rec = RoundRecord(
                round=rnd, client_losses=([float(lane_losses[i]) for i in ids]
                                          or [float("nan")]),
                eval_loss=ev_loss, eval_acc=ev_acc, divergence_scaled=div,
                lr=float(lrs[0]))
            self.history.append(rec)
            logger.info(
                "round=%d mode=mesh sampled=%d/%d delivered=%d "
                "quarantined=%d eval_loss=%.4f eval_acc=%.4f div=deferred "
                "programs=%d", rnd, n_sampled, c, len(ids), len(quarantined),
                ev_loss, ev_acc, self.closer.compiled_programs)
        self._resolve_divergences()
        return self.history

    def _evaluate(self) -> Tuple[float, float]:
        return evaluate_on_batches(self.eval_fn, self.params,
                                   self.global_lora, self.eval_batches)
