"""Roofline constants + MODEL_FLOPS yardsticks (assignment §Roofline).

Three terms per (arch × shape × mesh), seconds-per-step on TPU v5e:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

FLOPs / bytes / collective bytes come from the LOOP-AWARE analyzer in
launch/hlo_analysis.py (XLA's cost_analysis counts scan bodies once).
This module keeps the hardware constants and the analytic MODEL_FLOPS
yardstick (6·N_active·D train / 2·N_active·D inference) used for the
"useful FLOPs" ratio.
"""

from __future__ import annotations

from typing import Dict

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def model_flops_per_step(cfg, shape, lora_rank: int = 0) -> float:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference —
    the 'useful FLOPs' yardstick for the HLO_FLOPs ratio."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Parameter count touched per token (MoE: top-k experts only)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    v = cfg.vocab_size
    emb = v * d

    if cfg.family == "ssm":  # xlstm
        d_inner = cfg.ssm_expand * d
        per_mlstm = 2 * d * d_inner + 3 * d_inner * d_inner + d_inner * d + 2 * d_inner * cfg.num_heads
        per_slstm = 4 * d * d + int(d * 4 / 3) * d * 3
        period = cfg.slstm_every
        nper = cfg.num_layers // period
        return emb + nper * ((period - 1) * per_mlstm + per_slstm)

    def attn_params():
        if cfg.mla:
            qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            return (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * qk
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                    + cfg.kv_lora_rank * cfg.num_heads
                    * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                    + cfg.num_heads * cfg.v_head_dim * d)
        return (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
                + cfg.num_heads * hd * d)

    def mlp_params(ff):
        gated = cfg.act == "silu"
        return (3 if gated else 2) * d * ff

    if cfg.family == "moe":
        ff = cfg.moe_d_ff or cfg.d_ff
        active_ff = ff * (cfg.num_experts_per_tok + cfg.num_shared_experts)
        n_moe = cfg.num_layers - cfg.first_k_dense
        total = emb + n_moe * (attn_params() + mlp_params(active_ff) + d * cfg.num_experts)
        total += cfg.first_k_dense * (attn_params() + mlp_params(cfg.dense_d_ff or cfg.d_ff))
        return total

    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * d
        n = cfg.ssm_state
        per_mamba = d * (2 * d_inner + 2 * n + d_inner // cfg.ssm_head_dim) + d_inner * d
        napp = cfg.num_layers // cfg.attn_every
        return emb + cfg.num_layers * per_mamba + napp * (attn_params() + mlp_params(cfg.d_ff))

    if cfg.family == "encdec":
        per_enc = attn_params() + mlp_params(cfg.d_ff)
        per_dec = 2 * attn_params() + mlp_params(cfg.d_ff)
        return emb + cfg.enc_layers * per_enc + cfg.num_layers * per_dec

    # dense / vlm
    return emb + cfg.num_layers * (attn_params() + mlp_params(cfg.d_ff))
