"""Batched serving driver: prefill + greedy decode with the KV/state caches.

Serves a (optionally LoRA-adapted, FedEx-aggregated) model: the federated
artifact of train.py can be merged (core.merge_lora) or applied as adapters at
request time. ``--pull-from URL`` fetches the CURRENT merged global adapter
from a running federation server (``train.py --mode serve``) via
``FedClient.pull_latest`` — the served generation then runs on what the
federation actually aggregated (arch/rank must match the server's). CPU
demo:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b-smoke --steps 8
"""

from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LoRAConfig, get_config
from repro.core import init_lora
from repro.data import make_batch_for
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model
from repro.util.logging import get_logger

logger = get_logger("serve")


def serve(arch: str, *, batch_size: int = 2, prompt_len: int = 32,
          steps: int = 8, max_len: int = 128, rank: int = 4,
          use_lora: bool = True, seed: int = 0, pull_from: str = ""):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    lora_cfg = LoRAConfig(rank=rank)
    if pull_from:
        from repro.fedsrv.client import FedClient
        pulled = FedClient(pull_from, client_id=-1).pull_latest()
        lora = jax.tree_util.tree_map(jnp.asarray, pulled.lora)
        logger.info("pulled global adapter v%d from %s (W0 digest %s…)",
                    pulled.version, pull_from, pulled.w0_digest[:12])
    else:
        lora = init_lora(jax.random.key(seed + 1), params, cfg, lora_cfg) \
            if use_lora else None

    batch = make_batch_for(cfg, batch_size, prompt_len, seed=seed)
    cache = model.init_cache(batch_size, max_len)

    prefill = jax.jit(make_prefill_step(model, lora_cfg))
    decode = jax.jit(make_decode_step(model, lora_cfg))

    t0 = time.time()
    logits, cache = prefill(params, lora, batch, cache)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    pos0 = prompt_len + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    generated = [next_tok]
    t0 = time.time()
    for i in range(steps):
        next_tok, logits, cache = decode(params, lora, next_tok, cache,
                                         jnp.asarray(pos0 + i, jnp.int32))
        generated.append(next_tok)
    tokens = jnp.concatenate(generated, axis=1)
    t_decode = time.time() - t0
    logger.info("arch=%s prefill=%.3fs decode=%.3fs (%.1f ms/token)",
                arch, t_prefill, t_decode, 1000 * t_decode / max(steps, 1))
    return np.asarray(tokens)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b-smoke")
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--no-lora", action="store_true")
    ap.add_argument("--pull-from", default="",
                    help="federation server URL — serve the merged global "
                         "adapter from GET /v1/adapters/latest (arch/rank "
                         "must match the server's)")
    args = ap.parse_args()
    toks = serve(args.arch, batch_size=args.batch_size, prompt_len=args.prompt_len,
                 steps=args.steps, max_len=args.max_len, rank=args.rank,
                 use_lora=not args.no_lora, pull_from=args.pull_from)
    print("generated token ids:\n", toks)


if __name__ == "__main__":
    main()
