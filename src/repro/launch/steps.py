"""Step builders shared by the dry-run, trainer and server: jit-able
``train_step`` / ``prefill_step`` / ``decode_step`` closures plus the
``input_specs`` ShapeDtypeStruct factory for every (arch × input shape).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LoRAConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.optim import adamw_update, clip_by_global_norm, init_adamw, lr_at


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    Decode shapes describe ONE decode step: tokens (B, 1) + scalar position
    (the KV cache spec is built separately from ``model.init_cache``).
    """
    gb, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    if shape.is_decode:
        batch = {"tokens": sd((gb, 1), i32)}
        if cfg.family == "encdec":
            pass  # cross-KV lives in the cache
        return batch

    text_len = s
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        text_len = s - cfg.vision_tokens
        batch["vision_embeds"] = sd((gb, cfg.vision_tokens, cfg.d_model), f32)
    if cfg.family == "encdec":
        batch["frames"] = sd((gb, cfg.enc_seq_len, cfg.d_model), f32)
    batch["tokens"] = sd((gb, text_len), i32)
    if shape.kind == "train":
        batch["targets"] = sd((gb, text_len), i32)
        batch["loss_mask"] = sd((gb, text_len), f32)
    return batch


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def make_train_step(model, lora_cfg: LoRAConfig, train_cfg: TrainConfig,
                    num_microbatches: int = 1) -> Callable:
    """LoRA fine-tuning step: grads w.r.t. adapters only; W0 frozen.

    With ``num_microbatches > 1`` the global batch is split and gradients
    accumulate through a ``lax.scan`` — the activation-memory lever for
    train_4k at global batch 256 (DESIGN §5).
    """
    scale = lora_cfg.scale

    def loss_fn(lora, params, batch):
        loss, metrics = model.loss(params, batch, lora=lora, lora_scale=scale)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, lora, opt_state, batch, step):
        if num_microbatches > 1:
            def split(x):
                return x.reshape(num_microbatches, x.shape[0] // num_microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = grad_fn(lora, params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), lora)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
        else:
            (loss, _), grads = grad_fn(lora, params, batch)

        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        lr = lr_at(step, base_lr=train_cfg.learning_rate,
                   total_steps=train_cfg.total_steps,
                   warmup_ratio=train_cfg.warmup_ratio, kind=train_cfg.schedule)
        lora, opt_state = adamw_update(
            grads, opt_state, lora, learning_rate=lr,
            beta1=train_cfg.beta1, beta2=train_cfg.beta2, eps=train_cfg.eps,
            weight_decay=train_cfg.weight_decay)
        return lora, opt_state, loss, gnorm

    return train_step


def make_prefill_step(model, lora_cfg: LoRAConfig) -> Callable:
    scale = lora_cfg.scale

    def prefill_step(params, lora, batch, cache):
        logits, cache = model.prefill(params, batch, cache, lora=lora,
                                      lora_scale=scale)
        # serving returns only the last-position logits
        return logits[:, -1:], cache

    return prefill_step


def make_decode_step(model, lora_cfg: LoRAConfig) -> Callable:
    scale = lora_cfg.scale

    def decode_step(params, lora, tokens, cache, position):
        logits, cache = model.decode_step(params, tokens, cache, position,
                                          lora=lora, lora_scale=scale)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache

    return decode_step


def abstract_state(model, cfg: ModelConfig, lora_cfg: LoRAConfig
                   ) -> Tuple[Any, Any, Any]:
    """(params, lora, opt_state) as ShapeDtypeStructs — no allocation."""
    from repro.core.lora import init_lora

    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    lora = jax.eval_shape(
        lambda p: init_lora(jax.random.key(0), p, cfg, lora_cfg), params)
    opt_state = jax.eval_shape(init_adamw, lora)
    return params, lora, opt_state


def abstract_cache(model, batch_size: int, cache_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch_size, cache_len))
