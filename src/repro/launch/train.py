"""Federated fine-tuning launcher.

Two execution modes sharing the SAME aggregation math (core/aggregation.py):

* ``--mode host`` (default): the paper's cross-silo simulation — clients run
  sequentially on the local device(s); aggregation is host-side tree
  arithmetic (optionally through the Pallas fedex_residual kernel).
* ``--mode mesh`` (launch/mesh_train.py): datacenter co-scheduled clients —
  client adapters are STACKED on a leading axis sharded over a ``client``
  mesh axis and every client trains in the same pjit'd program; the FedEx
  close is a masked WEIGHTED psum-mean over the client axis + the exact
  residual fold, expressed with jnp ops inside jit so XLA lowers it to
  collectives over the mesh. Partial participation (``--participation``),
  non-uniform weights (``--weighting examples``) and full rounds all reuse
  ONE compiled close program — sampling only changes the weight vector
  (zero-weight lanes are masked), never the program. The divergence comes
  back as a deferred device scalar, resolved at round boundaries.

Example (CPU, tiny model):
  PYTHONPATH=src python -m repro.launch.train --arch paper-tiny --method fedex \
      --clients 3 --rounds 3 --local-steps 5 --vocab 64
  PYTHONPATH=src python -m repro.launch.train --mode mesh --participation 0.5 \
      --clients 4 --rounds 2 --local-steps 3 --vocab 32
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import replace
from typing import List

import numpy as np

from repro.configs import (FedConfig, LoRAConfig, TrainConfig, get_config,
                           validate_fed_lora)
from repro.core import FederatedTrainer
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.models import build_model
from repro.util.logging import MetricLogger, get_logger

logger = get_logger("train")


def build_federated_data(vocab: int, num_clients: int, *, seqs_per_task: int = 120,
                         seq_len: int = 64, alpha: float = 0.5, seed: int = 0,
                         batch_size: int = 8):
    ds = SyntheticLM(vocab=vocab, num_tasks=num_clients, seed=seed)
    seqs, labels = [], []
    for t in range(num_clients):
        s = ds.sample(task=t, num_sequences=seqs_per_task, seq_len=seq_len, seed=seed + t)
        seqs.append(s)
        labels += [t] * seqs_per_task
    seqs = np.concatenate(seqs)
    labels = np.array(labels)
    parts = dirichlet_partition(labels, num_clients, alpha=alpha, seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=batch_size, seed=seed + i)
               for i, p in enumerate(parts)]
    eval_batches = [ds.to_batch(ds.sample(task=t, num_sequences=16, seq_len=seq_len,
                                          seed=seed + 1000 + t))
                    for t in range(num_clients)]
    return loaders, eval_batches


def _run_serve(args, model, lora_cfg, fed_cfg) -> None:
    """--mode serve: boot the HTTP federation service and block until all
    rounds close (or Ctrl-C). Training happens in the CLIENT processes —
    this process only ingests deltas, closes rounds and serves the merged
    global adapter (scripts/loadgen.py is the benchmark driver)."""
    import time

    from repro.configs.base import ServeConfig
    from repro.fedsrv.server import (FederationServer, init_global_state,
                                     start_http_server)

    serve_cfg = ServeConfig(host=args.host, port=args.port,
                            max_concurrent=args.max_concurrent,
                            quota_per_round=args.quota,
                            token=args.serve_token)
    params, global_lora = init_global_state(model, lora_cfg, seed=args.seed)
    fed = FederationServer(params, global_lora, scale=lora_cfg.scale,
                           fed_cfg=fed_cfg, serve_cfg=serve_cfg)
    httpd = start_http_server(fed, host=serve_cfg.host, port=serve_cfg.port)
    host, port = httpd.server_address[:2]
    # machine-readable readiness line (loadgen --spawn waits for it)
    print(f"SERVING http://{host}:{port}", flush=True)
    try:
        while not fed.done:
            time.sleep(0.05)
            fed.tick()  # deadline-expiry closes need no inbound POST
        # drain window: the benchmark/clients still need the final
        # pull_latest + metrics after the last close
        logger.info("all %d rounds closed — lingering %.1fs for pulls",
                    fed.version, args.linger)
        time.sleep(args.linger)
    except KeyboardInterrupt:
        logger.info("interrupted — shutting down after %d close(s)",
                    fed.version)
    httpd.shutdown()
    fed.finalize()  # resolve the last divergence before metrics flush
    rec = fed.rec
    if rec.enabled:
        for line in rec.summary_lines():
            logger.info("%s", line)
        if args.trace:
            rec.write_trace(args.trace)
            logger.info("trace → %s", args.trace)
        if args.metrics_out:
            rec.write_metrics(args.metrics_out)
            logger.info("metrics JSONL → %s", args.metrics_out)
    if fed.ledger.entries:
        print("comm ledger (measured over HTTP):")
        for line in fed.ledger.summary_lines():
            print("  " + line)
    print(f"\nserved {fed.version}/{fed_cfg.rounds} round close(s) "
          f"(C={fed_cfg.num_clients}, method={fed_cfg.method})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="host", choices=("host", "mesh", "serve"),
                    help="host = paper's cross-silo simulation (fedsrv "
                         "coordinator); mesh = co-scheduled clients, one "
                         "pjit'd program per round phase (mesh_train.py); "
                         "serve = HTTP federation service (fedsrv/server.py) "
                         "— clients POST deltas over the wire, --deadline "
                         "means WALL seconds")
    ap.add_argument("--arch", default="paper-tiny")
    ap.add_argument("--method", default="fedex",
                    choices=("fedex", "fedit", "ffa", "fedex_svd", "hetero",
                             "centralized"))
    ap.add_argument("--assignment", default="average",
                    choices=("average", "keep_local", "reinit"))
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=8.0, help="LoRA alpha")
    ap.add_argument("--svd-rank", type=int, default=0)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (small = faster CPU demo)")
    ap.add_argument("--dirichlet-alpha", type=float, default=0.5)
    ap.add_argument("--include-mlp", action="store_true")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="L2 clip on uploaded adapter deltas (0 = off)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="Gaussian noise multiplier (σ = mult · clip)")
    ap.add_argument("--client-ranks", default="",
                    help="comma-separated per-client ranks, e.g. 2,4,8 — "
                         "non-empty (or --method hetero) runs the ragged-rank "
                         "engine close; adapters pad to --rank = r_max at "
                         "ingest and each lane masks back to its true rank")
    ap.add_argument("--client-local-steps", default="",
                    help="comma-separated per-client local step budgets "
                         "(mesh mode masks scan iterations past a client's "
                         "budget; empty = every client runs --local-steps)")
    # fedsrv coordinator (partial participation / stragglers / async buffer):
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per round (fedsrv)")
    ap.add_argument("--min-quorum", type=int, default=0,
                    help="deliveries needed to close at the deadline (0 = all)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="round deadline in sim-seconds (0 = wait for all)")
    ap.add_argument("--weighting", default="uniform",
                    choices=("uniform", "examples"),
                    help="client weights: uniform or example counts n_i/Σn_j")
    ap.add_argument("--stragglers", type=float, default=0.0,
                    help="straggler probability per (round, client); latency "
                         "is inflated ×5 for stragglers")
    ap.add_argument("--dropout-prob", type=float, default=0.0,
                    help="P(client accepts the round but never reports back)")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help=">0 → FedBuff-style buffered commits of this size")
    ap.add_argument("--quantize-uplink", default="none",
                    choices=("none", "fp16", "int8"),
                    help="uplink adapter codec (fedsrv transport)")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "jnp", "pallas", "off"),
                    help="fused round-close engine (core/engine.py) for "
                         "fedex/fedex_svd/keep_local/reinit closes: auto "
                         "picks Pallas kernels on TPU / jitted jnp twin on "
                         "CPU; off = legacy eager list-of-trees close")
    ap.add_argument("--ring-depth", type=int, default=2,
                    help="RoundBuffers ring depth: rounds whose uplink "
                         "stacks may be in flight at once (2 = double "
                         "buffering; >2 pipelines FedBuff commits deeper, "
                         "with deadline eviction of lagging rounds)")
    ap.add_argument("--close-chunk", type=int, default=0,
                    help="streaming chunked round closes: uplinks accumulate "
                         "in N-client chunks that fold eagerly as they fill, "
                         "so peak close memory is O(chunk) instead of O(C) "
                         "(0 = classic stacked close; rounds that fit in one "
                         "chunk always take the stacked close)")
    # fault injection + defended uplink (fedsrv/faults.py):
    ap.add_argument("--faults", default="",
                    help="seeded fault plan DSL, e.g. "
                         "'nan@0.2;truncate@1(clients=2,rounds=0+1)' — "
                         "corrupts uplinks between encode and delivery; the "
                         "validation stage quarantines them (close stays "
                         "exact over the survivors)")
    ap.add_argument("--no-uplink-validation", action="store_true",
                    help="disable the defended ingest path (finite/shape/"
                         "spec checks on every decoded uplink)")
    ap.add_argument("--uplink-max-norm", type=float, default=0.0,
                    help="quarantine uplinks whose ∞-norm exceeds this "
                         "(byzantine-scale rejection; 0 = off)")
    ap.add_argument("--uplink-retries", type=int, default=2,
                    help="bounded retries for transient decode failures")
    # crash-safe round state (checkpoint/):
    ap.add_argument("--checkpoint-dir", default="",
                    help="snapshot coordinator+ring+ledger round state here "
                         "at round boundaries ('' = off)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="snapshot every N round boundaries")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-dir's round_state.npz "
                         "(bitwise continuation of the interrupted run)")
    # HTTP federation service (--mode serve; fedsrv/server.py):
    ap.add_argument("--host", default="127.0.0.1",
                    help="serve mode: bind address")
    ap.add_argument("--port", type=int, default=8077,
                    help="serve mode: bind port (0 = ephemeral, reported at "
                         "startup)")
    ap.add_argument("--serve-token", default="",
                    help="serve mode: shared bearer token ('' = auth off)")
    ap.add_argument("--max-concurrent", type=int, default=16,
                    help="serve mode: concurrent uplink decodes admitted "
                         "before POSTs bounce with 429 (backpressure)")
    ap.add_argument("--quota", type=int, default=4,
                    help="serve mode: POSTs allowed per (client, round) "
                         "before 429 (quota)")
    ap.add_argument("--linger", type=float, default=15.0,
                    help="serve mode: keep serving GETs (pull_latest / "
                         "metrics) this many seconds after the last round "
                         "closes, so clients can fetch the final artifact")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--out", default="", help="write round history JSON here")
    # observability (repro.obs):
    ap.add_argument("--obs", default="", choices=("", "off", "basic", "trace"),
                    help="observability mode (default off; --trace/"
                         "--metrics-out imply trace/basic)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON here (Perfetto-"
                         "loadable); implies --obs trace")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics/round-record JSONL stream here; "
                         "implies --obs basic (scripts/obs_report.py reads "
                         "this)")
    args = ap.parse_args()

    obs_mode = args.obs or ("trace" if args.trace
                            else ("basic" if args.metrics_out else "off"))
    if args.trace and obs_mode != "trace":
        ap.error(f"--trace requires --obs trace (got --obs {obs_mode})")

    lora_cfg = LoRAConfig(rank=args.rank, alpha=args.alpha,
                          include_mlp=args.include_mlp)
    fed_cfg = FedConfig(num_clients=args.clients, rounds=args.rounds,
                        local_steps=args.local_steps, method=args.method,
                        svd_rank=args.svd_rank, assignment=args.assignment,
                        dirichlet_alpha=args.dirichlet_alpha, seed=args.seed,
                        dp_clip=args.dp_clip,
                        dp_noise_multiplier=args.dp_noise,
                        client_ranks=tuple(
                            int(r) for r in args.client_ranks.split(",")
                            if r.strip()),
                        client_local_steps=tuple(
                            int(s) for s in args.client_local_steps.split(",")
                            if s.strip()),
                        participation=args.participation,
                        min_quorum=args.min_quorum,
                        round_deadline=args.deadline,
                        weighting=args.weighting,
                        straggler_prob=args.stragglers,
                        dropout_prob=args.dropout_prob,
                        async_buffer=args.async_buffer,
                        quantize_uplink=args.quantize_uplink,
                        engine=args.engine,
                        ring_depth=args.ring_depth,
                        close_chunk=args.close_chunk,
                        obs=obs_mode,
                        faults=args.faults,
                        uplink_validation=not args.no_uplink_validation,
                        uplink_max_norm=args.uplink_max_norm,
                        uplink_retries=args.uplink_retries,
                        checkpoint_dir=args.checkpoint_dir,
                        checkpoint_every=args.checkpoint_every)
    # fail before any model build: svd_rank beyond the k·r residual bound
    validate_fed_lora(fed_cfg, lora_cfg)
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")

    cfg = get_config(args.arch)
    if args.vocab:
        cfg = replace(cfg, vocab_size=args.vocab)
    cfg = replace(cfg, dtype=args.dtype)
    model = build_model(cfg)

    if args.mode == "serve":
        _run_serve(args, model, lora_cfg, fed_cfg)
        return

    loaders, eval_batches = build_federated_data(
        cfg.vocab_size, args.clients, seq_len=args.seq_len,
        alpha=args.dirichlet_alpha, seed=args.seed, batch_size=args.batch_size)

    train_cfg = TrainConfig(learning_rate=args.lr, schedule="constant",
                            total_steps=args.rounds * args.local_steps)
    if args.mode == "mesh":
        from repro.launch.mesh_train import MeshFederatedTrainer

        # mesh mode co-schedules every lane: the fedsrv orchestration knobs
        # (and host-side engine/ring tuning) have no effect there — warn so
        # a run is never attributed to a configuration that didn't happen
        _host_only = ("assignment", "stragglers", "dropout_prob", "deadline",
                      "min_quorum", "async_buffer", "quantize_uplink",
                      "dp_clip", "dp_noise", "client_ranks", "engine",
                      "ring_depth", "close_chunk", "uplink_retries",
                      "checkpoint_dir", "checkpoint_every", "resume")
        ignored = [f"--{k.replace('_', '-')}" for k in _host_only
                   if getattr(args, k) != ap.get_default(k)]
        if ignored:
            logger.warning(
                "--mode mesh ignores host-mode flag(s) %s — mesh rounds are "
                "co-scheduled (no stragglers/async/quantization/DP) and "
                "always close through the engine's weighted program",
                ", ".join(ignored))

        trainer = MeshFederatedTrainer(
            model=model, lora_cfg=lora_cfg, fed_cfg=fed_cfg,
            train_cfg=train_cfg, client_loaders=loaders,
            eval_batches=eval_batches, seed=args.seed)
        history = trainer.run()
        logger.info("mesh mode: %d round(s) closed through %d compiled close "
                    "program(s)", args.rounds,
                    trainer.closer.compiled_programs)
    else:
        trainer = FederatedTrainer(
            model=model,
            lora_cfg=lora_cfg,
            fed_cfg=fed_cfg,
            train_cfg=train_cfg,
            client_loaders=loaders,
            eval_batches=eval_batches,
            seed=args.seed,
        )
        if args.resume:
            from repro.checkpoint import round_state_path
            trainer.load_state(round_state_path(args.checkpoint_dir))
        history = trainer.run()
        if trainer.engine is not None:
            logger.info("round closes ran through the fused engine "
                        "(method=%s backend=%s)", trainer.engine.method,
                        trainer.engine.backend)
    final = history[-1]
    print(f"\nfinal: method={args.method} eval_loss={final.eval_loss:.4f} "
          f"eval_acc={final.eval_acc:.4f} divergence={final.divergence_scaled:.3e}")
    if args.mode == "host" and trainer.ledger.entries:
        print("comm ledger (measured, fedsrv transport):")
        for line in trainer.ledger.summary_lines():
            print("  " + line)
    rec = trainer.recorder
    if rec.enabled:
        for line in rec.summary_lines():
            logger.info("%s", line)
        if args.trace:
            rec.write_trace(args.trace)
            logger.info("trace → %s (load in Perfetto / chrome://tracing)",
                        args.trace)
        if args.metrics_out:
            rec.write_metrics(args.metrics_out)
            logger.info("metrics JSONL → %s (summarize with "
                        "scripts/obs_report.py)", args.metrics_out)
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.__dict__ for r in history], f, indent=2)


if __name__ == "__main__":
    main()
