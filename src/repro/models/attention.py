"""Attention: GQA/MHA with RoPE, QKV-bias, sliding-window, blockwise (flash-style)
training path, and ring-buffer / full KV-cache decode paths.

The training/prefill path is *blockwise*: a ``lax.scan`` over KV blocks with an
online-softmax carry — the jnp twin of kernels/flash_swa. Peak activation
memory is O(Sq · block) instead of O(Sq · Sk), which is what lets the 32k
prefill shapes fit the v5e HBM budget in the dry-run.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    apply_rope,
    dense,
    make_dense_params,
    maybe_lora,
)

NEG_INF = -1e30


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def make_attention_params(rng, cfg, *, cross: bool = False) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    dtype = jnp.dtype(cfg.dtype)
    bias = cfg.qkv_bias
    return {
        "q_proj": make_dense_params(ks[0], d, h * hd, dtype, bias=bias),
        "k_proj": make_dense_params(ks[1], d, kv * hd, dtype, bias=bias),
        "v_proj": make_dense_params(ks[2], d, kv * hd, dtype, bias=bias),
        "o_proj": make_dense_params(ks[3], h * hd, d, dtype),
    }


# --------------------------------------------------------------------------
# blockwise core (training / prefill)
# --------------------------------------------------------------------------

def blockwise_attention(
    q: jnp.ndarray,  # (B, Sq, H, Dk)
    k: jnp.ndarray,  # (B, Sk, KV, Dk)
    v: jnp.ndarray,  # (B, Sk, KV, Dv)
    *,
    causal: bool = True,
    window: int = 0,  # 0 → unbounded
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    block_size: int = 1024,
) -> jnp.ndarray:
    """Flash-style attention with online softmax over KV blocks."""
    b, sq, h, dk = q.shape
    _, sk, kvh, dv = v.shape

    # §Perf: GSPMD cannot shard the (kvh, group) split when kvh < model-axis
    # size — it replicates the whole attention computation per model shard.
    # Repeat KV up to full heads (k/v are the SMALL tensors here) and pin the
    # flattened head axis to the model axis. No-op when unconfigured.
    from repro.sharding import act as _act
    if _act.enabled():
        ms = _act.model_size()
        if h % ms == 0 and kvh % ms != 0 and kvh < h:
            rep = h // kvh
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
            kvh = h
        q = _act.constrain(q, ("dp", None, "model", None))
        k = _act.constrain(k, ("dp", None, "model", None))
        v = _act.constrain(v, ("dp", None, "model", None))

    group = h // kvh
    scale = dk ** -0.5

    block_size = min(block_size, sk)
    pad = (-sk) % block_size
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblocks = (sk + pad) // block_size

    # (B, Sq, KV, G, Dk) so GQA never materialises repeated KV
    qg = q.reshape(b, sq, kvh, group, dk) * scale
    kb = k.reshape(b, nblocks, block_size, kvh, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblocks, block_size, kvh, dv).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        acc, m, l = carry
        blk_idx, kblk, vblk = inputs
        k_pos = blk_idx * block_size + jnp.arange(block_size)
        # scores: (B, KV, G, Sq, Bk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kblk, preferred_element_type=jnp.float32)
        mask = k_pos[None, :] <= q_pos[:, None] if causal else jnp.ones((sq, block_size), bool)
        if window:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        mask = mask & (k_pos < sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * correction[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kvh, group, sq, dv), jnp.float32)
    m0 = jnp.full((b, kvh, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, group, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(nblocks), kb, vb)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (B, KV, G, Sq, Dv) → (B, Sq, H, Dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(v.dtype)


# --------------------------------------------------------------------------
# flash attention with custom VJP (§Perf iteration 2)
#
# jax's AD through the online-softmax scan saves the per-block f32 probability
# tensors for the backward pass — ~2 TB of HBM traffic per train_4k step on
# granite-8b (measured; see EXPERIMENTS.md §Perf). The flash backward
# RECOMPUTES p from (q, k, v, lse) per block instead: residuals shrink to
# out + lse, and the attention boundary cotangent becomes bf16.
# --------------------------------------------------------------------------

def _flash_reshape(q, k, v):
    """Shared GQA/model-axis prep: returns (qg*scale, k, v, kvh, group)."""
    from repro.sharding import act as _act

    b, sq, h, dk = q.shape
    kvh = k.shape[2]
    if _act.enabled():
        ms = _act.model_size()
        if h % ms == 0 and kvh % ms != 0 and kvh < h:
            rep = h // kvh
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
            kvh = h
        q = _act.constrain(q, ("dp", None, "model", None))
        k = _act.constrain(k, ("dp", None, "model", None))
        v = _act.constrain(v, ("dp", None, "model", None))
    group = h // kvh
    scale = dk ** -0.5
    qg = q.reshape(b, sq, kvh, group, dk).astype(jnp.float32) * scale
    return qg, k, v, kvh, group


def _block_mask(sq, block_size, blk_idx, sk, q_offset, causal, window):
    q_pos = q_offset + jnp.arange(sq)
    k_pos = blk_idx * block_size + jnp.arange(block_size)
    mask = k_pos[None, :] <= q_pos[:, None] if causal else jnp.ones(
        (sq, block_size), bool)
    if window:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    return mask & (k_pos < sk)[None, :]


def _flash_fwd_impl(q, k, v, causal, window, q_offset, block_size):
    b, sq, h, dk = q.shape
    sk = k.shape[1]
    qg, k, v, kvh, group = _flash_reshape(q, k, v)
    dv_dim = v.shape[-1]
    bs = min(block_size, sk)
    pad = (-sk) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblocks = (sk + pad) // bs
    kb = k.reshape(b, nblocks, bs, kvh, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblocks, bs, kvh, dv_dim).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        acc, m, l = carry
        blk_idx, kblk, vblk = inputs
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kblk,
                       preferred_element_type=jnp.float32)
        mask = _block_mask(sq, bs, blk_idx, sk, q_offset, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kvh, group, sq, dv_dim), jnp.float32)
    m0 = jnp.full((b, kvh, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, group, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (jnp.arange(nblocks), kb, vb))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv_dim)
    lse = m + jnp.log(l)  # (b, kvh, group, sq)
    return out.astype(v.dtype), lse


def _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, q_offset, block_size):
    b, sq, h, dk = q.shape
    sk = k.shape[1]
    kvh_orig = k.shape[2]
    qg, k, v, kvh, group = _flash_reshape(q, k, v)
    dv_dim = v.shape[-1]
    bs = min(block_size, sk)
    pad = (-sk) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblocks = (sk + pad) // bs
    kb = k.reshape(b, nblocks, bs, kvh, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblocks, bs, kvh, dv_dim).transpose(1, 0, 2, 3, 4)

    og = out.reshape(b, sq, kvh, group, dv_dim).transpose(0, 2, 3, 1, 4)
    dog = dout.reshape(b, sq, kvh, group, dv_dim).transpose(0, 2, 3, 1, 4)
    delta = jnp.einsum("bkgqd,bkgqd->bkgq", og.astype(jnp.float32),
                       dog.astype(jnp.float32))  # (b,kvh,g,sq)

    def body(dq_acc, inputs):
        blk_idx, kblk, vblk = inputs
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kblk,
                       preferred_element_type=jnp.float32)
        mask = _block_mask(sq, bs, blk_idx, sk, q_offset, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # recomputed probabilities
        dvb = jnp.einsum("bkgqc,bkgqd->bckd", p.astype(dog.dtype), dog,
                         preferred_element_type=jnp.float32)
        dp = jnp.einsum("bkgqd,bckd->bkgqc", dog, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])  # (b,kvh,g,sq,c)
        dq_blk = jnp.einsum("bkgqc,bckd->bqkgd", ds.astype(kblk.dtype), kblk,
                            preferred_element_type=jnp.float32)
        dkb = jnp.einsum("bkgqc,bqkgd->bckd", ds.astype(qg.dtype), qg,
                         preferred_element_type=jnp.float32)
        return dq_acc + dq_blk, (dkb, dvb)

    dq0 = jnp.zeros((b, sq, kvh, group, dk), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nblocks), kb, vb))
    scale = dk ** -0.5
    dq = (dq * scale).reshape(b, sq, h, dk)
    dk_full = dks.transpose(1, 0, 2, 3, 4).reshape(b, nblocks * bs, kvh, dk)[:, :sk]
    dv_full = dvs.transpose(1, 0, 2, 3, 4).reshape(b, nblocks * bs, kvh, dv_dim)[:, :sk]
    if kvh != kvh_orig:  # GQA repeat in fwd → sum the repeats back
        rep = kvh // kvh_orig
        dk_full = dk_full.reshape(b, sk, kvh_orig, rep, dk).sum(axis=3)
        dv_full = dv_full.reshape(b, sk, kvh_orig, rep, dv_dim).sum(axis=3)
    return (dq.astype(q.dtype), dk_full.astype(q.dtype), dv_full.astype(q.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                    block_size=1024):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, block_size)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, block_size):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, block_size)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, block_size, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, causal, window, q_offset,
                           block_size)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------------
# KV cache
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Params:
    """``length`` is the buffer size: full seq for global attn, window for SWA.

    ``pos`` stores the absolute position held in each slot (-1 = empty) so the
    same code handles both full and ring-buffer caches.
    """
    return {
        "k": jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }


def cache_write(cache: Params, k_new: jnp.ndarray, v_new: jnp.ndarray,
                position: jnp.ndarray) -> Params:
    """Write one step (Sq=1) at ``position`` (scalar int32); ring if full."""
    length = cache["k"].shape[1]
    slot = position % length
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], position[None], slot, axis=0)
    return {"k": k, "v": v, "pos": pos}


def decode_attention(q: jnp.ndarray, cache: Params, position: jnp.ndarray,
                     window: int = 0) -> jnp.ndarray:
    """Single-query attention against a (possibly ring) cache.

    q: (B, 1, H, Dk). Returns (B, 1, H, Dv).
    """
    b, _, h, dk = q.shape
    kvh = cache["k"].shape[2]
    group = h // kvh
    scale = dk ** -0.5

    valid = (cache["pos"] >= 0) & (cache["pos"] <= position)
    if window:
        valid = valid & (cache["pos"] > position - window)

    qg = q.reshape(b, kvh, group, dk) * scale
    s = jnp.einsum("bkgd,bckd->bkgc", qg, cache["k"], preferred_element_type=jnp.float32)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(cache["v"].dtype), cache["v"],
                     preferred_element_type=jnp.float32)
    dv = cache["v"].shape[-1]
    return out.reshape(b, 1, h, dv).astype(cache["v"].dtype)


# --------------------------------------------------------------------------
# full attention block (projections + core), self- and cross-attention
# --------------------------------------------------------------------------

def attention_block(
    cfg,
    params: Params,
    x: jnp.ndarray,  # (B, Sq, d_model)
    *,
    lora: Optional[Params] = None,
    lora_scale: float = 0.0,
    positions: Optional[jnp.ndarray] = None,  # (Sq,) absolute positions
    causal: bool = True,
    window: int = 0,
    kv_x: Optional[jnp.ndarray] = None,  # cross-attention source
    cross: Optional[bool] = None,  # force cross-attn (decode reads cache, no kv_x)
    cache: Optional[Params] = None,
    decode_position: Optional[jnp.ndarray] = None,  # scalar → decode mode
    block_size: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Returns (output, updated_cache)."""
    b, sq, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads

    q = dense(x, params["q_proj"], maybe_lora(lora, "q_proj"), lora_scale)
    q = q.reshape(b, sq, h, hd)

    if positions is None:
        positions = jnp.arange(sq)

    is_decode = decode_position is not None
    if cross is None:
        cross = kv_x is not None

    if cross and cache is not None and is_decode:
        # cross-attn KV was precomputed at prefill; just read.
        k = v = None
    else:
        src = kv_x if cross else x
        k = dense(src, params["k_proj"], maybe_lora(lora, "k_proj"), lora_scale)
        v = dense(src, params["v_proj"], maybe_lora(lora, "v_proj"), lora_scale)
        sk = src.shape[1]
        k = k.reshape(b, sk, kvh, hd)
        v = v.reshape(b, sk, kvh, hd)

    if cfg.rope and not cross:
        q_positions = decode_position[None] if is_decode else positions
        q = apply_rope(q, q_positions, cfg.rope_theta)
        if k is not None:
            k_positions = decode_position[None] if is_decode else positions
            k = apply_rope(k, k_positions, cfg.rope_theta)

    new_cache = cache
    if is_decode:
        if cross:
            out = decode_attention(q, cache, jnp.array(2**30, jnp.int32), window=0)
        else:
            new_cache = cache_write(cache, k, v, decode_position)
            out = decode_attention(q, new_cache, decode_position, window=window)
    else:
        if cache is not None and not cross:
            # prefill: populate the cache buffer (left-aligned; ring caches get
            # the window-tail; prefill length must fit the buffer here).
            length = cache["k"].shape[1]
            kk, vv = k[:, -length:], v[:, -length:]
            ppos = positions[-length:]
            pad = length - kk.shape[1]
            if pad > 0:
                kk = jnp.pad(kk, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vv = jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0)))
                ppos = jnp.concatenate([ppos, jnp.full((pad,), -1, ppos.dtype)])
            new_cache = {"k": kk.astype(cache["k"].dtype), "v": vv.astype(cache["v"].dtype),
                         "pos": ppos.astype(jnp.int32)}
        elif cache is not None and cross:
            length = cache["k"].shape[1]
            new_cache = {"k": k[:, :length].astype(cache["k"].dtype),
                         "v": v[:, :length].astype(cache["v"].dtype),
                         "pos": jnp.arange(length, dtype=jnp.int32)}
        out = flash_attention(
            q, k, v,
            causal and not cross,
            window,
            0,
            block_size,
        )

    out = out.reshape(b, sq, h * hd).astype(x.dtype)
    out = dense(out, params["o_proj"], maybe_lora(lora, "o_proj"), lora_scale)
    return out.astype(x.dtype), new_cache
