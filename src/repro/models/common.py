"""Shared model primitives: norms, RoPE, dense (with LoRA hook), embeddings.

Conventions
-----------
* Kernels are stored ``(d_in, d_out)``; activations are ``x @ kernel``.
* LoRA factors are stored ``a: (d_in, r)``, ``b: (r, d_out)`` so the adapter
  update in our layout is ``ΔW = a @ b``. The paper writes ``ΔW_paper = B A``
  with ``A: (r, n)``, ``B: (m, r)`` acting on column vectors; the mapping is
  ``a = Aᵀ``, ``b = Bᵀ`` (``ΔW = ΔW_paperᵀ``). All aggregation math in
  :mod:`repro.core.aggregation` is layout-agnostic.
* Params live in ``cfg.dtype`` (bf16 in production); LoRA factors and norm
  accumulations are f32; softmax/logits are f32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def normal_init(rng, shape, dtype, stddev: float = 0.02):
    return (jax.random.normal(rng, shape, jnp.float32) * stddev).astype(dtype)


def zeros_init(_rng, shape, dtype):
    return jnp.zeros(shape, dtype)


def make_dense_params(rng, d_in: int, d_out: int, dtype, *, bias: bool = False,
                      stddev: Optional[float] = None) -> Params:
    stddev = 0.02 if stddev is None else stddev
    p = {"kernel": normal_init(rng, (d_in, d_out), dtype, stddev)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


# --------------------------------------------------------------------------
# dense + LoRA
# --------------------------------------------------------------------------

def dense(x: jnp.ndarray, params: Params, lora: Optional[Params] = None,
          lora_scale: float = 0.0) -> jnp.ndarray:
    """``x @ kernel (+ bias)``, with an optional LoRA adapter branch.

    ``lora`` is ``{"a": (d_in, r), "b": (r, d_out)}``; the adapter contribution
    is ``scale * (x @ a) @ b`` — the rank-r intermediate stays tiny. The Pallas
    fused path (kernels/lora_matmul) implements the same contract on TPU.
    """
    y = jnp.matmul(x, params["kernel"])
    if lora is not None:
        a = lora["a"].astype(x.dtype)
        b = lora["b"].astype(x.dtype)
        y = y + lora_scale * jnp.matmul(jnp.matmul(x, a), b)
    if "bias" in params:
        y = y + params["bias"]
    return y


def maybe_lora(lora: Optional[Params], name: str) -> Optional[Params]:
    if lora is None:
        return None
    return lora.get(name)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def make_norm_params(kind: str, dim: int, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    raise ValueError(f"unknown norm {kind!r}")


def apply_norm(kind: str, params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Pre-norm with f32 REDUCTIONS but bf16 tensor math.

    §Perf iteration 3: upcasting the whole tensor to f32 (the naive form) lets
    XLA hoist the convert ahead of the row-parallel all-reduces, doubling
    collective bytes (granite-8b train_4k: 310 GB of f32 all-reduce, measured).
    Keeping only the row statistics in f32 preserves the numerics that matter
    (mean/variance accumulation) while the full-size operands stay bf16.
    """
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * params["scale"].astype(x.dtype)
    if kind == "layernorm":
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
        return y * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)
    raise ValueError(f"unknown norm {kind!r}")


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------

def activation(kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {kind!r}")


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x (..., seq, heads, head_dim)`` by position-dependent angles.

    ``positions`` broadcasts against the seq axis: shape ``(seq,)`` or
    ``(batch, seq)``.
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    # broadcast over the heads axis
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------

def make_embedding_params(rng, vocab: int, dim: int, dtype) -> Params:
    return {"embedding": normal_init(rng, (vocab, dim), dtype)}


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray, *, tied_embedding: Optional[jnp.ndarray] = None,
            lora: Optional[Params] = None, lora_scale: float = 0.0) -> jnp.ndarray:
    if tied_embedding is not None:
        logits = jnp.matmul(x, tied_embedding.T.astype(x.dtype))
    else:
        logits = dense(x, params, lora=lora, lora_scale=lora_scale)
    return logits.astype(jnp.float32)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Token-level CE with optional loss mask. Returns (mean_loss, metrics)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == targets).astype(jnp.float32) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
