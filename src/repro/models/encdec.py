"""Encoder-decoder stack (whisper-style). The mel/conv audio frontend is a
STUB per the assignment: the encoder consumes precomputed frame embeddings
``(B, enc_seq_len, d_model)`` supplied by ``input_specs``.

Encoder: bidirectional attention layers (scan). Decoder: causal self-attention
+ cross-attention to the encoder output + FFN. Decode caches: per-layer
self-attn ring/full cache + cross-attn K/V computed once at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_block,
    init_kv_cache,
    make_attention_params,
)
from repro.models.common import (
    Params,
    apply_norm,
    embed,
    make_dense_params,
    make_embedding_params,
    make_norm_params,
    unembed,
)
from repro.models.mlp import make_mlp_params, mlp_block
from repro.models.transformer import stacked_init


def _enc_layer_init(cfg):
    def init(rng):
        ks = jax.random.split(rng, 2)
        return {
            "attn_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": make_attention_params(ks[0], cfg),
            "mlp_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": make_mlp_params(ks[1], cfg),
        }
    return init


def _dec_layer_init(cfg):
    def init(rng):
        ks = jax.random.split(rng, 3)
        return {
            "self_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "self_attn": make_attention_params(ks[0], cfg),
            "cross_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "cross_attn": make_attention_params(ks[1], cfg),
            "mlp_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": make_mlp_params(ks[2], cfg),
        }
    return init


def make_params(rng, cfg) -> Params:
    ks = jax.random.split(rng, 8)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "embed": make_embedding_params(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "pos_embed": make_embedding_params(ks[1], cfg.max_position_embeddings,
                                           cfg.d_model, dtype),
        "enc_pos_embed": make_embedding_params(ks[2], cfg.enc_seq_len, cfg.d_model, dtype),
        "encoder": stacked_init(ks[3], cfg.enc_layers, _enc_layer_init(cfg)),
        "enc_final_norm": make_norm_params(cfg.norm, cfg.d_model, dtype),
        "decoder": stacked_init(ks[4], cfg.num_layers, _dec_layer_init(cfg)),
        "final_norm": make_norm_params(cfg.norm, cfg.d_model, dtype),
    } | ({} if cfg.tie_embeddings else
         {"lm_head": make_dense_params(ks[5], cfg.d_model, cfg.vocab_size, dtype)})


def encode(cfg, params: Params, frames: jnp.ndarray, *, lora: Optional[Params] = None,
           lora_scale: float = 0.0, remat: bool = False,
           block_size: int = 1024) -> jnp.ndarray:
    """frames: (B, enc_seq, d_model) stub embeddings → encoder output."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + params["enc_pos_embed"]["embedding"][: x.shape[1]][None]
    lora = lora or {}

    def body(xc, inp):
        p, lo = inp
        h, _ = attention_block(cfg, p["attn"],
                               apply_norm(cfg.norm, p["attn_norm"], xc),
                               lora=(lo or {}).get("attn"), lora_scale=lora_scale,
                               causal=False, block_size=block_size)
        xc = xc + h
        m = mlp_block(cfg, p["mlp"], apply_norm(cfg.norm, p["mlp_norm"], xc),
                      lora=(lo or {}).get("mlp"), lora_scale=lora_scale)
        return xc + m, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, (params["encoder"], lora.get("encoder")))
    return apply_norm(cfg.norm, params["enc_final_norm"], x)


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    one_self = init_kv_cache(batch, cache_len, cfg.num_kv_heads, hd, dtype)
    one_cross = init_kv_cache(batch, cfg.enc_seq_len, cfg.num_kv_heads, hd, dtype)
    stack = lambda tree: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), tree)
    return {"self": stack(one_self), "cross": stack(one_cross)}


def decoder_forward(cfg, params: Params, tokens: jnp.ndarray, enc_out: Optional[jnp.ndarray],
                    *, lora: Optional[Params] = None, lora_scale: float = 0.0,
                    mode: str = "train", cache: Optional[Params] = None,
                    position: Optional[jnp.ndarray] = None,
                    block_size: int = 1024) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s = tokens.shape
    decode = mode == "decode"
    remat = mode == "train"
    lora = lora or {}
    x = embed(params["embed"], tokens)
    if decode:
        dpos = position.astype(jnp.int32)
        pe = jnp.take(params["pos_embed"]["embedding"],
                      jnp.minimum(dpos, cfg.max_position_embeddings - 1), axis=0)
        x = x + pe[None, None, :]
        positions = None
    else:
        dpos = None
        positions = jnp.arange(s)
        x = x + params["pos_embed"]["embedding"][:s][None]

    def body(xc, inp):
        p, lo, ca = inp
        self_ca = None if ca is None else ca["self"]
        cross_ca = None if ca is None else ca["cross"]
        h, nc_self = attention_block(
            cfg, p["self_attn"], apply_norm(cfg.norm, p["self_norm"], xc),
            lora=(lo or {}).get("self_attn"), lora_scale=lora_scale,
            positions=positions, cache=self_ca, decode_position=dpos,
            block_size=block_size)
        xc = xc + h
        # cross-attention: at decode, read precomputed cross K/V from cache.
        h, nc_cross = attention_block(
            cfg, p["cross_attn"], apply_norm(cfg.norm, p["cross_norm"], xc),
            lora=(lo or {}).get("cross_attn"), lora_scale=lora_scale,
            kv_x=enc_out, cross=True,
            cache=cross_ca, decode_position=dpos, causal=False,
            block_size=block_size)
        xc = xc + h
        m = mlp_block(cfg, p["mlp"], apply_norm(cfg.norm, p["mlp_norm"], xc),
                      lora=(lo or {}).get("mlp"), lora_scale=lora_scale)
        ys = None if ca is None else {"self": nc_self, "cross": nc_cross}
        return xc + m, ys

    lo = lora.get("decoder")
    if cache is None:
        def bnc(xc, inp):
            p, l = inp
            xo, _ = body(xc, (p, l, None))
            return xo, None
        fn = jax.checkpoint(bnc) if remat else bnc
        x, _ = jax.lax.scan(fn, x, (params["decoder"], lo))
        new_cache = None
    else:
        x, new_cache = jax.lax.scan(body, x, (params["decoder"], lo, cache))

    x = apply_norm(cfg.norm, params["final_norm"], x)
    tied = params["embed"]["embedding"] if cfg.tie_embeddings else None
    logits = unembed(params.get("lm_head", {}), x, tied_embedding=tied)
    return logits, new_cache
