"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to ``kv_lora_rank`` latents plus one shared decoupled-RoPE key
per position — the decode cache stores ONLY ``(c_kv, k_rope)`` per token
(512 + 64 dims for the full config vs 128·(128+128) for vanilla GQA: ~57×
smaller).

Training/prefill decompresses K/V and uses the shared blockwise core.
Decode uses *weight absorption* (the TPU-friendly form): queries are mapped
into the latent space through ``w_uk`` so scores are taken directly against the
compressed cache, and attention output is re-expanded through ``w_uv`` — per
step cost is O(S · kv_lora_rank) per head instead of decompressing the cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention
from repro.models.common import (
    Params,
    apply_rope,
    dense,
    make_dense_params,
    make_norm_params,
    apply_norm,
    maybe_lora,
)

NEG_INF = -1e30


def make_mla_params(rng, cfg) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    return {
        # query path: d → q_lora_rank → heads × (nope + rope)
        "q_down": make_dense_params(ks[0], d, qr, dtype),
        "q_norm": make_norm_params("rmsnorm", qr, dtype),
        "q_up": make_dense_params(ks[1], qr, h * (qk_nope + qk_rope), dtype),
        # kv path: d → kv_lora_rank (+ shared rope key)
        "kv_down": make_dense_params(ks[2], d, kvr + qk_rope, dtype),
        "kv_norm": make_norm_params("rmsnorm", kvr, dtype),
        "k_up": make_dense_params(ks[3], kvr, h * qk_nope, dtype),
        "v_up": make_dense_params(ks[4], kvr, h * dv, dtype),
        "o_proj": make_dense_params(ks[5], h * dv, d, dtype),
    }


def _project_q(cfg, params, x, lora, lora_scale):
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_nope, qk_rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    qd = dense(x, params["q_down"], maybe_lora(lora, "q_down"), lora_scale)
    qd = apply_norm("rmsnorm", params["q_norm"], qd)
    q = dense(qd, params["q_up"], maybe_lora(lora, "q_up"), lora_scale)
    q = q.reshape(b, s, h, qk_nope + qk_rope)
    return q[..., :qk_nope], q[..., qk_nope:]


def _project_kv_latent(cfg, params, x, lora, lora_scale):
    kvr, qk_rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = dense(x, params["kv_down"], maybe_lora(lora, "kv_down"), lora_scale)
    c_kv = apply_norm("rmsnorm", params["kv_norm"], kv[..., :kvr])
    k_rope = kv[..., kvr:]  # (B, S, qk_rope) — ONE shared rope key per position
    return c_kv, k_rope


def init_mla_cache(batch: int, length: int, cfg, dtype=jnp.bfloat16) -> Params:
    return {
        "c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }


def mla_block(
    cfg,
    params: Params,
    x: jnp.ndarray,
    *,
    lora: Optional[Params] = None,
    lora_scale: float = 0.0,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Params] = None,
    decode_position: Optional[jnp.ndarray] = None,
    block_size: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    if positions is None:
        positions = jnp.arange(s)
    is_decode = decode_position is not None

    q_nope, q_rope = _project_q(cfg, params, x, lora, lora_scale)
    q_pos = decode_position[None] if is_decode else positions
    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)

    c_kv, k_rope = _project_kv_latent(cfg, params, x, lora, lora_scale)
    k_rope = apply_rope(k_rope[..., None, :], q_pos, cfg.rope_theta)[..., 0, :]

    new_cache = cache
    if is_decode:
        # -- absorbed decode against the compressed cache ---------------------
        length = cache["c_kv"].shape[1]
        slot = decode_position % length
        c_kv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), slot, axis=1)
        k_rope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), slot, axis=1)
        pos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], decode_position[None], slot, axis=0)
        new_cache = {"c_kv": c_kv_c, "k_rope": k_rope_c, "pos": pos}

        w_uk = params["k_up"]["kernel"].reshape(kvr, h, qk_nope)
        w_uv = params["v_up"]["kernel"].reshape(kvr, h, dv)
        # absorb: query → latent space
        q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)  # (B,1,H,kvr)
        scale = (qk_nope + qk_rope) ** -0.5
        s_nope = jnp.einsum("bqhc,bsc->bhqs", q_lat, c_kv_c, preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope_c, preferred_element_type=jnp.float32)
        scores = (s_nope + s_rope) * scale
        valid = (pos >= 0) & (pos <= decode_position)
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhqs,bsc->bqhc", w.astype(c_kv_c.dtype), c_kv_c)
        out = jnp.einsum("bqhc,chd->bqhd", ctx_lat, w_uv)  # (B,1,H,dv)
    else:
        # -- decompressed training/prefill ------------------------------------
        k_nope = dense(c_kv, params["k_up"], maybe_lora(lora, "k_up"), lora_scale)
        v = dense(c_kv, params["v_up"], maybe_lora(lora, "v_up"), lora_scale)
        k_nope = k_nope.reshape(b, s, h, qk_nope)
        v = v.reshape(b, s, h, dv)
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, qk_rope))
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        out = flash_attention(q_full, k_full, v, True, 0, 0, block_size)
        if cache is not None:
            length = cache["c_kv"].shape[1]
            ck, kr = c_kv[:, -length:], k_rope[:, -length:]
            ppos = positions[-length:]
            pad = length - ck.shape[1]
            if pad > 0:
                ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0)))
                kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0)))
                ppos = jnp.concatenate([ppos, jnp.full((pad,), -1, ppos.dtype)])
            new_cache = {"c_kv": ck.astype(cache["c_kv"].dtype),
                         "k_rope": kr.astype(cache["k_rope"].dtype),
                         "pos": ppos.astype(jnp.int32)}

    out = out.reshape(b, s, h * dv).astype(x.dtype)
    out = dense(out, params["o_proj"], maybe_lora(lora, "o_proj"), lora_scale)
    return out.astype(x.dtype), new_cache
