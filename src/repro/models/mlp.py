"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain 2-layer MLPs."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import Params, activation, dense, make_dense_params, maybe_lora


def make_mlp_params(rng, cfg, d_ff: int = 0, *, gated: Optional[bool] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    gated = (cfg.act == "silu") if gated is None else gated
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 3)
    p = {
        "up_proj": make_dense_params(ks[0], d, ff, dtype, bias=cfg.qkv_bias and cfg.norm == "layernorm"),
        "down_proj": make_dense_params(ks[1], ff, d, dtype, bias=cfg.qkv_bias and cfg.norm == "layernorm"),
    }
    if gated:
        p["gate_proj"] = make_dense_params(ks[2], d, ff, dtype)
    return p


def mlp_block(cfg, params: Params, x: jnp.ndarray, *, lora: Optional[Params] = None,
              lora_scale: float = 0.0) -> jnp.ndarray:
    from repro.sharding import act as _act
    if _act.enabled() and x.ndim >= 2 and x.shape[-2] * (
            x.shape[0] if x.ndim == 3 else 1) <= 4096:
        # decode-scale token counts: replicate the (tiny) tokens so the
        # weight-stationary serving layout (ff sharded over BOTH axes) holds
        # without per-step weight gathers (§Perf it. 7, generalised from MoE).
        x = _act.constrain(x, tuple(None for _ in range(x.ndim)))
    up = dense(x, params["up_proj"], maybe_lora(lora, "up_proj"), lora_scale)
    if "gate_proj" in params:
        gate = dense(x, params["gate_proj"], maybe_lora(lora, "gate_proj"), lora_scale)
        h = activation(cfg.act, gate) * up
    else:
        h = activation(cfg.act, up)
    return dense(h, params["down_proj"], maybe_lora(lora, "down_proj"), lora_scale)
