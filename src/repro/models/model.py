"""Unified model API: ``build_model(cfg) → Model`` for every family.

``Model`` is a thin namespace of pure functions closed over the config:

* ``init(rng) → params``
* ``apply(params, batch, lora=…) → (logits, aux)`` — training forward
* ``loss(params, batch, lora=…) → (scalar, metrics)``
* ``init_cache(batch_size, cache_len) → cache``
* ``prefill(params, batch, cache, lora=…) → (logits, cache)``
* ``decode_step(params, tokens, cache, position, lora=…) → (logits, cache)``

``batch``: ``tokens``/``targets``/``loss_mask`` (B,S) plus family extras —
``frames`` (encdec stub frontend) / ``vision_embeds`` (vlm stub frontend).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.common import cross_entropy


Batch = Dict[str, jnp.ndarray]


@dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    apply: Callable
    loss: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable


def build_model(cfg, *, moe_impl: str = "ragged", block_size: int = 1024) -> Model:
    fam = cfg.family

    if fam == "encdec":
        def init(rng):
            return encdec.make_params(rng, cfg)

        def apply(params, batch, lora=None, lora_scale=0.0):
            enc_out = encdec.encode(cfg, params, batch["frames"], lora=lora,
                                    lora_scale=lora_scale, remat=True,
                                    block_size=block_size)
            logits, _ = encdec.decoder_forward(
                cfg, params, batch["tokens"], enc_out, lora=lora,
                lora_scale=lora_scale, mode="train", block_size=block_size)
            return logits, jnp.zeros((), jnp.float32)

        def init_cache(batch_size, cache_len, dtype=jnp.bfloat16):
            return encdec.init_cache(cfg, batch_size, cache_len, dtype)

        def prefill(params, batch, cache, lora=None, lora_scale=0.0):
            enc_out = encdec.encode(cfg, params, batch["frames"], lora=lora,
                                    lora_scale=lora_scale, block_size=block_size)
            logits, cache = encdec.decoder_forward(
                cfg, params, batch["tokens"], enc_out, lora=lora,
                lora_scale=lora_scale, mode="prefill", cache=cache,
                block_size=block_size)
            return logits, cache

        def decode_step(params, tokens, cache, position, lora=None, lora_scale=0.0):
            logits, cache = encdec.decoder_forward(
                cfg, params, tokens, None, lora=lora, lora_scale=lora_scale,
                mode="decode", cache=cache, position=position,
                block_size=block_size)
            return logits, cache

    else:
        def init(rng):
            return transformer.make_params(rng, cfg)

        def apply(params, batch, lora=None, lora_scale=0.0):
            logits, aux, _ = transformer.forward(
                cfg, params, batch["tokens"], lora=lora, lora_scale=lora_scale,
                mode="train", extra_embeds=batch.get("vision_embeds"),
                moe_impl=moe_impl, block_size=block_size)
            return logits, aux

        def init_cache(batch_size, cache_len, dtype=jnp.bfloat16):
            return transformer.init_cache(cfg, batch_size, cache_len, dtype)

        def prefill(params, batch, cache, lora=None, lora_scale=0.0):
            logits, _, cache = transformer.forward(
                cfg, params, batch["tokens"], lora=lora, lora_scale=lora_scale,
                mode="prefill", cache=cache,
                extra_embeds=batch.get("vision_embeds"),
                moe_impl=moe_impl, block_size=block_size)
            return logits, cache

        def decode_step(params, tokens, cache, position, lora=None, lora_scale=0.0):
            logits, _, cache = transformer.forward(
                cfg, params, tokens, lora=lora, lora_scale=lora_scale,
                mode="decode", cache=cache, position=position,
                moe_impl=moe_impl, block_size=block_size)
            return logits, cache

    def loss(params, batch, lora=None, lora_scale=0.0):
        logits, aux = apply(params, batch, lora=lora, lora_scale=lora_scale)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if fam == "vlm" and "vision_embeds" in batch:
            # logits cover [vision prefix | text]; score text positions only.
            vt = batch["vision_embeds"].shape[1]
            logits = logits[:, vt:]
        ce, metrics = cross_entropy(logits, targets, mask)
        total = ce + aux
        metrics = dict(metrics)
        metrics["aux_loss"] = aux
        metrics["total_loss"] = total
        return total, metrics

    return Model(cfg=cfg, init=init, apply=apply, loss=loss,
                 init_cache=init_cache, prefill=prefill, decode_step=decode_step)
