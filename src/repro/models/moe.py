"""Mixture-of-Experts: top-k router + grouped-GEMM dispatch.

Two execution paths share one set of parameters:

* ``ragged`` (default): sort-by-expert + ``jax.lax.ragged_dot`` grouped GEMM —
  FLOP-proportional (the megablocks pattern, TPU-native via ragged_dot). Expert
  weights carry the expert axis, sharded over the ``model`` mesh axis for
  expert parallelism.
* ``dense``: every expert on every token via einsum — the oracle used by tests
  and by tiny smoke configs (O(E/k) FLOP overhead, trivially shardable).

Router aux load-balance loss follows Switch/Mixtral: ``E · Σ_e f_e · p_e``.
Optional per-expert LoRA (cfg flag ``lora_experts``) applies stacked rank-r
factors through the same grouped GEMMs — the FedEx-LoRA residual machinery in
core/ then applies per expert, unchanged.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, activation, make_dense_params, normal_init
from repro.models.mlp import make_mlp_params, mlp_block


def make_moe_params(rng, cfg) -> Params:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 5)
    p = {
        "router": make_dense_params(ks[0], d, e, dtype),
        "experts": {
            "up_proj": normal_init(ks[1], (e, d, ff), dtype),
            "gate_proj": normal_init(ks[2], (e, d, ff), dtype),
            "down_proj": normal_init(ks[3], (e, ff, d), dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = make_mlp_params(ks[4], cfg, d_ff=ff * cfg.num_shared_experts, gated=True)
    return p


def router_topk(cfg, router_params: Params, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (topk_weights (T,k), topk_idx (T,k), aux_loss scalar)."""
    logits = jnp.matmul(x, router_params["kernel"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss: E * Σ_e (fraction routed to e) * (mean prob of e)
    e = cfg.num_experts
    one_hot = jax.nn.one_hot(topk_idx, e, dtype=jnp.float32).sum(axis=1)  # (T, E)
    f = one_hot.mean(axis=0) / cfg.num_experts_per_tok
    pbar = probs.mean(axis=0)
    aux = e * jnp.sum(f * pbar) * cfg.router_aux_loss_coef
    return topk_w, topk_idx, aux


def _expert_ffn_dense(cfg, experts: Params, x: jnp.ndarray, w_full: jnp.ndarray,
                      lora: Optional[Params], lora_scale: float) -> jnp.ndarray:
    """(T, d) × routing weights (T, E) → (T, d).

    Every expert on every token, combine fused into the down projection so the
    (T, E, d) intermediate never materialises and the expert axis reduces
    straight into the all-reduce (the GSPMD-friendly form — §Perf it. 5).
    """
    up = jnp.einsum("td,edf->tef", x, experts["up_proj"])
    gate = jnp.einsum("td,edf->tef", x, experts["gate_proj"])
    if lora is not None and "experts" in lora:
        le = lora["experts"]
        up = up + lora_scale * jnp.einsum(
            "ter,erf->tef", jnp.einsum("td,edr->ter", x, le["up_proj"]["a"].astype(x.dtype)),
            le["up_proj"]["b"].astype(x.dtype))
        gate = gate + lora_scale * jnp.einsum(
            "ter,erf->tef", jnp.einsum("td,edr->ter", x, le["gate_proj"]["a"].astype(x.dtype)),
            le["gate_proj"]["b"].astype(x.dtype))
    h = activation(cfg.act, gate) * up
    hw = h * w_full[..., None].astype(h.dtype)  # routing-weighted (T, E, ff)
    y = jnp.einsum("tef,efd->td", hw, experts["down_proj"])
    if lora is not None and "experts" in lora:
        le = lora["experts"]
        y = y + lora_scale * jnp.einsum(
            "ter,erd->td", jnp.einsum("tef,efr->ter", hw, le["down_proj"]["a"].astype(x.dtype)),
            le["down_proj"]["b"].astype(x.dtype))
    return y


def _ragged(lhs, rhs, group_sizes):
    return jax.lax.ragged_dot(lhs, rhs, group_sizes.astype(jnp.int32))


def _expert_ffn_ragged(cfg, experts: Params, x_sorted: jnp.ndarray,
                       group_sizes: jnp.ndarray,
                       lora: Optional[Params], lora_scale: float) -> jnp.ndarray:
    """Grouped GEMM over tokens sorted by expert id."""
    up = _ragged(x_sorted, experts["up_proj"], group_sizes)
    gate = _ragged(x_sorted, experts["gate_proj"], group_sizes)
    if lora is not None and "experts" in lora:
        le = lora["experts"]
        up = up + lora_scale * _ragged(
            _ragged(x_sorted, le["up_proj"]["a"].astype(x_sorted.dtype), group_sizes),
            le["up_proj"]["b"].astype(x_sorted.dtype), group_sizes)
        gate = gate + lora_scale * _ragged(
            _ragged(x_sorted, le["gate_proj"]["a"].astype(x_sorted.dtype), group_sizes),
            le["gate_proj"]["b"].astype(x_sorted.dtype), group_sizes)
    h = activation(cfg.act, gate) * up
    y = _ragged(h, experts["down_proj"], group_sizes)
    if lora is not None and "experts" in lora:
        le = lora["experts"]
        y = y + lora_scale * _ragged(
            _ragged(h, le["down_proj"]["a"].astype(h.dtype), group_sizes),
            le["down_proj"]["b"].astype(h.dtype), group_sizes)
    return y


def moe_block(cfg, params: Params, x: jnp.ndarray, *, lora: Optional[Params] = None,
              lora_scale: float = 0.0, impl: str = "ragged"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    k = cfg.num_experts_per_tok
    e = cfg.num_experts

    topk_w, topk_idx, aux = router_topk(cfg, params["router"], xf)

    if impl == "dense":
        w_full = jax.nn.one_hot(topk_idx, e, dtype=jnp.float32) * topk_w[..., None]  # (T,k,E)
        w_full = w_full.sum(axis=1)  # (T, E)
        from repro.sharding import act as _act
        if _act.enabled() and t <= 4096:
            # decode-scale token counts: replicating the (tiny) tokens lets
            # the weight-stationary serving layout hold — otherwise GSPMD
            # gathers expert weights over the batch axis every step (§Perf 7).
            xf = _act.constrain(xf, (None, None))
            w_full = _act.constrain(w_full, (None, None))
        y = _expert_ffn_dense(cfg, params["experts"], xf, w_full, lora, lora_scale)
    elif impl == "ragged":
        flat_expert = topk_idx.reshape(t * k)  # (T*k,)
        sort_idx = jnp.argsort(flat_expert)
        # token index each sorted row came from
        token_idx = sort_idx // k
        x_sorted = jnp.take(xf, token_idx, axis=0)  # (T*k, d)
        group_sizes = jnp.bincount(flat_expert, length=e)
        y_sorted = _expert_ffn_ragged(cfg, params["experts"], x_sorted, group_sizes,
                                      lora, lora_scale)
        w_sorted = jnp.take(topk_w.reshape(t * k), sort_idx)
        y_weighted = y_sorted * w_sorted[:, None].astype(y_sorted.dtype)
        # combine: scatter-add back onto tokens
        y = jnp.zeros((t, d), y_sorted.dtype).at[token_idx].add(y_weighted)
    else:
        raise ValueError(f"unknown moe impl {impl!r}")

    if "shared" in params:
        y = y + mlp_block(cfg, params["shared"], xf,
                          lora=(lora or {}).get("shared"), lora_scale=lora_scale)

    return y.reshape(b, s, d), aux
