"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like with decay mask) + inter-chunk recurrence over per-chunk
states via ``lax.scan``. Decode is the O(1) recurrent update on the carried
state ``h ∈ (B, H, P, N)``.

LoRA targets: ``in_proj`` / ``out_proj`` (the frozen matmuls — the FedEx-LoRA
machinery applies unchanged; see DESIGN §4).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    dense,
    make_dense_params,
    maybe_lora,
    normal_init,
)


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n  # x, B, C all pass through the causal conv
    return d_inner, nheads, n, conv_ch


def make_mamba2_params(rng, cfg) -> Params:
    d = cfg.d_model
    d_inner, nheads, n, conv_ch = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    # in_proj emits [z (d_inner), x (d_inner), B (n), C (n), dt (nheads)]
    d_in_proj = 2 * d_inner + 2 * n + nheads
    a_init = jnp.log(jnp.linspace(1.0, 16.0, nheads))  # A = -exp(A_log)
    return {
        "in_proj": make_dense_params(ks[0], d, d_in_proj, dtype),
        "conv": {
            "kernel": normal_init(ks[1], (cfg.ssm_conv, conv_ch), dtype, stddev=0.1),
            "bias": jnp.zeros((conv_ch,), dtype),
        },
        "A_log": a_init.astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": make_dense_params(ks[2], d_inner, d, dtype),
    }


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray, bias: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d. x: (B, S, C); kernel: (K, C).

    Returns (y, new_state) where state holds the last K-1 inputs.
    """
    k = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    # windows: y_t = Σ_j kernel[j] * xx[t+j]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(k):
        y = y + xx[:, j : j + x.shape[1]].astype(jnp.float32) * kernel[j].astype(jnp.float32)
    y = (y + bias.astype(jnp.float32)).astype(x.dtype)
    new_state = xx[:, -(k - 1):] if k > 1 else state
    return jax.nn.silu(y), new_state


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """segsum(x)[..., i, j] = Σ_{j < l <= i} x[..., l]  (−inf above diagonal)."""
    t = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 256,
                h0: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    x:  (B, S, H, P) inputs per head
    dt: (B, S, H)    positive step sizes
    a:  (H,)         negative per-head decay
    b:  (B, S, N)    input projections (shared across heads, n_groups=1)
    c:  (B, S, N)    output projections
    h0: (B, H, P, N) initial state
    → (y (B,S,H,P), h_final (B,H,P,N))
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]  # (B, NC, L, H) log-decay per step
    da_cs = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (diagonal) term ----------------------------------------
    # L_mat[i,j] = exp(Σ_{j<l<=i} da_l): (B, NC, H, L, L)
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B,NC,H,L,L)
    cb = jnp.einsum("bzln,bzmn->bzlm", cc, bc)  # (B,NC,L,L)
    y_diag = jnp.einsum("bzhlm,bzlm,bzmh,bzmhp->bzlhp", lmat, cb, dtc, xc)

    # ---- per-chunk final states ---------------------------------------------
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (B,NC,L,H)
    states = jnp.einsum("bzlh,bzlh,bzln,bzlhp->bzhpn",
                        decay_to_end, dtc, bc, xc)  # (B,NC,H,P,N)

    # ---- inter-chunk recurrence over chunk states ---------------------------
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])  # (B, NC, H) total decay per chunk

    def scan_body(h_prev, inputs):
        st, dec = inputs  # st: (B,H,P,N), dec: (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit state ENTERING this chunk

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    st_t = states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    dec_t = chunk_decay.transpose(1, 0, 2)
    h_final, h_enter = jax.lax.scan(scan_body, h0, (st_t, dec_t))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (B,NC,H,P,N)

    # ---- inter-chunk (off-diagonal) output ----------------------------------
    state_decay = jnp.exp(da_cs)  # decay from chunk start to position i
    y_off = jnp.einsum("bzln,bzlh,bzhpn->bzlhp", cc, state_decay, h_enter)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_final


def ssd_step(h: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. h: (B,H,P,N); x: (B,H,P); dt: (B,H); b,c: (B,N)."""
    decay = jnp.exp(dt * a[None, :])  # (B,H)
    inp = jnp.einsum("bh,bn,bhp->bhpn", dt, b, x)
    h_new = h * decay[..., None, None] + inp
    y = jnp.einsum("bn,bhpn->bhp", c, h_new.astype(c.dtype))
    return h_new, y


def init_mamba_cache(batch: int, cfg, dtype=jnp.bfloat16) -> Params:
    d_inner, nheads, n, conv_ch = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nheads, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba2_block(cfg, params: Params, x: jnp.ndarray, *,
                 lora: Optional[Params] = None, lora_scale: float = 0.0,
                 cache: Optional[Params] = None, decode: bool = False,
                 chunk: int = 256) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, S, d_model) → (y, new_cache)."""
    bsz, s, _ = x.shape
    d_inner, nheads, n, conv_ch = _dims(cfg)
    p_dim = cfg.ssm_head_dim

    zxbcdt = dense(x, params["in_proj"], maybe_lora(lora, "in_proj"), lora_scale)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_ch]
    dt_pre = zxbcdt[..., d_inner + conv_ch :]  # (B, S, H)

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv_state = _causal_conv(xbc, params["conv"]["kernel"],
                                       params["conv"]["bias"], conv_state)
    xs = xbc[..., :d_inner]
    b = xbc[..., d_inner : d_inner + n]
    c = xbc[..., d_inner + n :]

    a = -jnp.exp(params["A_log"])  # (H,)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    xh = xs.reshape(bsz, s, nheads, p_dim)

    if decode:
        assert s == 1 and cache is not None
        h_new, y = ssd_step(cache["ssm"], xh[:, 0].astype(jnp.float32),
                            dt[:, 0], a, b[:, 0].astype(jnp.float32),
                            c[:, 0].astype(jnp.float32))
        y = y[:, None]  # (B,1,H,P)
        new_cache = {"ssm": h_new, "conv": new_conv_state}
    else:
        h0 = cache["ssm"] if cache is not None else None
        pad = (-s) % chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, b_p, c_p = xh, dt, b, c
        y, h_final = ssd_chunked(xh_p, dt_p, a, b_p.astype(jnp.float32),
                                 c_p.astype(jnp.float32), chunk=chunk, h0=h0)
        y = y[:, :s]
        new_cache = None if cache is None else {"ssm": h_final, "conv": new_conv_state}

    y = y + xh.astype(y.dtype) * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z, params["norm"]["scale"])
    out = dense(y, params["out_proj"], maybe_lora(lora, "out_proj"), lora_scale)
    return out.astype(x.dtype), new_cache
