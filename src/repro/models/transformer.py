"""Decoder-only stacks for the dense / moe / ssm / hybrid / vlm families.

Layer stacks are ``jax.lax.scan`` over stacked parameters so the HLO is O(1)
in depth (critical for CPU-hosted dry-run compiles of 60–81-layer configs).
Heterogeneous architectures scan over their *period*:

* gemma3: period = 5 local (sliding-window) layers + 1 global layer
* zamba2: period = ``attn_every`` mamba2 layers + one application of the single
  parameter-SHARED attention+MLP block (+ trailing mamba layers)
* xlstm:  period = (slstm_every − 1) mLSTM blocks + 1 sLSTM block
* deepseek-v2: ``first_k_dense`` dense-FFN MLA layers, then MLA+MoE layers

Modes: ``train`` (remat'd, no cache), ``prefill`` (fills caches), ``decode``
(single token against caches). MoE aux losses accumulate through the scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import attention_block, init_kv_cache, make_attention_params
from repro.models.common import (
    Params,
    apply_norm,
    embed,
    make_dense_params,
    make_embedding_params,
    make_norm_params,
    normal_init,
    unembed,
)
from repro.models.mlp import make_mlp_params, mlp_block


# ==========================================================================
# init helpers
# ==========================================================================

def stacked_init(rng, n: int, fn):
    """vmap a per-layer init over n split rngs → params stacked on axis 0."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(fn)(rngs)


def _dense_layer_init(cfg, use_moe: bool, d_ff_override: int = 0):
    def init(rng):
        ks = jax.random.split(rng, 2)
        p = {
            "attn_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp_norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
        }
        if cfg.mla:
            p["attn"] = mla_mod.make_mla_params(ks[0], cfg)
        else:
            p["attn"] = make_attention_params(ks[0], cfg)
        if use_moe:
            p["mlp"] = moe_mod.make_moe_params(ks[1], cfg)
        else:
            p["mlp"] = make_mlp_params(ks[1], cfg, d_ff=d_ff_override or cfg.d_ff)
        return p

    return init


def _mamba_layer_init(cfg):
    def init(rng):
        return {
            "norm": make_norm_params(cfg.norm, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mamba": ssm_mod.make_mamba2_params(rng, cfg),
        }

    return init


# ==========================================================================
# layer bodies
# ==========================================================================

def _attn_mlp_layer(cfg, p, x, *, lora, lora_scale, positions, window, cache,
                    decode_position, moe_impl, block_size):
    """Standard pre-norm transformer layer; returns (x, new_cache, aux)."""
    h_in = apply_norm(cfg.norm, p["attn_norm"], x)
    if cfg.mla:
        h, new_cache = mla_mod.mla_block(
            cfg, p["attn"], h_in, lora=(lora or {}).get("attn"),
            lora_scale=lora_scale, positions=positions, cache=cache,
            decode_position=decode_position, block_size=block_size)
    else:
        h, new_cache = attention_block(
            cfg, p["attn"], h_in, lora=(lora or {}).get("attn"),
            lora_scale=lora_scale, positions=positions, window=window,
            cache=cache, decode_position=decode_position, block_size=block_size)
    x = x + h
    m_in = apply_norm(cfg.norm, p["mlp_norm"], x)
    aux = jnp.zeros((), jnp.float32)
    if "router" in p["mlp"]:
        m, aux = moe_mod.moe_block(cfg, p["mlp"], m_in,
                                   lora=(lora or {}).get("mlp"),
                                   lora_scale=lora_scale, impl=moe_impl)
    else:
        m = mlp_block(cfg, p["mlp"], m_in, lora=(lora or {}).get("mlp"),
                      lora_scale=lora_scale)
    return x + m, new_cache, aux


def _mamba_layer(cfg, p, x, *, lora, lora_scale, cache, decode):
    h_in = apply_norm(cfg.norm, p["norm"], x)
    h, new_cache = ssm_mod.mamba2_block(cfg, p["mamba"], h_in,
                                        lora=(lora or {}).get("mamba"),
                                        lora_scale=lora_scale, cache=cache,
                                        decode=decode)
    return x + h, new_cache


# ==========================================================================
# scan runner
# ==========================================================================

def _scan_layers(body, x, xs, *, remat: bool):
    """scan ``body(x, xs_slice) → (x, ys_slice)`` over the leading layer axis."""
    fn = jax.checkpoint(body) if remat else body

    def wrapped(carry, inp):
        return fn(carry, inp)

    return jax.lax.scan(wrapped, x, xs)


def _maybe(tree, default_like):
    """Replace a None subtree with a scan-compatible zeros dummy."""
    return tree if tree is not None else default_like


# ==========================================================================
# parameter construction per family
# ==========================================================================

def make_params(rng, cfg) -> Params:
    ks = jax.random.split(rng, 8)
    dtype = jnp.dtype(cfg.dtype)
    params: Params = {"embed": make_embedding_params(ks[0], cfg.vocab_size, cfg.d_model, dtype)}
    if cfg.learned_pos_embeddings:
        params["pos_embed"] = make_embedding_params(
            ks[1], cfg.max_position_embeddings, cfg.d_model, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global_ratio:
            period = cfg.local_global_ratio + 1
            nper = cfg.num_layers // period
            params["periods"] = {
                "local": stacked_init(
                    ks[2], nper,
                    lambda r: stacked_init(r, cfg.local_global_ratio,
                                           _dense_layer_init(cfg, False))),
                "global": stacked_init(ks[3], nper, _dense_layer_init(cfg, False)),
            }
        else:
            params["layers"] = stacked_init(ks[2], cfg.num_layers,
                                            _dense_layer_init(cfg, False))
    elif fam == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            params["dense_layers"] = stacked_init(
                ks[2], cfg.first_k_dense,
                _dense_layer_init(cfg, False, d_ff_override=cfg.dense_d_ff))
        params["layers"] = stacked_init(ks[3], n_moe, _dense_layer_init(cfg, True))
    elif fam == "hybrid":
        nper = cfg.num_layers // cfg.attn_every
        trailing = cfg.num_layers - nper * cfg.attn_every
        params["mamba_layers"] = stacked_init(
            ks[2], nper,
            lambda r: stacked_init(r, cfg.attn_every, _mamba_layer_init(cfg)))
        if trailing:
            params["mamba_trailing"] = stacked_init(ks[4], trailing, _mamba_layer_init(cfg))
        # zamba2: ONE parameter-shared attention+MLP block
        params["shared_attn"] = _dense_layer_init(cfg, False)(ks[3])
    elif fam == "ssm":  # xlstm
        period = cfg.slstm_every
        nper = cfg.num_layers // period
        params["periods"] = {
            "mlstm": stacked_init(
                ks[2], nper,
                lambda r: stacked_init(r, period - 1,
                                       lambda r2: xlstm_mod.make_mlstm_params(r2, cfg))),
            "slstm": stacked_init(ks[3], nper,
                                  lambda r: xlstm_mod.make_slstm_params(r, cfg)),
        }
    else:
        raise ValueError(f"make_params: unsupported family {fam!r} (encdec has its own)")

    params["final_norm"] = make_norm_params(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = make_dense_params(ks[5], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.family == "vlm":
        # projector stub: identity-sized projection applied to provided patch
        # embeddings (the ViT itself is stubbed per the assignment).
        params["vision_proj"] = make_dense_params(ks[6], cfg.d_model, cfg.d_model, dtype)
    return params


# ==========================================================================
# caches
# ==========================================================================

def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> Params:
    """Cache pytree mirroring the stack layout.

    ``cache_len`` is the max absolute sequence length; windowed layers allocate
    ring buffers of ``min(window, cache_len)``.
    """
    hd = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads

    def attn_cache(n: Optional[int], window: int):
        length = min(window, cache_len) if window else cache_len
        if cfg.mla:
            one = lambda: mla_mod.init_mla_cache(batch, length, cfg, dtype)
        else:
            one = lambda: init_kv_cache(batch, length, kvh, hd, dtype)
        if n is None:
            return one()
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(n)])

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global_ratio:
            period = cfg.local_global_ratio + 1
            nper = cfg.num_layers // period
            local = attn_cache(None, cfg.local_window)
            local = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (nper, cfg.local_global_ratio) + x.shape), local)
            glob = attn_cache(nper, 0)
            return {"local": local, "global": glob}
        return {"layers": attn_cache(cfg.num_layers, cfg.sliding_window)}
    if fam == "moe":
        out = {"layers": attn_cache(cfg.num_layers - cfg.first_k_dense, cfg.sliding_window)}
        if cfg.first_k_dense:
            out["dense_layers"] = attn_cache(cfg.first_k_dense, cfg.sliding_window)
        return out
    if fam == "hybrid":
        nper = cfg.num_layers // cfg.attn_every
        trailing = cfg.num_layers - nper * cfg.attn_every
        mamba_one = ssm_mod.init_mamba_cache(batch, cfg, dtype)
        mamba = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nper, cfg.attn_every) + x.shape), mamba_one)
        out = {"mamba": mamba, "shared_attn": attn_cache(nper, 0)}
        if trailing:
            out["mamba_trailing"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (trailing,) + x.shape), mamba_one)
        return out
    if fam == "ssm":
        period = cfg.slstm_every
        nper = cfg.num_layers // period
        m_one = xlstm_mod.init_mlstm_cache(batch, cfg, dtype)
        s_one = xlstm_mod.init_slstm_cache(batch, cfg, dtype)
        return {
            "mlstm": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (nper, period - 1) + x.shape), m_one),
            "slstm": jax.tree.map(lambda x: jnp.broadcast_to(x, (nper,) + x.shape), s_one),
        }
    raise ValueError(f"init_cache: unsupported family {fam!r}")


# ==========================================================================
# forward
# ==========================================================================

def forward(
    cfg,
    params: Params,
    tokens: jnp.ndarray,  # (B, S) int32
    *,
    lora: Optional[Params] = None,
    lora_scale: float = 0.0,
    mode: str = "train",  # train | prefill | decode
    cache: Optional[Params] = None,
    position: Optional[jnp.ndarray] = None,  # scalar decode position
    extra_embeds: Optional[jnp.ndarray] = None,  # vlm: (B, Vt, d) patch embeds
    moe_impl: str = "ragged",
    block_size: int = 1024,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params]]:
    """Returns (logits (B,S,V) f32, aux_loss scalar, new_cache)."""
    b, s = tokens.shape
    decode = mode == "decode"
    remat = mode == "train"
    x = embed(params["embed"], tokens)

    offset = 0
    if cfg.family == "vlm" and extra_embeds is not None and not decode:
        from repro.models.common import dense as dense_fn
        vis = dense_fn(extra_embeds.astype(x.dtype), params["vision_proj"])
        x = jnp.concatenate([vis, x], axis=1)
        s = x.shape[1]

    if decode:
        positions = None
        dpos = position.astype(jnp.int32)
    else:
        positions = jnp.arange(s)
        dpos = None

    if cfg.learned_pos_embeddings:
        if decode:
            pe = jnp.take(params["pos_embed"]["embedding"],
                          jnp.minimum(dpos, cfg.max_position_embeddings - 1), axis=0)
            x = x + pe[None, None, :]
        else:
            pe = params["pos_embed"]["embedding"][:s]
            x = x + pe[None]

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = None

    lora = lora or {}
    fam = cfg.family

    def attn_body_factory(window):
        def body(carry, inp):
            xc, aux = carry
            p, lo, ca = inp
            xo, nc, a = _attn_mlp_layer(
                cfg, p, xc, lora=lo, lora_scale=lora_scale, positions=positions,
                window=window, cache=ca, decode_position=dpos, moe_impl=moe_impl,
                block_size=block_size)
            return (xo, aux + a), nc
        return body

    def run_stack(x, layer_params, layer_lora, layer_cache, window):
        n = jax.tree_util.tree_leaves(layer_params)[0].shape[0]
        lo = layer_lora if layer_lora is not None else _broadcast_none(n)
        if layer_cache is None:
            def body_nc(carry, inp):
                p, l = inp
                (xo, aux), _ = attn_body_factory(window)(carry, (p, l, None))
                return (xo, aux), None
            (x, aux), _ = _scan_layers(body_nc, (x, jnp.zeros((), jnp.float32)),
                                       (layer_params, lo), remat=remat)
            return x, aux, None
        (x, aux), ncache = _scan_layers(
            attn_body_factory(window), (x, jnp.zeros((), jnp.float32)),
            (layer_params, lo, layer_cache), remat=False)
        return x, aux, ncache

    if fam in ("dense", "vlm") and not cfg.local_global_ratio:
        x, aux, nc = run_stack(x, params["layers"], lora.get("layers"),
                               None if cache is None else cache["layers"],
                               cfg.sliding_window)
        aux_total += aux
        new_cache = None if cache is None else {"layers": nc}

    elif fam in ("dense", "vlm"):
        # gemma3: scan over periods of (5 local + 1 global)
        def period_body(carry, inp):
            xc, aux = carry
            pp, lo, ca = inp
            local_ca = None if ca is None else ca["local"]
            # inner scan over the local layers of this period
            def local_body(c2, inp2):
                p2, l2, ca2 = inp2
                x2, a2 = c2
                xo, nc2, a = _attn_mlp_layer(
                    cfg, p2, x2, lora=l2, lora_scale=lora_scale,
                    positions=positions, window=cfg.local_window, cache=ca2,
                    decode_position=dpos, moe_impl=moe_impl, block_size=block_size)
                return (xo, a2 + a), nc2
            nlocal = cfg.local_global_ratio
            lo_local = lo["local"] if lo is not None else _broadcast_none(nlocal)
            if local_ca is None:
                def lb(c2, inp2):
                    p2, l2 = inp2
                    (xo, a2), _ = local_body(c2, (p2, l2, None))
                    return (xo, a2), None
                (xc, aux), _ = jax.lax.scan(lb, (xc, aux), (pp["local"], lo_local))
                nc_local = None
            else:
                (xc, aux), nc_local = jax.lax.scan(
                    local_body, (xc, aux), (pp["local"], lo_local, local_ca))
            xo, nc_glob, a = _attn_mlp_layer(
                cfg, pp["global"], xc,
                lora=None if lo is None else lo["global"],
                lora_scale=lora_scale, positions=positions, window=0,
                cache=None if ca is None else ca["global"],
                decode_position=dpos, moe_impl=moe_impl, block_size=block_size)
            ys = None if ca is None else {"local": nc_local, "global": nc_glob}
            return (xo, aux + a), ys

        pp = params["periods"]
        nper = jax.tree_util.tree_leaves(pp["global"])[0].shape[0]
        lo = lora.get("periods")
        if lo is None:
            lo = _broadcast_none(nper)
        ca = None if cache is None else {"local": cache["local"], "global": cache["global"]}
        if ca is None:
            def pb(c, inp):
                p_, l_ = inp
                (xo, a), _ = period_body(c, (p_, l_, None))
                return (xo, a), None
            (x, aux_total), _ = _scan_layers(pb, (x, aux_total), (pp, lo), remat=remat)
        else:
            (x, aux_total), nc = _scan_layers(period_body, (x, aux_total),
                                              (pp, lo, ca), remat=False)
            new_cache = nc

    elif fam == "moe":
        new_cache = {} if cache is not None else None
        if cfg.first_k_dense:
            x, aux, nc = run_stack(x, params["dense_layers"], lora.get("dense_layers"),
                                   None if cache is None else cache["dense_layers"],
                                   cfg.sliding_window)
            aux_total += aux
            if cache is not None:
                new_cache["dense_layers"] = nc
        x, aux, nc = run_stack(x, params["layers"], lora.get("layers"),
                               None if cache is None else cache["layers"],
                               cfg.sliding_window)
        aux_total += aux
        if cache is not None:
            new_cache["layers"] = nc

    elif fam == "hybrid":
        shared_p = params["shared_attn"]
        shared_lo = lora.get("shared_attn")

        def hperiod_body(carry, inp):
            xc, aux = carry
            pp, lo, ca = inp
            m_ca = None if ca is None else ca["mamba"]

            def mbody(c2, inp2):
                p2, l2, ca2 = inp2
                xo, nc2 = _mamba_layer(cfg, p2, c2, lora=l2, lora_scale=lora_scale,
                                       cache=ca2, decode=decode)
                return xo, nc2

            nm = jax.tree_util.tree_leaves(pp)[0].shape[0]
            lo_m = lo if lo is not None else _broadcast_none(nm)
            if m_ca is None:
                def mb(c2, inp2):
                    p2, l2 = inp2
                    xo, _ = mbody(c2, (p2, l2, None))
                    return xo, None
                xc, _ = jax.lax.scan(mb, xc, (pp, lo_m))
                nc_m = None
            else:
                xc, nc_m = jax.lax.scan(mbody, xc, (pp, lo_m, m_ca))
            xo, nc_a, a = _attn_mlp_layer(
                cfg, shared_p, xc, lora=shared_lo, lora_scale=lora_scale,
                positions=positions, window=0,
                cache=None if ca is None else ca["attn"],
                decode_position=dpos, moe_impl=moe_impl, block_size=block_size)
            ys = None if ca is None else {"mamba": nc_m, "attn": nc_a}
            return (xo, aux + a), ys

        pp = params["mamba_layers"]
        nper = jax.tree_util.tree_leaves(pp)[0].shape[0]
        lo = lora.get("mamba_layers")
        if lo is None:
            lo = _broadcast_none(nper)
        ca = None if cache is None else {"mamba": cache["mamba"], "attn": cache["shared_attn"]}
        if ca is None:
            def hb(c, inp):
                p_, l_ = inp
                (xo, a), _ = hperiod_body(c, (p_, l_, None))
                return (xo, a), None
            (x, aux_total), _ = _scan_layers(hb, (x, aux_total), (pp, lo), remat=remat)
        else:
            (x, aux_total), nc = _scan_layers(hperiod_body, (x, aux_total),
                                              (pp, lo, ca), remat=False)
            new_cache = {"mamba": nc["mamba"], "shared_attn": nc["attn"]}
        if "mamba_trailing" in params:
            tp = params["mamba_trailing"]
            nt = jax.tree_util.tree_leaves(tp)[0].shape[0]
            lo_t = lora.get("mamba_trailing") or _broadcast_none(nt)
            t_ca = None if cache is None else cache["mamba_trailing"]
            if t_ca is None:
                def tb(c, inp):
                    p_, l_ = inp
                    xo, _ = _mamba_layer(cfg, p_, c, lora=l_, lora_scale=lora_scale,
                                         cache=None, decode=decode)
                    return xo, None
                body = jax.checkpoint(tb) if remat else tb
                x, _ = jax.lax.scan(body, x, (tp, lo_t))
            else:
                def tb2(c, inp):
                    p_, l_, ca_ = inp
                    return _mamba_layer(cfg, p_, c, lora=l_, lora_scale=lora_scale,
                                        cache=ca_, decode=decode)
                x, nc_t = jax.lax.scan(tb2, x, (tp, lo_t, t_ca))
                new_cache["mamba_trailing"] = nc_t

    elif fam == "ssm":  # xlstm
        def xperiod_body(carry, inp):
            xc = carry
            pp, lo, ca = inp
            m_ca = None if ca is None else ca["mlstm"]

            def mbody(c2, inp2):
                p2, l2, ca2 = inp2
                return xlstm_mod.mlstm_block(cfg, p2, c2, lora=l2,
                                             lora_scale=lora_scale, cache=ca2,
                                             decode=decode)

            nm = jax.tree_util.tree_leaves(pp["mlstm"])[0].shape[0]
            lo_m = (lo or {}).get("mlstm") if lo is not None else None
            lo_m = lo_m if lo_m is not None else _broadcast_none(nm)
            if m_ca is None:
                def mb(c2, inp2):
                    p2, l2 = inp2
                    xo, _ = mbody(c2, (p2, l2, None))
                    return xo, None
                xc, _ = jax.lax.scan(mb, xc, (pp["mlstm"], lo_m))
                nc_m = None
            else:
                xc, nc_m = jax.lax.scan(mbody, xc, (pp["mlstm"], lo_m, m_ca))
            xo, nc_s = xlstm_mod.slstm_block(
                cfg, pp["slstm"], xc,
                lora=None if lo is None else lo.get("slstm"),
                lora_scale=lora_scale,
                cache=None if ca is None else ca["slstm"], decode=decode)
            ys = None if ca is None else {"mlstm": nc_m, "slstm": nc_s}
            return xo, ys

        pp = params["periods"]
        nper = jax.tree_util.tree_leaves(pp["slstm"])[0].shape[0]
        lo = lora.get("periods") or _broadcast_none(nper)
        ca = None if cache is None else {"mlstm": cache["mlstm"], "slstm": cache["slstm"]}
        if ca is None:
            def xb(c, inp):
                p_, l_ = inp
                xo, _ = xperiod_body(c, (p_, l_, None))
                return xo, None
            x, _ = _scan_layers(xb, x, (pp, lo), remat=remat)
        else:
            x, nc = _scan_layers(xperiod_body, x, (pp, lo, ca), remat=False)
            new_cache = nc
    else:
        raise ValueError(f"forward: unsupported family {fam!r}")

    x = apply_norm(cfg.norm, params["final_norm"], x)
    tied = params["embed"]["embedding"] if cfg.tie_embeddings else None
    logits = unembed(params.get("lm_head", {}), x, tied_embedding=tied,
                     lora=(lora or {}).get("lm_head"), lora_scale=lora_scale)
    return logits, aux_total, new_cache


def _broadcast_none(n: int):
    # scanning over a None pytree: jax treats None as an empty pytree, which is
    # valid as a scan xs — every slice is None.
    return None
