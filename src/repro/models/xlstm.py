"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory) blocks.

mLSTM training path is *chunkwise-parallel* with full log-space stabilization:
within-chunk quadratic (decay-masked attention-like) + inter-chunk recurrence
over the stabilized matrix memory ``(C, n, m)`` via ``lax.scan``. Decode is the
O(1) recurrent update. sLSTM is a true recurrence (``lax.scan`` over time) with
block-diagonal per-head recurrent weights and exponential-gate stabilization.

Block layout follows the paper: mLSTM blocks are pre-LN up-projected (factor
``ssm_expand``) with causal-conv q/k path and output gating; sLSTM blocks are
post-normed with a gated FFN (factor 4/3). ``slstm_every`` controls the period
(xLSTM[7:1] → one sLSTM per 8 blocks).

LoRA targets: ``up_proj``/``down_proj`` (mLSTM) and the gate input projections
(sLSTM) — all frozen matmuls, so FedEx-LoRA aggregation applies unchanged.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense, make_dense_params, maybe_lora, normal_init
from repro.models.ssm import _causal_conv


# ==========================================================================
# mLSTM cell
# ==========================================================================

def mlstm_step(state, q, k, v, i_pre, lf):
    """One stabilized recurrent step.

    state: (C (B,H,Dk,Dv), n (B,H,Dk), m (B,H))
    q,k,v: (B,H,D); i_pre, lf: (B,H)  [lf = log f = logsigmoid(f_pre)]
    """
    C, n, m = state
    m_new = jnp.maximum(lf + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(lf + m - m_new)
    C_new = f_s[..., None, None] * C + i_s[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = f_s[..., None] * n + i_s[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    den = jnp.maximum(den, jnp.exp(-m_new))
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def mlstm_chunked(q, k, v, i_pre, lf, *, chunk: int = 256, state=None):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B, S, H, D) (k pre-scaled by D^-0.5); i_pre, lf: (B, S, H).
    state: optional (C, n, m). Returns (h (B,S,H,D), final_state).
    """
    bsz, s, h, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    qc = q.reshape(bsz, nc, chunk, h, d).transpose(1, 0, 3, 2, 4)  # (NC,B,H,L,D)
    kc = k.reshape(bsz, nc, chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(bsz, nc, chunk, h, d).transpose(1, 0, 3, 2, 4)
    ic = i_pre.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)  # (NC,B,H,L)
    lfc = lf.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)

    if state is None:
        state = (
            jnp.zeros((bsz, h, d, d), jnp.float32),
            jnp.zeros((bsz, h, d), jnp.float32),
            jnp.full((bsz, h), -jnp.inf, jnp.float32),
        )

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inputs):
        C, n, m = carry
        qb, kb, vb, ib, lfb = inputs  # (B,H,L,D) / (B,H,L)
        b_cum = jnp.cumsum(lfb, axis=-1)  # (B,H,L) inclusive
        # D_ij = b_i - b_j + i_j (j <= i)
        dmat = b_cum[..., :, None] - b_cum[..., None, :] + ib[..., None, :]
        dmat = jnp.where(causal, dmat, -jnp.inf)
        state_scale = b_cum + m[..., None]  # (B,H,L): log-scale of state branch
        m_i = jnp.maximum(dmat.max(axis=-1), state_scale)
        m_i = jnp.maximum(m_i, -1e30)  # keep finite when everything is empty
        w = jnp.exp(dmat - m_i[..., None])  # (B,H,L,L)
        sc = jnp.einsum("bhld,bhmd->bhlm", qb, kb) * w
        num_intra = jnp.einsum("bhlm,bhmv->bhlv", sc, vb)
        # normalizer via n-vector: den_i = q_i · (Σ_j w_ij k_j + state_w_i n)
        n_intra = jnp.einsum("bhlm,bhmd->bhld", w, kb)
        state_w = jnp.exp(state_scale - m_i)  # (B,H,L)
        num = num_intra + state_w[..., None] * jnp.einsum("bhld,bhdv->bhlv", qb, C)
        n_comb = n_intra + state_w[..., None] * n[..., None, :]
        den = jnp.abs(jnp.einsum("bhld,bhld->bhl", qb, n_comb))
        den = jnp.maximum(den, jnp.exp(-m_i))
        hout = num / den[..., None]  # (B,H,L,D)

        # ---- state update to chunk end ----
        b_tot = b_cum[..., -1]  # (B,H)
        g = b_tot[..., None] - b_cum + ib  # (B,H,L): decay j→L + input gate
        m_next = jnp.maximum(b_tot + m, g.max(axis=-1))
        m_next = jnp.maximum(m_next, -1e30)
        w_state = jnp.exp(g - m_next[..., None])  # (B,H,L)
        C_next = jnp.exp(b_tot + m - m_next)[..., None, None] * C + jnp.einsum(
            "bhl,bhld,bhlv->bhdv", w_state, kb, vb)
        n_next = jnp.exp(b_tot + m - m_next)[..., None] * n + jnp.einsum(
            "bhl,bhld->bhd", w_state, kb)
        return (C_next, n_next, m_next), hout

    final_state, hs = jax.lax.scan(body, state, (qc.astype(jnp.float32),
                                                 kc.astype(jnp.float32),
                                                 vc.astype(jnp.float32),
                                                 ic.astype(jnp.float32),
                                                 lfc.astype(jnp.float32)))
    # hs: (NC, B, H, L, D) → (B, S, H, D)
    hs = hs.transpose(1, 0, 3, 2, 4).reshape(bsz, s, h, d)
    return hs, final_state


# ==========================================================================
# mLSTM block
# ==========================================================================

def _xlstm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = cfg.num_heads
    d_head = d_inner // nheads
    return d_inner, nheads, d_head


def make_mlstm_params(rng, cfg) -> Params:
    d = cfg.d_model
    d_inner, nheads, d_head = _xlstm_dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    return {
        "norm": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "up_proj": make_dense_params(ks[0], d, 2 * d_inner, dtype),
        "conv": {
            "kernel": normal_init(ks[1], (4, d_inner), dtype, stddev=0.1),
            "bias": jnp.zeros((d_inner,), dtype),
        },
        "q_proj": make_dense_params(ks[2], d_inner, d_inner, dtype),
        "k_proj": make_dense_params(ks[3], d_inner, d_inner, dtype),
        "v_proj": make_dense_params(ks[4], d_inner, d_inner, dtype),
        "gate_proj": make_dense_params(ks[5], d_inner, 2 * nheads, dtype),
        "head_norm": {"scale": jnp.ones((d_inner,), dtype)},
        "down_proj": make_dense_params(ks[6], d_inner, d, dtype),
    }


def init_mlstm_cache(batch: int, cfg, dtype=jnp.bfloat16) -> Params:
    d_inner, nheads, d_head = _xlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nheads, d_head, d_head), jnp.float32),
        "n": jnp.zeros((batch, nheads, d_head), jnp.float32),
        "m": jnp.full((batch, nheads), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_inner), dtype),
    }


def _per_head_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, nheads: int,
                      eps: float = 1e-6) -> jnp.ndarray:
    """GroupNorm-style per-head RMS norm over (B, S, H*Dh)."""
    b, s, d = x.shape
    xf = x.astype(jnp.float32).reshape(b, s, nheads, d // nheads)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = (xf * jax.lax.rsqrt(var + eps)).reshape(b, s, d)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_block(cfg, params: Params, x: jnp.ndarray, *,
                lora: Optional[Params] = None, lora_scale: float = 0.0,
                cache: Optional[Params] = None, decode: bool = False,
                chunk: int = 256) -> Tuple[jnp.ndarray, Optional[Params]]:
    from repro.models.common import apply_norm

    bsz, s, _ = x.shape
    d_inner, nheads, d_head = _xlstm_dims(cfg)

    xn = apply_norm("layernorm", params["norm"], x)
    up = dense(xn, params["up_proj"], maybe_lora(lora, "up_proj"), lora_scale)
    x_in, z = up[..., :d_inner], up[..., d_inner:]

    conv_state = cache["conv"] if cache is not None else None
    x_conv, new_conv = _causal_conv(x_in, params["conv"]["kernel"],
                                    params["conv"]["bias"], conv_state)

    q = dense(x_conv, params["q_proj"], maybe_lora(lora, "q_proj"), lora_scale)
    k = dense(x_conv, params["k_proj"], maybe_lora(lora, "k_proj"), lora_scale)
    v = dense(x_in, params["v_proj"], maybe_lora(lora, "v_proj"), lora_scale)
    gates = dense(x_conv, params["gate_proj"], None, 0.0).astype(jnp.float32)
    i_pre = gates[..., :nheads]
    lf = jax.nn.log_sigmoid(gates[..., nheads:])

    qh = q.reshape(bsz, s, nheads, d_head).astype(jnp.float32)
    kh = k.reshape(bsz, s, nheads, d_head).astype(jnp.float32) * (d_head ** -0.5)
    vh = v.reshape(bsz, s, nheads, d_head).astype(jnp.float32)

    if decode:
        assert s == 1 and cache is not None
        state = (cache["C"], cache["n"], cache["m"])
        state, h = mlstm_step(state, qh[:, 0], kh[:, 0], vh[:, 0],
                              i_pre[:, 0], lf[:, 0])
        h = h[:, None]
        new_cache = {"C": state[0], "n": state[1], "m": state[2], "conv": new_conv}
    else:
        state = None
        if cache is not None:
            state = (cache["C"], cache["n"], cache["m"])
        pad = (-s) % chunk
        if pad:
            qh = jnp.pad(qh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kh = jnp.pad(kh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vh = jnp.pad(vh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            i_pre_p = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
            lf_p = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        else:
            i_pre_p, lf_p = i_pre, lf
        h, state = mlstm_chunked(qh, kh, vh, i_pre_p, lf_p, chunk=chunk, state=state)
        h = h[:, :s]
        new_cache = None if cache is None else {
            "C": state[0], "n": state[1], "m": state[2], "conv": new_conv}

    h = h.reshape(bsz, s, d_inner).astype(x.dtype)
    h = _per_head_rmsnorm(h, params["head_norm"]["scale"], nheads)
    h = h * jax.nn.silu(z)
    out = x + dense(h, params["down_proj"], maybe_lora(lora, "down_proj"), lora_scale).astype(x.dtype)
    return out, new_cache


# ==========================================================================
# sLSTM
# ==========================================================================

def make_slstm_params(rng, cfg) -> Params:
    d = cfg.d_model
    nheads = cfg.num_heads
    d_head = d // nheads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 7)
    ff = int(d * 4 / 3)
    return {
        "norm": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "w_gates": make_dense_params(ks[0], d, 4 * d, dtype),  # z,i,f,o stacked
        "r_gates": normal_init(ks[1], (4, nheads, d_head, d_head), dtype, stddev=0.05),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "head_norm": {"scale": jnp.ones((d,), dtype)},
        "ffn_norm": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "ffn": {
            "up_proj": make_dense_params(ks[2], d, ff, dtype),
            "gate_proj": make_dense_params(ks[3], d, ff, dtype),
            "down_proj": make_dense_params(ks[4], ff, d, dtype),
        },
    }


def init_slstm_cache(batch: int, cfg, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), dtype),
    }


def slstm_step(params: Params, state: Dict, x_t: jnp.ndarray, nheads: int):
    """x_t: (B, 4d) pre-computed input gate pre-activations W x + b."""
    c, n, m, h_prev = state["c"], state["n"], state["m"], state["h"]
    b, d = c.shape
    d_head = d // nheads
    hp = h_prev.astype(jnp.float32).reshape(b, nheads, d_head)
    rec = jnp.einsum("ghij,bhj->gbhi", params["r_gates"].astype(jnp.float32), hp)
    rec = rec.reshape(4, b, d)
    pre = x_t.astype(jnp.float32).reshape(b, 4, d).transpose(1, 0, 2) + rec
    z = jnp.tanh(pre[0])
    i_pre = pre[1]
    lf = jax.nn.log_sigmoid(pre[2])
    o = jax.nn.sigmoid(pre[3])
    m_new = jnp.maximum(lf + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(lf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new.astype(h_prev.dtype)}, h_new


def slstm_block(cfg, params: Params, x: jnp.ndarray, *,
                lora: Optional[Params] = None, lora_scale: float = 0.0,
                cache: Optional[Params] = None, decode: bool = False
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    from repro.models.common import apply_norm
    from repro.models.mlp import mlp_block

    bsz, s, d = x.shape
    nheads = cfg.num_heads
    xn = apply_norm("layernorm", params["norm"], x)
    pre = dense(xn, params["w_gates"], maybe_lora(lora, "w_gates"), lora_scale)
    pre = pre.astype(jnp.float32) + params["b_gates"]

    state = cache if cache is not None else init_slstm_cache(bsz, cfg, x.dtype)

    if decode:
        assert s == 1
        new_state, h = slstm_step(params, state, pre[:, 0], nheads)
        hs = h[:, None]
    else:
        def body(st, x_t):
            st2, h = slstm_step(params, st, x_t, nheads)
            return st2, h
        new_state, hs = jax.lax.scan(body, state, pre.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)

    hs = _per_head_rmsnorm(hs.astype(x.dtype), params["head_norm"]["scale"], nheads)
    y = x + hs
    yn = apply_norm("layernorm", params["ffn_norm"], y)
    ff = mlp_block(cfg, params["ffn"], yn, lora=(lora or {}).get("ffn"), lora_scale=lora_scale)
    out = (y + ff).astype(x.dtype)
    new_cache = new_state if cache is not None else None
    return out, new_cache
