"""obs — round-lifecycle tracing + metrics for the federation stack.

Dependency-free observability in three pieces:

* :mod:`repro.obs.tracer` — host-side spans (``perf_counter_ns``,
  thread-aware) nested with ``jax.profiler.TraceAnnotation`` device
  annotations, exported as Chrome trace-event JSON (Perfetto-loadable) and a
  JSONL event stream.
* :mod:`repro.obs.metrics` — typed counters / gauges / histograms behind a
  get-or-create registry.
* :mod:`repro.obs.recorder` — the facade every layer records through:
  ``make_recorder("off")`` returns the shared zero-overhead :data:`NULL`
  no-op, ``"basic"`` collects metrics + per-round records, ``"trace"`` adds
  spans. The per-round record is the unit ``scripts/obs_report.py``
  summarizes: close latency split into dispatch vs block-until-ready, ring
  occupancy/evictions/stale drops, sampled/straggler/dropout/delivered
  client counts, ledger bytes reconciled against core/comm.py, resolved
  divergence, compile-cache hits/misses.

Instrumented layers: fedsrv/coordinator.py (round open → uplinks →
quorum/deadline → close → downlink as nested spans, async commit/staleness
events), core/engine.py (close dispatch; DeferredDivergence resolution as
its own span), engine.RoundBuffers (begin/write/take/evict),
fedsrv/transport.py (encode/decode byte counts), core/federated.py +
launch/mesh_train.py (trainer round loop). Wired up via
``FedConfig.obs = off|basic|trace`` and the launcher's ``--obs`` /
``--trace`` / ``--metrics-out`` flags.

The overlap invariant this layer proves from span timestamps (the host-side
counterpart of ROADMAP's TPU-profile item): round N+1 ``ring.write`` span
intervals intersect round N's close window [``close.dispatch`` start,
``divergence.resolve`` end] — the ring genuinely streams the next round's
uplinks while the previous close is in flight.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.recorder import (NULL, OBS_MODES, NullRecorder, Recorder,
                                make_recorder)
from repro.obs.tracer import Span, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL",
    "NullRecorder",
    "OBS_MODES",
    "Recorder",
    "Span",
    "Tracer",
    "make_recorder",
]
