"""Typed metric primitives + registry (counters, gauges, histograms).

The registry is get-or-create by name with type checking — asking for an
existing name with a different metric type raises, so a counter can never be
silently shadowed by a gauge. ``snapshot()`` flattens everything into plain
dicts for the JSONL metrics stream (obs.recorder) and the end-of-run summary.

Names are dotted, lowest-level component last: ``ring.evictions``,
``engine.compile_miss[fedex]``, ``transport.uplink_bytes`` — the full table
lives in docs/observability.md.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Union

Number = Union[int, float]


class Counter:
    """Monotonically increasing count (events, bytes, cache misses)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (n={n})")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (ring occupancy, in-flight count)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v


class Histogram:
    """Streaming summary of observations (latencies): count/sum/min/max/mean
    plus an exact mean-of-squares for the stddev — no buckets, no deps."""

    __slots__ = ("name", "count", "total", "sq_total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.sq_total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: Number) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.sq_total += v * v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        mean = self.total / self.count
        var = max(self.sq_total / self.count - mean * mean, 0.0)
        return {"count": self.count, "sum": self.total, "mean": mean,
                "min": self.min, "max": self.max, "std": math.sqrt(var)}


class MetricsRegistry:
    """Get-or-create store of named metrics with type enforcement."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, requested as "
                f"{cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def hist(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {"counters": {}, "gauges": {},
                                          "histograms": {}}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        return out
