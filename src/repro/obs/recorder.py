"""The recorder facade: one object every layer records through.

``make_recorder(mode)`` returns one of:

* ``off``   → the shared :data:`NULL` no-op recorder (a few attribute reads
  and no-op calls per round — nothing is allocated, timed, or stored);
* ``basic`` → metrics only: counters/gauges/histograms + per-round records
  (close latency, ring/ledger stats) with NO span collection;
* ``trace`` → everything in basic plus host spans (obs.tracer) nested with
  ``jax.profiler.TraceAnnotation`` device annotations, exportable as Chrome
  trace-event JSON.

Per-round records are keyed by ``(run, round_id)`` — ``set_run(label)``
namespaces rounds when one process drives several runs (the scenario demo,
sweeps), so round 0 of scenario 2 never merges into round 0 of scenario 1.

The JSONL metrics stream (``write_metrics``) is the contract consumed by
``scripts/obs_report.py``: one JSON object per line with a ``type`` field —
``meta`` (jax/device info), ``counters`` (the registry snapshot), ``round``
(one per (run, round)), ``span`` / ``event`` (trace mode only, timestamps in
µs relative to the tracer origin).
"""

from __future__ import annotations

import json
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import Tracer

OBS_MODES = ("off", "basic", "trace")


class _NullSpan:
    """Reusable no-op context manager (one shared instance, zero allocs)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


class _NullMetric:
    """No-op stand-in for Counter/Gauge/Histogram (shared instance)."""

    __slots__ = ()
    value = 0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


_NULL_SPAN = _NullSpan()
_NULL_METRIC = _NullMetric()


class NullRecorder:
    """The ``obs=off`` recorder: every call is a no-op returning shared
    singletons. Instrumented code can call it unconditionally; hot paths may
    additionally guard on ``recorder.enabled`` to skip building kwargs."""

    enabled = False
    tracing = False
    mode = "off"
    run: Optional[str] = None

    def set_run(self, label: Optional[str]) -> None:
        pass

    def span(self, name: str, cat: str = "host", **args) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, cat: str = "host", **args) -> None:
        pass

    def counter(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def hist(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def round_set(self, round_id, **fields) -> None:
        pass

    def round_inc(self, round_id, key: str, n=1) -> None:
        pass

    def round_records(self) -> List[Dict[str, Any]]:
        return []

    def write_trace(self, path: str) -> None:
        pass

    def write_metrics(self, path: str) -> None:
        pass


NULL = NullRecorder()


class Recorder:
    """Live recorder: metrics registry + per-round records (+ tracer)."""

    enabled = True

    def __init__(self, mode: str = "trace"):
        if mode not in ("basic", "trace"):
            raise ValueError(f"recorder mode must be basic|trace, got {mode!r}"
                             " (off → use obs.NULL / make_recorder)")
        self.mode = mode
        self.tracing = mode == "trace"
        self.tracer = Tracer(device_annotations=True) if self.tracing else None
        self.metrics = MetricsRegistry()
        self.run: Optional[str] = None
        # (run, round_id) → field dict, insertion-ordered
        self._rounds: "OrderedDict[Any, Dict[str, Any]]" = OrderedDict()
        self._created_ns = time.perf_counter_ns()

    # -- run namespacing ----------------------------------------------------
    def set_run(self, label: Optional[str]) -> None:
        """Namespace subsequent rounds/spans under ``label`` (multi-run
        processes: scenario demos, sweeps). ``None`` clears it."""
        self.run = label

    # -- spans / events -----------------------------------------------------
    def span(self, name: str, cat: str = "host", **args):
        if self.tracer is not None:
            return self.tracer.span(name, cat, run=self.run, **args)
        return _NULL_SPAN

    def event(self, name: str, cat: str = "host", **args) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, cat, run=self.run, **args)

    # -- metrics ------------------------------------------------------------
    def counter(self, name: str):
        return self.metrics.counter(name)

    def gauge(self, name: str):
        return self.metrics.gauge(name)

    def hist(self, name: str):
        return self.metrics.hist(name)

    # -- per-round records --------------------------------------------------
    def _round(self, round_id) -> Dict[str, Any]:
        key = (self.run, round_id)
        rec = self._rounds.get(key)
        if rec is None:
            rec = self._rounds[key] = {"run": self.run, "round": round_id}
        return rec

    def round_set(self, round_id, **fields) -> None:
        self._round(round_id).update(fields)

    def round_inc(self, round_id, key: str, n=1) -> None:
        rec = self._round(round_id)
        rec[key] = rec.get(key, 0) + n

    def round_records(self) -> List[Dict[str, Any]]:
        return [dict(rec) for rec in self._rounds.values()]

    # -- export -------------------------------------------------------------
    def write_trace(self, path: str, process_name: str = "repro") -> None:
        if self.tracer is None:
            raise ValueError("write_trace needs mode='trace' "
                             f"(recorder mode is {self.mode!r})")
        self.tracer.write_chrome_trace(path, process_name)

    def metrics_records(self) -> List[Dict[str, Any]]:
        """Every JSONL record, in stream order (meta, counters, rounds,
        then spans/events when tracing)."""
        out: List[Dict[str, Any]] = [
            {"type": "meta", "mode": self.mode, **_env_meta()},
            {"type": "counters", **self.metrics.snapshot()},
        ]
        for rec in self._rounds.values():
            out.append({"type": "round", **rec})
        if self.tracer is not None:
            for s in self.tracer.spans:
                out.append({"type": "span", "name": s["name"],
                            "cat": s["cat"], "run": s["run"],
                            "tid": s["tid"], "ts_us": s["ts"] / 1e3,
                            "dur_us": s["dur"] / 1e3, "args": s["args"]})
            for e in self.tracer.events:
                out.append({"type": "event", "name": e["name"],
                            "cat": e["cat"], "run": e["run"],
                            "tid": e["tid"], "ts_us": e["ts"] / 1e3,
                            "args": e["args"]})
        return out

    def write_metrics(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.metrics_records():
                f.write(json.dumps(rec) + "\n")

    def summary_lines(self) -> List[str]:
        """Human-readable end-of-run digest (the launcher logs these)."""
        snap = self.metrics.snapshot()
        lines = [f"obs mode={self.mode}: {len(self._rounds)} round record(s)"]
        for name, v in snap["counters"].items():
            lines.append(f"  counter {name} = {v}")
        for name, v in snap["gauges"].items():
            lines.append(f"  gauge   {name} = {v}")
        for name, s in snap["histograms"].items():
            if s.get("count"):
                lines.append(f"  hist    {name}: n={s['count']} "
                             f"mean={s['mean']:.1f} max={s['max']:.1f}")
        return lines


def _env_meta() -> Dict[str, Any]:
    try:
        import jax
        dev = jax.devices()[0]
        return {"jax_version": jax.__version__,
                "backend": jax.default_backend(),
                "device_kind": getattr(dev, "device_kind", str(dev)),
                "platform": dev.platform,
                "device_count": jax.device_count()}
    except Exception:  # pragma: no cover - jax is a hard dep of this repo
        return {}


def make_recorder(mode: str = "off"):
    """``off`` → the shared no-op :data:`NULL`; else a live Recorder."""
    if mode not in OBS_MODES:
        raise ValueError(f"obs mode must be one of {OBS_MODES}, got {mode!r}")
    if mode == "off":
        return NULL
    return Recorder(mode)
