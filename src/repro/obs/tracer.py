"""Host-side span tracer with Chrome trace-event export.

Spans are measured with ``time.perf_counter_ns`` (monotonic, ns resolution)
and tagged with the recording thread, so concurrent round work (a future RPC
server, background uplink decode) renders as separate tracks. In ``trace``
mode every host span additionally enters a ``jax.profiler.TraceAnnotation``
so the SAME span names show up nested inside device profiles captured with
``jax.profiler.trace`` — the host trace and the XLA trace share a vocabulary.

Export targets:

* **Chrome trace-event JSON** (``write_chrome_trace``): the ``traceEvents``
  array format, loadable in Perfetto (https://ui.perfetto.dev) or
  ``chrome://tracing``. Spans are complete events (``ph="X"`` with ``ts`` /
  ``dur`` in microseconds); instants are ``ph="i"``. Nesting is implicit —
  the viewer reconstructs it from containment of [ts, ts+dur) intervals per
  thread track.
* **JSONL records** (via obs.recorder): one JSON object per span/event, with
  timestamps in µs relative to the tracer's origin — the stream
  ``scripts/obs_report.py`` summarizes and checks the overlap invariant on.

No external dependencies; everything is stdlib + an optional jax import.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

try:  # device-side annotation (present in every supported jax)
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax always present in this repo
    _TraceAnnotation = None


class Span:
    """One in-flight span; a context manager recorded on exit.

    Created by :meth:`Tracer.span`; not reusable. Exceptions propagate (the
    span still records, so a trace shows where a round died).
    """

    __slots__ = ("_tracer", "name", "cat", "run", "args", "_start", "_ann")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 run: Optional[str], args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.run = run
        self.args = args
        self._start = 0
        self._ann = None

    def __enter__(self) -> "Span":
        if self._tracer.device_annotations and _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(self.name)
            self._ann.__enter__()
        self._start = self._tracer._now()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        end = self._tracer._now()
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
            self._ann = None
        self._tracer._record_span(self, self._start, end)


class Tracer:
    """Collects spans + instant events; exports Chrome trace-event JSON.

    All timestamps are ns relative to the tracer's construction time (so
    traces start near t=0 regardless of process uptime). Appends are
    GIL-atomic list ops — safe for multiple recording threads.
    """

    def __init__(self, device_annotations: bool = False):
        self.device_annotations = device_annotations
        self._t0 = time.perf_counter_ns()
        # recorded span dicts: name/cat/run/ts/dur (ns)/tid/args
        self.spans: List[Dict[str, Any]] = []
        # instant event dicts: name/cat/run/ts (ns)/tid/args
        self.events: List[Dict[str, Any]] = []
        self._tids: Dict[int, int] = {}  # thread ident → small track id
        self._tid_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _now(self) -> int:
        return time.perf_counter_ns() - self._t0

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._tid_lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _record_span(self, span: Span, start: int, end: int) -> None:
        self.spans.append({
            "name": span.name, "cat": span.cat, "run": span.run,
            "ts": start, "dur": end - start, "tid": self._tid(),
            "args": span.args,
        })

    # ------------------------------------------------------------------
    def span(self, name: str, cat: str = "host", run: Optional[str] = None,
             **args: Any) -> Span:
        """A new span context manager (records on exit)."""
        return Span(self, name, cat, run, args)

    def instant(self, name: str, cat: str = "host",
                run: Optional[str] = None, **args: Any) -> None:
        """Record a zero-duration instant event."""
        self.events.append({
            "name": name, "cat": cat, "run": run, "ts": self._now(),
            "tid": self._tid(), "args": args,
        })

    # ------------------------------------------------------------------
    def to_chrome(self, process_name: str = "repro") -> Dict[str, Any]:
        """The Chrome trace-event dict: ``{"traceEvents": [...]}``.

        Spans become complete events (``ph="X"``, µs), instants ``ph="i"``
        with thread scope. Thread-name metadata events label each track.
        """
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": process_name},
        }]
        for ident, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            events.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": f"host-{tid} ({ident})"},
            })
        for s in self.spans:
            args = dict(s["args"])
            if s["run"] is not None:
                args["run"] = s["run"]
            events.append({
                "name": s["name"], "cat": s["cat"], "ph": "X",
                "ts": s["ts"] / 1e3, "dur": s["dur"] / 1e3,
                "pid": 0, "tid": s["tid"], "args": args,
            })
        for e in self.events:
            args = dict(e["args"])
            if e["run"] is not None:
                args["run"] = e["run"]
            events.append({
                "name": e["name"], "cat": e["cat"], "ph": "i", "s": "t",
                "ts": e["ts"] / 1e3, "pid": 0, "tid": e["tid"], "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str,
                           process_name: str = "repro") -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(process_name), f)
