from repro.optim.adamw import AdamWState, adamw_update, clip_by_global_norm, init_adamw
from repro.optim.schedule import lr_at

__all__ = ["AdamWState", "adamw_update", "clip_by_global_norm", "init_adamw", "lr_at"]
