"""AdamW (decoupled weight decay, arXiv:1711.05101) over arbitrary pytrees.

The paper trains LoRA adapters with AdamW (Appendix B); in this framework the
optimizer state exists ONLY for the trainable (LoRA) tree — the frozen base
never gets moments, which is where LoRA's memory saving comes from.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any  # first moments (pytree like params)
    nu: Any  # second moments


def init_adamw(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    learning_rate: jnp.ndarray | float,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state). Grads may be lower precision; math is f32."""
    step = state.step + 1
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = beta1 * m + (1.0 - beta1) * g
        v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
        m_hat = m_new / b1c
        v_hat = v_new / b2c
        delta = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - learning_rate * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm
