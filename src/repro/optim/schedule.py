"""LR schedules: constant / linear / cosine with warmup (paper Appendix B)."""

from __future__ import annotations

import jax.numpy as jnp


def lr_at(step, *, base_lr: float, total_steps: int, warmup_ratio: float = 0.02,
          kind: str = "cosine", min_ratio: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warmup = jnp.maximum(1.0, warmup_ratio * total_steps)
    warm = step / warmup
    frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total_steps - warmup), 0.0, 1.0)
    if kind == "cosine":
        decay = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif kind == "linear":
        decay = min_ratio + (1 - min_ratio) * (1.0 - frac)
    elif kind == "constant":
        decay = jnp.ones_like(frac)
    else:
        raise ValueError(f"unknown schedule {kind!r}")
    return base_lr * jnp.where(step < warmup, warm, decay)
