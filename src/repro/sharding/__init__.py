from repro.sharding.specs import (
    CLIENT,
    batch_spec,
    cache_spec,
    client_axis_size,
    client_stack_spec,
    data_axes,
    param_spec,
    param_spec_serving,
    tree_shardings,
    tree_specs,
)

__all__ = [
    "CLIENT",
    "batch_spec",
    "cache_spec",
    "client_axis_size",
    "client_stack_spec",
    "data_axes",
    "param_spec",
    "param_spec_serving",
    "tree_shardings",
    "tree_specs",
]
