from repro.sharding.specs import (
    batch_spec,
    cache_spec,
    data_axes,
    param_spec,
    param_spec_serving,
    tree_shardings,
    tree_specs,
)

__all__ = [
    "batch_spec",
    "cache_spec",
    "data_axes",
    "param_spec",
    "param_spec_serving",
    "tree_shardings",
    "tree_specs",
]
