"""Activation-sharding context: explicit with_sharding_constraint hints that
model code applies when a launcher has configured mesh axes.

Why this exists (§Perf finding #1): GSPMD fails to shard GQA attention
internals when num_kv_heads < model-axis size (granite: kv=8 on a 16-way
axis) — the (kvh, group) reshape has no valid propagation, so XLA silently
REPLICATES the entire attention computation on every model-parallel device
(16× redundant FLOPs + activation bytes, confirmed in the granite-8b HLO).
The fix: repeat KV up to the head count when needed and pin the flattened
head axis to ``model`` explicitly.

Model code stays mesh-agnostic: constraints are no-ops unless a launcher
calls ``configure()`` (dryrun.py / train.py do; CPU tests never do).
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

_CTX = {"dp": None, "model": None, "model_size": 1, "enabled": False}


def configure(dp: Union[str, Tuple[str, ...], None], model: Optional[str],
              model_size: int) -> None:
    _CTX.update(dp=dp, model=model, model_size=model_size, enabled=True)


def reset() -> None:
    _CTX.update(dp=None, model=None, model_size=1, enabled=False)


@contextmanager
def configured(dp, model, model_size):
    configure(dp, model, model_size)
    try:
        yield
    finally:
        reset()


def enabled() -> bool:
    return _CTX["enabled"]


def model_size() -> int:
    return _CTX["model_size"]


def _resolve(axis):
    if axis == "dp":
        return _CTX["dp"]
    if axis == "model":
        return _CTX["model"]
    return axis


def constrain(x: jax.Array, spec: Sequence) -> jax.Array:
    """Apply a symbolic spec ('dp' / 'model' / None per dim); no-op unless
    configured. Dims whose size doesn't divide the axis stay unconstrained."""
    if not _CTX["enabled"]:
        return x
    resolved = []
    for dim, axis in zip(x.shape, spec):
        a = _resolve(axis)
        if a is None:
            resolved.append(None)
            continue
        size = _CTX["model_size"] if axis == "model" else None
        if size is not None and dim % size != 0:
            resolved.append(None)
        else:
            resolved.append(a)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
