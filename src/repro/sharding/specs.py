"""Parameter / activation / cache PartitionSpec rules (2D: FSDP × tensor).

Mesh axes: ``pod``/``data`` shard the batch; ``model`` shards tensor dims.
Weight matrices are 2D-sharded — tensor-parallel on ``model`` (Megatron
column→row pairs) AND fully-sharded on ``data`` over the other dim (ZeRO-3 /
FSDP) so 236B-class configs fit v5e HBM: deepseek-v2 = 472 GB bf16 →
472/(16·16) ≈ 1.8 GB/chip. The ``pod`` axis is pure data parallelism
(weights replicated across pods; only grad reduction crosses DCI).

KV caches shard the SEQUENCE dim on ``model`` (32k×128-batch caches are tens
of GB; attention reductions over a sharded S lower to psum) and the batch dim
on the data axes. SSM/xLSTM states shard heads on ``model`` where divisible.

LoRA factors stay replicated: rank-r is tiny and replication makes the FedEx
aggregation a pure psum-mean with no resharding (DESIGN §5).

Mesh-mode federated rounds (launch/mesh_train.py) add a ``client`` axis:
client-STACKED adapter/optimizer/batch leaves carry a leading ``(C_max, …)``
axis sharded over it (:func:`client_stack_spec`), so per-client local
training partitions lane-per-device-group and the round close's weighted
reductions over the client axis (``Σ_c w_c·…``, zero weight = masked lane)
lower to psum-mean collectives inside ONE pjit'd program — partial
participation and non-uniform weights only change the weight VECTOR, never
the program. Base params stay replicated across the client axis (every lane
fine-tunes the same frozen W0).

Every axis assignment is guarded by divisibility — non-divisible dims fall
back to replication rather than relying on GSPMD padding.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.util.tree import flatten_with_paths, unflatten_from_paths

MODEL = "model"
FSDP = "data"  # weights are additionally sharded over the data axis (ZeRO-3)
CLIENT = "client"  # mesh-mode federated rounds: leading client-stack axis

_COLUMN_MODULES = (
    "q_proj", "k_proj", "v_proj", "up_proj", "gate_proj", "in_proj",
    "w_gates", "q_down", "q_up", "k_up", "v_up", "kv_down", "lm_head",
    "vision_proj",
)
_ROW_MODULES = ("o_proj", "down_proj", "out_proj")
_EXPERT_TENSORS = ("up_proj", "gate_proj", "down_proj")

# matrices smaller than this on both dims stay replicated (sharding overhead
# beats the memory win for tiny matrices)
_MIN_SHARD_DIM = 512


def _axis_size(mesh: Mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return 1


def _ok(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= _axis_size(mesh, a)
    else:
        size = _axis_size(mesh, axis)
    return dim % size == 0 and dim >= size


def _guard(shape, mesh: Mesh, spec) -> P:
    out = []
    for dim, axis in zip(shape, spec):
        out.append(axis if _ok(dim, mesh, axis) else None)
    return P(*out)


def param_spec(path: str, leaf, mesh: Mesh) -> P:
    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) >= 2 else ""
    grandparent = parts[-3] if len(parts) >= 3 else ""
    shape = leaf.shape

    def tail_spec(tail):
        lead = (None,) * (leaf.ndim - len(tail))
        return _guard(shape, mesh, lead + tuple(tail))

    # LoRA factors: replicated
    if name in ("a", "b"):
        return P(*([None] * leaf.ndim))

    # MoE expert tensors — expert-parallel over `model` + FSDP on the
    # contracting dim (§Perf iterations 4–5, EXPERIMENTS.md):
    #   · ragged_dot + expert-sharded weights → GSPMD all-gathers the FULL
    #     expert weights every call (deepseek-v2: 45.8 TB/step, measured);
    #   · ragged_dot + ff-sharded weights → group_sizes are global, so GSPMD
    #     all-gathers token ROWS ×16 across data (6× redundant compute);
    #   · dense-dispatch einsum + expert sharding (this layout, with
    #     moe_impl="dense" in distributed runs) partitions cleanly: tokens
    #     stay data-sharded, experts stay model-sharded, only FSDP weight
    #     gathers (~0.5 GB/layer) + an 84 MB combine all-reduce move.
    # When E doesn't divide the model axis (mixtral: 8 experts on 16-way),
    # fall back to ff-on-model TP inside each expert (§Perf it. 6) — otherwise
    # the guard would silently REPLICATE 271 GB of expert weights per device
    # row and every decode step would re-read all of them.
    if parent == "experts" and name in _EXPERT_TENSORS and leaf.ndim >= 3:
        e_dim = leaf.shape[-3]
        if _ok(e_dim, mesh, MODEL):
            return tail_spec((MODEL, FSDP, None))
        if name == "down_proj":  # (E, ff, d)
            return tail_spec((None, MODEL, FSDP))
        return tail_spec((None, FSDP, MODEL))  # (E, d, ff)

    if parent == "router":
        return P(*([None] * leaf.ndim))

    if parent == "embed" and name == "embedding":
        return tail_spec((MODEL, FSDP))
    if parent in ("pos_embed", "enc_pos_embed") and name == "embedding":
        return tail_spec((None, FSDP))

    if parent == "conv":
        if name == "kernel":
            return tail_spec((None, MODEL))
        return tail_spec((MODEL,))

    if parent in _COLUMN_MODULES:
        if name == "kernel":
            d_in, d_out = shape[-2], shape[-1]
            fsdp = FSDP if min(d_in, d_out) >= _MIN_SHARD_DIM else None
            return tail_spec((fsdp, MODEL))
        if name == "bias":
            return tail_spec((MODEL,))
    if parent in _ROW_MODULES:
        if name == "kernel":
            d_in, d_out = shape[-2], shape[-1]
            fsdp = FSDP if min(d_in, d_out) >= _MIN_SHARD_DIM else None
            return tail_spec((MODEL, fsdp))
        if name == "bias":
            return P(*([None] * leaf.ndim))

    # norms, gates, per-head scalars, r_gates, b_gates, A_log, D, dt_bias …
    return P(*([None] * leaf.ndim))


# --------------------------------------------------------------------------
# caches — (name, base_rank, tail spec builder)
# --------------------------------------------------------------------------

def param_spec_serving(path: str, leaf, mesh: Mesh) -> P:
    """Decode-shape layout (§Perf iteration 7): weight-stationary.

    Training wants FSDP (re-gather weights per microbatch, amortised over
    thousands of tokens). A decode step touches every weight ONCE for a
    handful of tokens — re-gathering FSDP shards per step dominates
    (mixtral-8x22b decode_32k: 23.6 GB of all-gather per token, measured).
    Serving layout shards every large matrix over BOTH mesh axes: fully
    resident, zero per-step weight collectives; the tiny activations take the
    psum instead.
    """
    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) >= 2 else ""
    shape = leaf.shape
    both = ("data", "model")

    def tail_spec(tail):
        lead = (None,) * (leaf.ndim - len(tail))
        return _guard(shape, mesh, lead + tuple(tail))

    if name in ("a", "b"):
        return P(*([None] * leaf.ndim))
    if parent == "experts" and name in _EXPERT_TENSORS and leaf.ndim >= 3:
        # experts consume REPLICATED tokens at decode (moe_block constrains
        # them) → both axes available for weight residency.
        if name == "down_proj":  # (E, ff, d)
            return tail_spec((None, both, None))
        return tail_spec((None, None, both))  # (E, d, ff)
    if parent == "router":
        return P(*([None] * leaf.ndim))
    if parent == "embed" and name == "embedding":
        return tail_spec((MODEL, None))
    if parent in ("pos_embed", "enc_pos_embed") and name == "embedding":
        return tail_spec((None, None))
    if parent == "conv":
        return tail_spec((None, MODEL)) if name == "kernel" else tail_spec((MODEL,))
    # MLP weights are the residency bottleneck for 70B+ dense archs
    # (internvl2-76b: 66% of layer params; model-only sharding left 9.5 GB of
    # weights/device → 18.5 GiB peak, over v5e HBM). Both-axes sharding works
    # because mlp_block REPLICATES the (tiny) decode tokens, like the MoE path.
    if parent in ("up_proj", "gate_proj") and name == "kernel":
        return tail_spec((None, both))
    if parent == "down_proj" and name == "kernel":
        return tail_spec((both, None))
    # attention projections: batch stays data-sharded at decode, so only the
    # model axis is conflict-free (data+model sharding forces an 8.5 GB/step
    # o_proj gather — measured); attention weights are small enough resident.
    if parent in _COLUMN_MODULES:
        if name == "kernel":
            return tail_spec((None, MODEL))
        if name == "bias":
            return tail_spec((MODEL,))
    if parent in _ROW_MODULES:
        if name == "kernel":
            return tail_spec((MODEL, None))
        return P(*([None] * leaf.ndim))
    return P(*([None] * leaf.ndim))


def cache_spec(path: str, leaf, mesh: Mesh, dp) -> P:
    name = path.split("/")[-1]
    shape = leaf.shape
    rules = [
        ("k", 4, (dp, MODEL, None, None)),       # (B, S, KV, D): shard SEQ
        ("v", 4, (dp, MODEL, None, None)),
        ("pos", 1, (MODEL,)),                     # position slots follow S
        ("c_kv", 3, (dp, MODEL, None)),           # MLA latents: shard SEQ
        ("k_rope", 3, (dp, MODEL, None)),
        ("ssm", 4, (dp, MODEL, None, None)),      # (B, H, P, N): shard heads
        ("conv", 3, (dp, None, MODEL)),           # (B, K-1, C): shard channels
        ("C", 4, (dp, MODEL, None, None)),        # mLSTM memory: shard heads
        ("n", 3, (dp, MODEL, None)),
        ("n", 2, (dp, None)),
        ("m", 2, (dp, MODEL)),
        ("c", 2, (dp, None)),
        ("h", 2, (dp, None)),
    ]
    for rule_name, rank, tail in rules:
        if name == rule_name and leaf.ndim >= rank:
            lead = (None,) * (leaf.ndim - rank)
            return _guard(shape, mesh, lead + tuple(tail))
    return P(*([None] * leaf.ndim))


def batch_spec(path: str, leaf, mesh: Mesh, dp) -> P:
    return _guard(leaf.shape, mesh, (dp,) + (None,) * (leaf.ndim - 1))


def client_stack_spec(path: str, leaf, mesh: Mesh) -> P:
    """Client-STACKED leaves for mesh-mode federated rounds: the leading
    ``(C_max, …)`` axis shards over the ``client`` mesh axis; trailing dims
    stay replicated (LoRA factors are rank-r tiny — see module docstring).
    With this layout every ``Σ_c w_c · leaf[c]`` inside the close program
    lowers to a psum-mean over the client axis; zero-weight lanes (masked /
    non-sampled clients) contribute exact zeros, so the SAME compiled
    program serves full, sampled-subset and weighted rounds. Divisibility
    guard as everywhere: a C_max the client axis doesn't divide falls back
    to replication instead of GSPMD padding."""
    return _guard(leaf.shape, mesh, (CLIENT,) + (None,) * (leaf.ndim - 1))


def client_axis_size(mesh: Mesh) -> int:
    """Size of the ``client`` mesh axis (1 when the mesh has none)."""
    return _axis_size(mesh, CLIENT)


# --------------------------------------------------------------------------
# tree-level helpers
# --------------------------------------------------------------------------

def tree_specs(tree: Any, fn, *args) -> Any:
    flat = flatten_with_paths(tree)
    return unflatten_from_paths({p: fn(p, leaf, *args) for p, leaf in flat.items()})


def tree_shardings(tree: Any, mesh: Mesh, fn, *args) -> Any:
    specs = tree_specs(tree, fn, mesh, *args)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def data_axes(mesh: Mesh):
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return dp if len(dp) > 1 else dp[0]
