from repro.util.registry import Registry
from repro.util.tree import (
    tree_paths,
    flatten_with_paths,
    unflatten_from_paths,
    count_params,
    tree_bytes,
    tree_allclose,
)
from repro.util.logging import get_logger, MetricLogger

__all__ = [
    "Registry",
    "tree_paths",
    "flatten_with_paths",
    "unflatten_from_paths",
    "count_params",
    "tree_bytes",
    "tree_allclose",
    "get_logger",
    "MetricLogger",
]
