"""Structured stdout logging + CSV metric sink (no external deps)."""

from __future__ import annotations

import csv
import logging
import os
import sys
import time
from typing import Dict, Optional

_FORMAT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(os.environ.get("REPRO_LOGLEVEL", "INFO"))
        logger.propagate = False
    return logger


class MetricLogger:
    """Accumulates step metrics; optionally mirrors to a CSV file."""

    def __init__(self, csv_path: Optional[str] = None, logger_name: str = "metrics"):
        self.logger = get_logger(logger_name)
        self.csv_path = csv_path
        self._writer = None
        self._file = None
        self._fields: list = []
        self._t0 = time.time()
        self.history = []

    def _reopen(self) -> None:
        """(Re)write the CSV from scratch with the current field union —
        heterogeneous records (e.g. a round that adds eval metrics) used to
        crash DictWriter, whose fieldnames were frozen from the FIRST record."""
        if self._file:
            self._file.close()
        self._file = open(self.csv_path, "w", newline="")
        self._writer = csv.DictWriter(self._file, fieldnames=self._fields,
                                      restval="")
        self._writer.writeheader()
        for past in self.history:
            self._writer.writerow(past)

    def log(self, step: int, metrics: Dict[str, float]) -> None:
        rec = {"step": step, "wall_s": round(time.time() - self._t0, 3), **metrics}
        self.history.append(rec)
        msg = " ".join(f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}" for k, v in rec.items())
        self.logger.info(msg)
        if self.csv_path:
            new_keys = [k for k in rec if k not in self._fields]
            if new_keys or self._writer is None:
                # union-of-keys header: rewrite history under the new header
                # (records missing a column get ""), then stream as before
                self._fields += new_keys
                self._reopen()
            else:
                self._writer.writerow(rec)
            self._file.flush()

    def close(self) -> None:
        if self._file:
            self._file.close()
            self._file = None
            self._writer = None
