"""Minimal name → factory registry used for configs, models and aggregators."""

from __future__ import annotations

from typing import Callable, Dict, Generic, Iterator, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    """A string-keyed registry with decorator-style registration.

    >>> configs = Registry("configs")
    >>> @configs.register("tiny")
    ... def tiny():
    ...     return {"d_model": 8}
    >>> configs.get("tiny")()["d_model"]
    8
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, T] = {}

    def register(self, name: str) -> Callable[[T], T]:
        def deco(obj: T) -> T:
            if name in self._entries:
                raise KeyError(f"{self.kind}: duplicate registration {name!r}")
            self._entries[name] = obj
            return obj

        return deco

    def get(self, name: str) -> T:
        try:
            return self._entries[name]
        except KeyError:
            known = ", ".join(sorted(self._entries))
            raise KeyError(f"{self.kind}: unknown entry {name!r} (known: {known})") from None

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))

    def names(self):
        return sorted(self._entries)
