"""Pytree helpers: path flattening, parameter counting, size accounting."""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts: List[str] = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_paths(tree: Any) -> List[str]:
    """Sorted list of '/'-joined key paths for every leaf."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [_path_str(path) for path, _ in leaves]


def flatten_with_paths(tree: Any) -> Dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(path): leaf for path, leaf in leaves}


def unflatten_from_paths(flat: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`flatten_with_paths` for dict-of-dict trees."""
    out: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return out


def count_params(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        dt = getattr(x, "dtype", None)
        itemsize = jnp.dtype(dt).itemsize if dt is not None else 4
        total += int(np.prod(x.shape)) * itemsize
    return total


def tree_allclose(a: Any, b: Any, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol) for x, y in zip(la, lb))
