import dataclasses

import jax
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")
