"""Unit tests for the paper's aggregation operators (core/aggregation.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    apply_residual,
    assign_after_aggregation,
    fedex_aggregate,
    fedex_svd_aggregate,
    fedit_aggregate,
    per_client_residuals,
    product_mean,
    reconstruct,
    residual_factors,
    truncated_svd_product,
)
from repro.core.aggregation import map_factors


def make_client_loras(k=3, m=24, r=4, n=16, seed=0, layers=None):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(k):
        lead = () if layers is None else (layers,)
        out.append({
            "blk": {
                "q_proj": {
                    "a": jnp.asarray(rng.normal(size=lead + (m, r)), jnp.float32),
                    "b": jnp.asarray(rng.normal(size=lead + (r, n)), jnp.float32),
                },
            }
        })
    return out


def dense_update(lora):
    return jnp.matmul(lora["blk"]["q_proj"]["a"], lora["blk"]["q_proj"]["b"])


class TestFedExExactness:
    def test_fedex_equals_ideal(self):
        """Eq. 7–9: global + residual == mean of client products."""
        loras = make_client_loras()
        g, res = fedex_aggregate(loras)
        ideal = sum(dense_update(l) for l in loras) / len(loras)
        got = dense_update(g) + res["blk"]["q_proj"]
        np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-6)

    def test_fedit_is_inexact(self):
        loras = make_client_loras()
        g = fedit_aggregate(loras)
        ideal = sum(dense_update(l) for l in loras) / len(loras)
        assert float(jnp.abs(dense_update(g) - ideal).max()) > 1e-3

    def test_stacked_layers(self):
        loras = make_client_loras(layers=5)
        g, res = fedex_aggregate(loras)
        ideal = sum(dense_update(l) for l in loras) / len(loras)
        got = dense_update(g) + res["blk"]["q_proj"]
        assert got.shape == (5, 24, 16)
        np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-6)

    def test_single_client_residual_zero(self):
        loras = make_client_loras(k=1)
        _, res = fedex_aggregate(loras)
        np.testing.assert_allclose(res["blk"]["q_proj"], 0.0, atol=1e-5)

    def test_identical_clients_residual_zero(self):
        l = make_client_loras(k=1)[0]
        _, res = fedex_aggregate([l, l, l])
        np.testing.assert_allclose(res["blk"]["q_proj"], 0.0, atol=1e-4)


class TestAssignmentStrategies:
    """Table 5: every strategy must be exact; they differ in (aᵢ, bᵢ)."""

    @pytest.mark.parametrize("strategy", ["average", "keep_local", "reinit"])
    def test_strategy_exactness(self, strategy):
        loras = make_client_loras()
        ideal = sum(dense_update(l) for l in loras) / len(loras)
        new_loras, residual = assign_after_aggregation(
            strategy, loras, jax.random.key(0))
        if strategy == "keep_local":
            residuals = per_client_residuals(loras)
            for lora_i, res_i in zip(new_loras, residuals):
                got = dense_update(lora_i) + res_i["blk"]["q_proj"]
                np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-5)
        else:
            for lora_i in new_loras:
                got = dense_update(lora_i) + residual["blk"]["q_proj"]
                np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-5)

    def test_reinit_b_is_zero(self):
        loras = make_client_loras()
        new_loras, _ = assign_after_aggregation("reinit", loras, jax.random.key(0))
        np.testing.assert_allclose(new_loras[0]["blk"]["q_proj"]["b"], 0.0)


class TestResidualDecomposition:
    def test_factored_form_exact(self):
        """§4.2 communication protocol: rank-(k+1)r factors are lossless."""
        loras = make_client_loras(m=32, n=20)
        _, res = fedex_aggregate(loras)
        factors = [l["blk"]["q_proj"] for l in loras]
        L, R = residual_factors(factors)
        assert L.shape[1] == (len(loras) + 1) * 4
        np.testing.assert_allclose(L @ R, res["blk"]["q_proj"], rtol=1e-5, atol=1e-5)

    def test_truncated_svd_is_optimal(self):
        """Eckart–Young: QR+small-SVD == dense SVD truncation."""
        loras = make_client_loras(k=4, m=40, n=28)
        _, res = fedex_aggregate(loras)
        dense = np.asarray(res["blk"]["q_proj"])
        factors = [l["blk"]["q_proj"] for l in loras]
        L, R = residual_factors(factors)
        for rank in (1, 3, 8):
            u, s, vt = truncated_svd_product(L, R, rank)
            approx = np.asarray(reconstruct(u, s, vt))
            u2, s2, vt2 = np.linalg.svd(dense, full_matrices=False)
            best = (u2[:, :rank] * s2[:rank]) @ vt2[:rank]
            np.testing.assert_allclose(
                np.linalg.norm(dense - approx),
                np.linalg.norm(dense - best), rtol=1e-4)

    def test_truncation_error_decreases_with_rank(self):
        loras = make_client_loras(k=4, m=40, n=28, seed=3)
        _, res = fedex_aggregate(loras)
        dense = np.asarray(res["blk"]["q_proj"])
        factors = [l["blk"]["q_proj"] for l in loras]
        L, R = residual_factors(factors)
        errs = []
        for rank in (1, 2, 4, 8, 16):
            u, s, vt = truncated_svd_product(L, R, rank)
            errs.append(np.linalg.norm(dense - np.asarray(reconstruct(u, s, vt))))
        assert all(e1 >= e2 - 1e-6 for e1, e2 in zip(errs, errs[1:]))
        assert errs[-1] < 1e-4  # full rank (k+1)*r=20 > 16 ≥ true rank ≤ 15…
        # rank (k+1)·r reconstructs exactly
        u, s, vt = truncated_svd_product(L, R, L.shape[1])
        assert np.linalg.norm(dense - np.asarray(reconstruct(u, s, vt))) < 1e-4


class TestApplyResidual:
    def test_apply_residual_adds_scaled(self):
        params = {"blk": {"q_proj": {"kernel": jnp.zeros((24, 16))}}}
        loras = make_client_loras()
        _, res = fedex_aggregate(loras)
        out = apply_residual(params, res, scale=0.5)
        np.testing.assert_allclose(out["blk"]["q_proj"]["kernel"],
                                   0.5 * res["blk"]["q_proj"], rtol=1e-6)

    def test_fedex_svd_aggregate_full_rank_is_exact(self):
        """r' = k·r (the residual's rank bound) reconstructs exactly."""
        loras = make_client_loras()
        g, res_t = fedex_svd_aggregate(loras, svd_rank=len(loras) * 4)
        _, res = fedex_aggregate(loras)
        np.testing.assert_allclose(res_t["blk"]["q_proj"], res["blk"]["q_proj"],
                                   rtol=1e-4, atol=1e-5)

    def test_fedex_svd_aggregate_rejects_degenerate_ranks(self):
        """r' ≤ 0 (silent rank-0 truncation) and r' > k·r (pure padding)
        both raise instead of falling through to a degenerate dense SVD."""
        loras = make_client_loras()
        with pytest.raises(ValueError, match="svd_rank"):
            fedex_svd_aggregate(loras, svd_rank=0)
        with pytest.raises(ValueError, match="rank bound"):
            fedex_svd_aggregate(loras, svd_rank=len(loras) * 4 + 1)
