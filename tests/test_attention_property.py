"""Property tests for the attention cores (hypothesis): flash custom-VJP vs
materialised oracle over random shapes / windows / GQA factors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models.attention import blockwise_attention, flash_attention


@settings(max_examples=15, deadline=None)
@given(
    sq=st.integers(8, 96),
    h_and_kv=st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4)]),
    window=st.sampled_from([0, 16, 51]),
    block=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_matches_oracle(sq, h_and_kv, window, block, seed):
    h, kv = h_and_kv
    d = 16
    rng = np.random.default_rng(seed)
    mk = lambda *sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    q, k, v = mk(2, sq, h, d), mk(2, sq, kv, d), mk(2, sq, kv, d)
    out = flash_attention(q, k, v, True, window, 0, block)
    kk = jnp.repeat(k, h // kv, axis=2)
    vv = jnp.repeat(v, h // kv, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(2 * h, sq, d)
    kf = kk.transpose(0, 2, 1, 3).reshape(2 * h, sq, d)
    vf = vv.transpose(0, 2, 1, 3).reshape(2 * h, sq, d)
    orc = ref.flash_swa_ref(qf, kf, vf, causal=True, window=window)
    orc = orc.reshape(2, h, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(orc),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    sq=st.integers(8, 48),
    window=st.sampled_from([0, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_grads_match_blockwise_ad(sq, window, seed):
    """custom-VJP backward == jax AD through the online-softmax scan."""
    rng = np.random.default_rng(seed)
    mk = lambda *sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    q, k, v = mk(1, sq, 2, 8), mk(1, sq, 2, 8), mk(1, sq, 2, 8)
    gf = jax.grad(lambda *t: (flash_attention(*t, True, window, 0, 16) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(lambda *t: (blockwise_attention(
        *t, causal=True, window=window, block_size=16) ** 2).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_flash_row_with_no_visible_keys_is_zero_not_nan():
    """Window smaller than the gap: fully-masked rows must yield 0, not NaN."""
    q = jnp.ones((1, 8, 1, 4))
    k = jnp.ones((1, 8, 1, 4))
    v = jnp.ones((1, 8, 1, 4))
    # q_offset far beyond keys + tiny window → every row masked
    out = flash_attention(q, k, v, True, 2, 1000, 8)
    assert bool(jnp.all(jnp.isfinite(out)))
