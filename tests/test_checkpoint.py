"""Checkpoint roundtrip tests (flat-path npz, bf16-aware)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.util.tree import tree_allclose


def test_roundtrip(tmp_path):
    tree = {
        "layers": {"attn": {"q_proj": {"kernel": jnp.arange(12.0).reshape(3, 4)}}},
        "scale": jnp.asarray([1.0, 2.0]),
        "step": jnp.asarray(7, jnp.int32),
    }
    p = str(tmp_path / "ckpt.npz")
    save_checkpoint(p, tree, meta={"round": 3, "method": "fedex"})
    loaded, meta = load_checkpoint(p)
    assert meta == {"round": 3, "method": "fedex"}
    assert tree_allclose(tree, loaded)
    assert loaded["step"].dtype == jnp.int32


def test_bf16_preserved(tmp_path):
    tree = {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16)}
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree)
    loaded, _ = load_checkpoint(p)
    assert loaded["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(loaded["w"], np.float32),
                               np.asarray(tree["w"], np.float32))


def test_federated_round_state(tmp_path):
    """Save/restore of (W0, lora, round meta) — the server's checkpoint."""
    from repro.configs import LoRAConfig, get_config
    from repro.core import init_lora
    from repro.models import build_model
    import dataclasses

    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lora = init_lora(jax.random.key(1), params, cfg, LoRAConfig(rank=2))
    p = str(tmp_path / "server.npz")
    save_checkpoint(p, {"params": params, "lora": lora},
                    meta={"round": 5, "method": "fedex"})
    loaded, meta = load_checkpoint(p)
    assert meta["round"] == 5
    assert tree_allclose(params, loaded["params"])
    assert tree_allclose(lora, loaded["lora"])
