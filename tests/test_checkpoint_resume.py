"""Crash-safe round state: kill a run at a round boundary, resume it, and
get the uninterrupted run BITWISE — params, global adapter, and history.

Contracts under test (robustness tentpole, part 3):

* ``FedConfig.checkpoint_dir`` makes the trainer snapshot coordinator +
  ring + BytesLedger + loader + clock state every ``checkpoint_every``
  round boundaries (``save_state`` / ``round_state_path``);
* a fresh trainer that ``load_state``s the snapshot and finishes the run
  matches the uninterrupted run bitwise — sync, FedBuff-async (in-flight
  uplinks + snapshot versions restored), and faulty (the fault coins key
  off absolute round indices, so resumed draws line up) — with a cosine LR
  schedule so the step counter restoring wrong would show up immediately;
* the component states (loader cursor/rng, SimClock, BytesLedger) round-
  trip through their ``state_dict``/``load_state`` pairs exactly;
* chunked rounds (``close_chunk``) are crash-safe MID-CHUNK: a ring
  snapshot taken with partial-fold accumulators live and a chunk half
  written restores the exact fold-cascade position, so the resumed close
  is bitwise identical to the uninterrupted one.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import round_state_path
from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer
from repro.core.engine import RoundCloseEngine
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.fedsrv import AdapterCodec, SimClock
from repro.fedsrv.transport import BytesLedger
from repro.models import build_model

ROUNDS = 3
_MODEL_CACHE = {}


def _make_trainer(fed_cfg, clients=3, vocab=16, seed=0):
    if vocab not in _MODEL_CACHE:
        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=vocab)
        _MODEL_CACHE[vocab] = build_model(cfg)
    model = _MODEL_CACHE[vocab]
    ds = SyntheticLM(vocab=vocab, num_tasks=clients, seed=seed)
    seqs, labels = [], []
    for t in range(clients):
        n = 30 + 20 * t
        seqs.append(ds.sample(task=t, num_sequences=n, seq_len=32,
                              seed=seed + t))
        labels += [t] * n
    seqs = np.concatenate(seqs)
    parts = dirichlet_partition(np.array(labels), clients, alpha=0.5,
                                seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=8, seed=seed + i)
               for i, p in enumerate(parts)]
    evals = [ds.to_batch(ds.sample(task=t, num_sequences=8, seq_len=32,
                                   seed=seed + 100 + t))
             for t in range(clients)]
    return FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8), fed_cfg=fed_cfg,
        # cosine: the LR at round r depends on the ABSOLUTE step index, so
        # a resume that miscounts steps diverges immediately
        train_cfg=TrainConfig(learning_rate=1e-2, schedule="cosine",
                              total_steps=ROUNDS * fed_cfg.local_steps),
        client_loaders=loaders, eval_batches=evals, seed=seed)


def _assert_bitwise_runs(full, resumed):
    assert len(full.history) == len(resumed.history) == ROUNDS
    for a, b in zip(full.history, resumed.history):
        assert a == b, f"history diverged at round {a.round}"
    fa = jax.tree.leaves((full.global_lora, full.params))
    fb = jax.tree.leaves((resumed.global_lora, resumed.params))
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # hetero/keep_local runs carry PER-CLIENT bases + adapters — each
    # client's own residual fold must survive the resume bitwise too
    if full.client_params is not None:
        fa = jax.tree.leaves((full.client_params, full._client_lora))
        fb = jax.tree.leaves((resumed.client_params, resumed._client_lora))
        for a, b in zip(fa, fb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _kill_and_resume(fed_cfg, tmp_path, kill_after=1, clients=3):
    """Run uninterrupted; run a twin killed at ``kill_after`` rounds; resume
    it in a FRESH trainer from the checkpoint; compare bitwise."""
    full = _make_trainer(fed_cfg, clients=clients)
    full.run()

    ck = dataclasses.replace(fed_cfg, checkpoint_dir=str(tmp_path))
    killed = _make_trainer(ck, clients=clients)
    killed.run(until=kill_after)
    assert len(killed.history) == kill_after

    resumed = _make_trainer(ck, clients=clients)
    resumed.load_state(round_state_path(str(tmp_path)))
    resumed.run()
    _assert_bitwise_runs(full, resumed)
    return full, resumed


class TestKillAndResume:
    def test_sync_round_bitwise(self, tmp_path):
        self_cfg = FedConfig(num_clients=3, rounds=ROUNDS, local_steps=2,
                             method="fedex", participation=1.0,
                             weighting="examples", engine="auto")
        _kill_and_resume(self_cfg, tmp_path)

    def test_async_inflight_restored_bitwise(self, tmp_path):
        """FedBuff: the kill point leaves uplinks IN FLIGHT — the resumed
        run must re-launch them with their original send times/versions."""
        cfg = FedConfig(num_clients=4, rounds=ROUNDS, local_steps=2,
                        method="fedex", async_buffer=2, latency_jitter=0.5,
                        weighting="examples", engine="auto")
        full, resumed = _kill_and_resume(cfg, tmp_path)
        assert resumed.coordinator._version == full.coordinator._version

    def test_faulty_run_resumes_bitwise(self, tmp_path):
        """Fault coins key off absolute (seed, round, client): the resumed
        half replays the SAME injections, quarantines included."""
        cfg = FedConfig(num_clients=3, rounds=ROUNDS, local_steps=2,
                        method="fedex", participation=1.0, engine="auto",
                        faults="nan@1(clients=1,rounds=1)")
        full, resumed = _kill_and_resume(cfg, tmp_path)
        assert (1, "nonfinite") in full.outcomes[1].quarantined
        # the resumed trainer saw rounds 1..2 only, same quarantine
        assert (1, "nonfinite") in resumed.outcomes[0].quarantined

    def test_kill_later_boundary(self, tmp_path):
        cfg = FedConfig(num_clients=3, rounds=ROUNDS, local_steps=2,
                        method="fedex", participation=1.0, engine="auto")
        _kill_and_resume(cfg, tmp_path, kill_after=2)

    def test_sync_chunked_round_bitwise(self, tmp_path):
        """close_chunk=2 at 5 clients: every round closes through the
        CHUNKED path (partial folds + raw ingest weights in the ring), and
        the resumed run must still be bitwise."""
        cfg = FedConfig(num_clients=5, rounds=ROUNDS, local_steps=2,
                        method="fedex", participation=1.0,
                        weighting="examples", engine="auto", close_chunk=2)
        _kill_and_resume(cfg, tmp_path, clients=5)

    def test_hetero_round_bitwise(self, tmp_path):
        """Ragged-rank engine closes (close_hetero) are crash-safe: the
        checkpoint carries every client's OWN folded base + rank-r_i
        adapters AND the ring's per-slot rank vectors, so the resumed half
        replays the masked closes bitwise."""
        cfg = FedConfig(num_clients=3, rounds=ROUNDS, local_steps=2,
                        method="hetero", client_ranks=(2, 4, 3),
                        participation=1.0, engine="auto")
        full, resumed = _kill_and_resume(cfg, tmp_path)
        # the ragged ranks actually survived: each client's adapter is at
        # its OWN rank after the resume
        from repro.util.tree import flatten_with_paths
        for i, r in enumerate((2, 4, 3)):
            for lora in (full._client_lora[i], resumed._client_lora[i]):
                widths = [np.shape(v)[-1]
                          for k, v in flatten_with_paths(lora).items()
                          if k.endswith("/a")]
                assert widths and all(w == r for w in widths)

    def test_hetero_chunked_midstream_bitwise(self, tmp_path):
        """close_chunk=2 at 5 ragged clients: every hetero close runs the
        CHUNKED path (per-chunk rank vectors + partial masked folds live in
        the ring snapshot) and the resumed run is still bitwise."""
        cfg = FedConfig(num_clients=5, rounds=ROUNDS, local_steps=2,
                        method="hetero", client_ranks=(2, 4, 1, 3, 4),
                        participation=1.0, engine="auto", close_chunk=2)
        _kill_and_resume(cfg, tmp_path, clients=5)

    def test_checkpoint_every_skips_rounds(self, tmp_path):
        cfg = FedConfig(num_clients=3, rounds=2, local_steps=1,
                        method="fedex", participation=1.0,
                        checkpoint_dir=str(tmp_path), checkpoint_every=2)
        tr = _make_trainer(cfg)
        tr.run(until=1)
        assert not os.path.exists(round_state_path(str(tmp_path)))
        tr.run()
        assert os.path.exists(round_state_path(str(tmp_path)))


class TestComponentStateRoundTrips:
    def test_loader_state(self):
        rng = np.random.default_rng(0)
        seqs = rng.integers(0, 16, size=(40, 8))
        a = ClientLoader(seqs, batch_size=8, seed=3)
        for _ in range(7):  # crosses an epoch reshuffle
            a.next_batch()
        state = a.state_dict()
        want = [np.asarray(a.next_batch()["tokens"]) for _ in range(6)]
        b = ClientLoader(seqs, batch_size=8, seed=999)  # wrong seed on purpose
        b.load_state(state)
        got = [np.asarray(b.next_batch()["tokens"]) for _ in range(6)]
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)

    def test_clock_state(self):
        c = SimClock()
        c.advance_to(3.5)
        c.advance(1.25)
        d = SimClock()
        d.load_state(c.state_dict())
        assert d.now() == c.now() == 4.75

    def test_ring_midchunk_state(self):
        """Snapshot a chunked round MID-CHUNK — accumulators live (chunk 0
        already eagerly folded) and chunk 1 half written — restore into a
        fresh engine, finish streaming in both, and the closes must be
        bitwise identical (the snapshot restores the exact fold-cascade
        position, not just the raw buffers)."""
        c, chunk = 6, 2
        rng = np.random.default_rng(21)
        mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
        params = {"q_proj": {"kernel": mk((16, 12))}}
        lora_t = {"q_proj": {"a": mk((16, 2)), "b": mk((2, 12))}}
        loras = [{"q_proj": {"a": mk((16, 2)), "b": mk((2, 12))}}
                 for _ in range(c)]
        raw_w = [30.0, 50.0, 70.0, 90.0, 110.0, 130.0]

        def make():
            return RoundCloseEngine(params, lora_t, c_max=c, scale=0.5,
                                    method="fedex", backend="jnp",
                                    chunk=chunk)

        def close(eng):
            g, p, div = eng.close(params, list(range(c)), raw_w)
            div.resolve()
            return g, p

        uninterrupted = make()
        uninterrupted.buffers.begin_round({i: i for i in range(c)})
        crashed = make()
        crashed.buffers.begin_round({i: i for i in range(c)})
        for i in range(c):
            uninterrupted.buffers.write(i, loras[i], weight=raw_w[i])
            if i < 3:  # crash after chunk 0 folded + chunk 1 half full
                crashed.buffers.write(i, loras[i], weight=raw_w[i])
        meta, arrays = crashed.buffers.state_dict()
        assert meta["open"][0]["chunked"]
        assert meta["open"][0]["acc_keys"], "no partial fold before the crash"

        resumed = make()
        resumed.buffers.load_state(meta, arrays)
        for i in range(3, c):
            resumed.buffers.write(i, loras[i], weight=raw_w[i])
        g_r, p_r = close(resumed)
        g_f, p_f = close(uninterrupted)
        for a, b in zip(jax.tree.leaves((g_f, p_f)),
                        jax.tree.leaves((g_r, p_r))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ring_midchunk_hetero_rank_state(self):
        """Hetero twin of test_ring_midchunk_state: the chunked ring
        snapshot additionally carries per-chunk RANK VECTORS (``_ranks``) —
        restore mid-chunk into a fresh hetero engine, finish streaming, and
        the ragged ``close_hetero`` must be bitwise identical, per-client
        params included."""
        c, chunk, rmax = 6, 2, 4
        ranks = [2, 4, 1, 3, 4, 2]
        rng = np.random.default_rng(33)
        mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
        params = {"q_proj": {"kernel": mk((16, 12))}}
        lora_t = {"q_proj": {"a": mk((16, rmax)), "b": mk((rmax, 12))}}
        from repro.core.hetero import pad_adapters
        loras = [pad_adapters({"q_proj": {"a": mk((16, r)),
                                          "b": mk((r, 12))}}, rmax)
                 for r in ranks]
        raw_w = [30.0, 50.0, 70.0, 90.0, 110.0, 130.0]

        def make():
            return RoundCloseEngine(params, lora_t, c_max=c, scale=0.5,
                                    method="hetero", backend="jnp",
                                    chunk=chunk, client_ranks=ranks)

        def close(eng):
            cps, cls, g, div = eng.close_hetero([params] * c, list(range(c)),
                                                raw_w)
            div.resolve()
            return g, cps, cls

        uninterrupted = make()
        uninterrupted.buffers.begin_round({i: i for i in range(c)})
        crashed = make()
        crashed.buffers.begin_round({i: i for i in range(c)})
        for i in range(c):
            uninterrupted.buffers.write(i, loras[i], weight=raw_w[i],
                                        rank=ranks[i])
            if i < 3:  # crash after chunk 0 folded + chunk 1 half full
                crashed.buffers.write(i, loras[i], weight=raw_w[i],
                                      rank=ranks[i])
        meta, arrays = crashed.buffers.state_dict()
        assert meta["open"][0]["chunked"]
        # the rank vectors live in the snapshot alongside the chunk stacks
        assert any(k.endswith("/_ranks") for k in arrays), \
            f"no rank vectors in snapshot arrays: {sorted(arrays)}"

        resumed = make()
        resumed.buffers.load_state(meta, arrays)
        for i in range(3, c):
            resumed.buffers.write(i, loras[i], weight=raw_w[i],
                                  rank=ranks[i])
        g_r, cps_r, cls_r = close(resumed)
        g_f, cps_f, cls_f = close(uninterrupted)
        for a, b in zip(jax.tree.leaves((g_f, cps_f, cls_f)),
                        jax.tree.leaves((g_r, cps_r, cls_r))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ledger_state(self):
        codec = AdapterCodec("none")
        ledger = BytesLedger()
        tree = {"q_proj": {"a": np.zeros((4, 2), np.float32)}}
        ledger.record(codec.encode(tree, round_id=0, client_id=1))
        ledger.record(codec.encode(tree, round_id=0, client_id=2),
                      direction="quarantined")
        restored = BytesLedger()
        restored.load_state(ledger.state_dict())
        assert restored.round_totals(0) == ledger.round_totals(0)
        assert [dataclasses.asdict(e) for e in restored.entries] \
            == [dataclasses.asdict(e) for e in ledger.entries]
