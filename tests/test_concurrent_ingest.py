"""Threaded ingest into the ring (the HTTP server's concurrency contract).

fedsrv/server.py decodes uplinks on many ThreadingHTTPServer handler threads
at once; decode/validate run in parallel and only the ring scatter +
bookkeeping serialize on RoundBuffers' internal RLock. These tests hammer
that lock directly — many writer threads racing each other, racing
``begin_round``/``take`` rotation, and racing eviction — and assert the
ring's invariants hold under the race:

* every ACCEPTED write lands exactly once in its lane (no lost updates,
  no double scatters), and the closed aggregate equals a serial twin's;
* a duplicate (client, round) write loses the race exactly once — accepted
  + duplicate_drops == attempts, per lane accepted == 1;
* writes racing an eviction either land before it (counted in the evicted
  round's delivered map) or drop cleanly (return False) — never scatter
  into a different live round;
* the codec's shared ingest-throughput accumulator under ``decode_into``
  from many threads equals the exact byte sum of accepted payloads.
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import RoundBuffers, RoundCloseEngine
from repro.fedsrv.transport import (AdapterCodec, StaleUplinkError,
                                    ValidationPolicy)
from repro.util.tree import flatten_with_paths

M, N, R = 8, 6, 2


def _template():
    return {"blk": {"q": {"a": jnp.zeros((M, R), jnp.float32),
                          "b": jnp.zeros((R, N), jnp.float32)}}}


def _delta(rnd, cid, seed=7):
    g = np.random.default_rng([seed, rnd, cid])
    return {"blk": {"q": {"a": g.normal(size=(M, R)).astype(np.float32),
                          "b": g.normal(size=(R, N)).astype(np.float32)}}}


def _run_threads(fns):
    """Start all thunks behind one barrier so they actually contend."""
    barrier = threading.Barrier(len(fns))
    errors = []

    def _wrap(fn):
        try:
            barrier.wait()
            fn()
        except Exception as e:  # pragma: no cover - surfaced via assert
            errors.append(e)

    threads = [threading.Thread(target=_wrap, args=(fn,)) for fn in fns]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "writer thread wedged"
    assert not errors, errors


class TestThreadedWriters:
    def test_parallel_writes_land_exactly_once(self):
        C = 24
        buf = RoundBuffers(_template(), c_max=C)
        buf.begin_round({i: i for i in range(C)}, round_id=0)
        results = {}

        def writer(cid):
            def _go():
                results[cid] = buf.write(cid, _delta(0, cid), round_id=0)
            return _go

        _run_threads([writer(i) for i in range(C)])
        assert all(results.values())
        assert sorted(buf.delivered_in(0)) == list(range(C))
        stacks = buf.take(0)
        for path, stack in stacks.items():
            for i in range(C):
                want = flatten_with_paths(_delta(0, i))[path]
                np.testing.assert_array_equal(np.asarray(stack[i]), want,
                                              err_msg=f"{path} lane {i}")

    def test_duplicate_race_single_winner_per_lane(self):
        C, dup = 8, 4  # dup threads per lane, all racing the same round
        buf = RoundBuffers(_template(), c_max=C)
        buf.begin_round({i: i for i in range(C)}, round_id=0)
        outcomes = []
        lock = threading.Lock()

        def writer(cid):
            def _go():
                ok = buf.write(cid, _delta(0, cid), round_id=0)
                with lock:
                    outcomes.append((cid, ok))
            return _go

        _run_threads([writer(i) for i in range(C) for _ in range(dup)])
        for cid in range(C):
            wins = [ok for c, ok in outcomes if c == cid and ok]
            assert len(wins) == 1, f"lane {cid}: {len(wins)} accepted writes"
        assert buf.duplicate_drops == C * (dup - 1)
        assert sorted(buf.delivered_in(0)) == list(range(C))

    def test_writers_racing_rotation_and_eviction(self):
        """Round 0 (evictable) and round 1 fill concurrently while the main
        thread evicts round 0 mid-stream: round-1 writes must ALL land,
        round-0 writes must each either land before the evict (delivered)
        or drop (False) — the two rounds' lanes never cross."""
        C = 16
        buf = RoundBuffers(_template(), c_max=C)
        buf.begin_round({i: i for i in range(C)}, round_id=0)
        buf.begin_round({i: i for i in range(C)}, round_id=1)
        r0 = {}

        def writer(rnd, cid):
            def _go():
                ok = buf.write(cid, _delta(rnd, cid), round_id=rnd)
                if rnd == 0:
                    r0[cid] = ok
            return _go

        evicted = {}

        def evictor():
            evicted.update(buf.evict(0, reason="test race"))

        _run_threads([writer(r, i) for r in (0, 1) for i in range(C)]
                     + [evictor])
        # round 0: accepted set == the delivered map the evict returned
        assert {c for c, ok in r0.items() if ok} == set(evicted)
        # round 1 is untouched by the eviction
        assert sorted(buf.delivered_in(1)) == list(range(C))
        stacks = buf.take(1)
        for path, stack in stacks.items():
            for i in range(C):
                want = flatten_with_paths(_delta(1, i))[path]
                np.testing.assert_array_equal(np.asarray(stack[i]), want,
                                              err_msg=f"{path} lane {i}")
        # late uplink for the evicted round drops cleanly
        assert buf.write(0, _delta(0, 0), round_id=0) is False
        assert buf.stale_drops >= 1

    def test_threaded_close_equals_serial_twin(self):
        """Engine close over threads-scattered stacks is BITWISE the serial
        close — arrival order cannot leak into the aggregate."""
        C = 12
        params = {"blk": {"q": {"kernel": jnp.asarray(
            np.random.default_rng(0).normal(size=(M, N)), jnp.float32)}}}
        threaded = RoundCloseEngine(params, _template(), c_max=C, scale=0.5,
                                    backend="auto")
        serial = RoundCloseEngine(params, _template(), c_max=C, scale=0.5,
                                  backend="auto")
        threaded.buffers.begin_round({i: i for i in range(C)}, round_id=0)
        serial.buffers.begin_round({i: i for i in range(C)}, round_id=0)
        _run_threads([
            (lambda cid: lambda: threaded.buffers.write(
                cid, _delta(0, cid), round_id=0))(i)
            for i in reversed(range(C))])
        for i in range(C):
            serial.buffers.write(i, _delta(0, i), round_id=0)
        lt, pt, _ = threaded.close(params, list(range(C)), round_id=0)
        ls, ps, _ = serial.close(params, list(range(C)), round_id=0)
        for k, v in flatten_with_paths(lt).items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(flatten_with_paths(ls)[k]))
        for k, v in flatten_with_paths(pt).items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(flatten_with_paths(ps)[k]))


class TestThreadedDecodeInto:
    @pytest.mark.parametrize("quantize", ["none", "int8"])
    def test_concurrent_decode_into_exact(self, quantize):
        """The server's actual ingest path: many threads running
        ``codec.decode_into`` concurrently. Every accepted payload's
        dequantized leaves land in their lane; the codec's shared ingest
        byte accumulator (uplink.ingest_bytes_per_s numerator) is the exact
        sum of accepted payload bytes — no torn read-modify-write."""
        C = 16
        codec = AdapterCodec(quantize, validation=ValidationPolicy())
        codec.register_spec(_template())
        buf = RoundBuffers(_template(), c_max=C)
        buf.begin_round({i: i for i in range(C)}, round_id=0)
        payloads = [codec.encode(_delta(0, i), round_id=0, client_id=i)
                    for i in range(C)]

        def writer(p):
            return lambda: codec.decode_into(p, buf)

        _run_threads([writer(p) for p in payloads])
        assert sorted(buf.delivered_in(0)) == list(range(C))
        assert codec._ingest_bytes == sum(p.nbytes for p in payloads)
        ref = AdapterCodec(quantize)
        stacks = buf.take(0)
        for i, p in enumerate(payloads):
            want = flatten_with_paths(ref.decode(p))
            for path, stack in stacks.items():
                np.testing.assert_array_equal(
                    np.asarray(stack[i]), np.asarray(want[path]),
                    err_msg=f"{path} lane {i} ({quantize})")

    def test_stale_decode_into_races_accepted_writes(self):
        """Duplicate payloads race the originals through decode_into: each
        lane accepts exactly one copy, every loser raises StaleUplinkError,
        and only WINNER bytes hit the ingest accumulator."""
        C = 8
        codec = AdapterCodec("none")
        codec.register_spec(_template())
        buf = RoundBuffers(_template(), c_max=C)
        buf.begin_round({i: i for i in range(C)}, round_id=0)
        payloads = [codec.encode(_delta(0, i), round_id=0, client_id=i)
                    for i in range(C)]
        stale = []
        lock = threading.Lock()

        def writer(p):
            def _go():
                try:
                    codec.decode_into(p, buf)
                except StaleUplinkError:
                    with lock:
                        stale.append(p.client_id)
            return _go

        _run_threads([writer(p) for p in payloads for _ in range(3)])
        assert sorted(buf.delivered_in(0)) == list(range(C))
        assert sorted(stale) == sorted(list(range(C)) * 2)
        assert buf.duplicate_drops == 2 * C
        assert codec._ingest_bytes == sum(p.nbytes for p in payloads)
