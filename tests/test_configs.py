"""Config registry + assigned-architecture spec conformance tests."""

import pytest

from repro.configs import ASSIGNED, SHAPES, get_config, get_shape, list_configs

# the assignment table, verbatim
SPEC = {
    "whisper-medium": dict(L=24, d=1024, H=16, KV=16, ff=4096, V=51865),
    "starcoder2-15b": dict(L=40, d=6144, H=48, KV=4, ff=24576, V=49152),
    "granite-8b": dict(L=36, d=4096, H=32, KV=8, ff=14336, V=49152),
    "mixtral-8x22b": dict(L=56, d=6144, H=48, KV=8, ff=16384, V=32768, E=8, topk=2),
    "zamba2-7b": dict(L=81, d=3584, H=32, KV=32, ff=14336, V=32000, ssm=64),
    "gemma3-12b": dict(L=48, d=3840, H=16, KV=8, ff=15360, V=262144),
    "internvl2-76b": dict(L=80, d=8192, H=64, KV=8, ff=28672, V=128256),
    "deepseek-v2-236b": dict(L=60, d=5120, H=128, KV=128, ff=1536, V=102400,
                             E=160, topk=6, kv_lora=512),
    "xlstm-1.3b": dict(L=48, d=2048, H=4, KV=4, ff=0, V=50304),
    "qwen2.5-3b": dict(L=36, d=2048, H=16, KV=2, ff=11008, V=151936),
}


def test_all_assigned_registered():
    assert set(SPEC) == set(ASSIGNED)
    for name in SPEC:
        assert name in list_configs()


@pytest.mark.parametrize("name", list(SPEC))
def test_exact_assignment_values(name):
    cfg = get_config(name)
    s = SPEC[name]
    assert cfg.num_layers == s["L"]
    assert cfg.d_model == s["d"]
    assert cfg.num_heads == s["H"]
    assert cfg.num_kv_heads == s["KV"]
    assert cfg.d_ff == s["ff"]
    assert cfg.vocab_size == s["V"]
    if "E" in s:
        assert cfg.num_experts == s["E"]
        assert cfg.num_experts_per_tok == s["topk"]
    if "ssm" in s:
        assert cfg.ssm_state == s["ssm"]
    if "kv_lora" in s:
        assert cfg.kv_lora_rank == s["kv_lora"]
    assert cfg.source, "config must cite its source"


def test_shapes_match_assignment():
    assert get_shape("train_4k").seq_len == 4096
    assert get_shape("train_4k").global_batch == 256
    assert get_shape("prefill_32k").seq_len == 32768
    assert get_shape("prefill_32k").global_batch == 32
    assert get_shape("decode_32k").global_batch == 128
    assert get_shape("long_500k").seq_len == 524288
    assert get_shape("long_500k").global_batch == 1
    assert get_shape("decode_32k").is_decode and get_shape("long_500k").is_decode


@pytest.mark.parametrize("name", list(SPEC))
def test_reduced_variant_bounds(name):
    r = get_config(name).reduced()
    assert r.num_layers <= 2 and r.d_model <= 512 and r.num_experts <= 4


def test_smoke_suffix_lookup():
    assert get_config("qwen2.5-3b-smoke").d_model <= 512


def test_unknown_raises():
    with pytest.raises(KeyError):
        get_config("nonexistent-model")
    with pytest.raises(KeyError):
        get_shape("nonexistent-shape")


def test_long_context_support_flags():
    """DESIGN §4: who runs long_500k."""
    runs = {n for n in ASSIGNED if get_config(n).supports_long_context}
    assert runs == {"mixtral-8x22b", "zamba2-7b", "gemma3-12b", "xlstm-1.3b"}
