"""Data pipeline tests: synthetic corpus statistics, partitioning, loaders."""

import numpy as np
import pytest

from repro.data import ClientLoader, SyntheticLM, dirichlet_partition, iid_partition


class TestSyntheticLM:
    def test_shapes_and_range(self):
        ds = SyntheticLM(vocab=32, num_tasks=3, seed=0)
        s = ds.sample(task=0, num_sequences=10, seq_len=20, seed=1)
        assert s.shape == (10, 21)
        assert s.min() >= 0 and s.max() < 32

    def test_tasks_are_distinguishable(self):
        """Different tasks → different bigram statistics (learnable signal)."""
        ds = SyntheticLM(vocab=16, num_tasks=2, seed=0)
        def bigram_counts(task):
            s = ds.sample(task=task, num_sequences=200, seq_len=50, seed=7)
            cnt = np.zeros((16, 16))
            for row in s:
                for a, b in zip(row[:-1], row[1:]):
                    cnt[a, b] += 1
            return cnt / cnt.sum()
        d = np.abs(bigram_counts(0) - bigram_counts(1)).sum()
        assert d > 0.5, f"tasks nearly identical (L1={d})"

    def test_deterministic_given_seed(self):
        ds = SyntheticLM(vocab=16, num_tasks=2, seed=0)
        a = ds.sample(task=0, num_sequences=4, seq_len=8, seed=3)
        b = ds.sample(task=0, num_sequences=4, seq_len=8, seed=3)
        np.testing.assert_array_equal(a, b)

    def test_to_batch(self):
        ds = SyntheticLM(vocab=16, seed=0)
        s = ds.sample(task=0, num_sequences=4, seq_len=8, seed=0)
        b = ds.to_batch(s)
        assert b["tokens"].shape == (4, 8)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["targets"][:, :-1]))


class TestPartition:
    def test_iid_covers_all(self):
        parts = iid_partition(100, 3, seed=0)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(100))

    def test_dirichlet_covers_all_nonempty(self):
        labels = np.repeat(np.arange(4), 25)
        parts = dirichlet_partition(labels, 5, alpha=0.2, seed=0)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(100))
        assert all(len(p) > 0 for p in parts)

    def test_low_alpha_is_skewed(self):
        labels = np.repeat(np.arange(3), 100)
        parts_skew = dirichlet_partition(labels, 3, alpha=0.05, seed=1)
        parts_flat = dirichlet_partition(labels, 3, alpha=100.0, seed=1)

        def mix_entropy(parts):
            ent = []
            for p in parts:
                hist = np.bincount(labels[p], minlength=3) / max(len(p), 1)
                hist = hist[hist > 0]
                ent.append(-(hist * np.log(hist)).sum())
            return np.mean(ent)

        assert mix_entropy(parts_skew) < mix_entropy(parts_flat)


class TestLoader:
    def test_batches_cycle_and_shuffle(self):
        seqs = np.arange(5 * 9).reshape(5, 9).astype(np.int32)
        ld = ClientLoader(seqs, batch_size=3, seed=0)
        seen = set()
        for _ in range(4):
            b = ld.next_batch()
            assert b["tokens"].shape == (3, 8)
            seen.update(np.asarray(b["tokens"][:, 0]).tolist())
        assert len(seen) == 5  # every sequence visited within 2 epochs

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            ClientLoader(np.zeros((0, 9), np.int32), batch_size=2)
