"""Dry-run machinery smoke tests.

The full 512-device production dry-run is exercised by launch/dryrun.py (run
separately — results in EXPERIMENTS.md). Here we verify the machinery end to
end in a SUBPROCESS with 8 forced host devices (so the main test process keeps
its single real CPU device), plus in-process unit checks of the pieces.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config, get_shape
from repro.launch.dryrun import should_skip
from repro.launch.hlo_analysis import analyze
from repro.launch.roofline import active_params, model_flops_per_step
from repro.launch.steps import input_specs


class TestInputSpecs:
    def test_train_shape(self):
        cfg = get_config("qwen2.5-3b")
        b = input_specs(cfg, get_shape("train_4k"))
        assert b["tokens"].shape == (256, 4096)
        assert set(b) == {"tokens", "targets", "loss_mask"}

    def test_vlm_budget_includes_vision(self):
        cfg = get_config("internvl2-76b")
        b = input_specs(cfg, get_shape("train_4k"))
        assert b["vision_embeds"].shape == (256, 256, 8192)
        assert b["tokens"].shape[1] + 256 == 4096

    def test_encdec_frames(self):
        cfg = get_config("whisper-medium")
        b = input_specs(cfg, get_shape("prefill_32k"))
        assert b["frames"].shape == (32, 1500, 1024)

    def test_decode_is_single_token(self):
        cfg = get_config("granite-8b")
        b = input_specs(cfg, get_shape("decode_32k"))
        assert b["tokens"].shape == (128, 1)


class TestSkips:
    def test_full_attention_skips_500k(self):
        assert should_skip(get_config("granite-8b"), get_shape("long_500k"))
        assert should_skip(get_config("deepseek-v2-236b"), get_shape("long_500k"))

    def test_subquadratic_runs_500k(self):
        for n in ("mixtral-8x22b", "zamba2-7b", "gemma3-12b", "xlstm-1.3b"):
            assert should_skip(get_config(n), get_shape("long_500k")) is None

    def test_nothing_else_skips(self):
        for name in ASSIGNED:
            for sh in ("train_4k", "prefill_32k", "decode_32k"):
                assert should_skip(get_config(name), get_shape(sh)) is None


class TestHloAnalysis:
    def test_loop_aware_flops(self):
        def f(x, w):
            def body(c, _):
                return jnp.dot(c, w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        compiled = jax.jit(f).lower(s, s).compile()
        costs = analyze(compiled.as_text())
        assert costs.flops == pytest.approx(2 * 64**3 * 7, rel=0.01)

    def test_model_flops_sane(self):
        """6·N·D within 2× of a hand count for a dense config."""
        cfg = get_config("granite-8b")
        n = active_params(cfg)
        assert 7e9 < n < 10e9  # granite-8b ≈ 8B
        f = model_flops_per_step(cfg, get_shape("train_4k"))
        assert f == pytest.approx(6 * n * 256 * 4096, rel=1e-6)

    def test_moe_active_params(self):
        """deepseek-v2: 236B total but ~21B active."""
        cfg = get_config("deepseek-v2-236b")
        n = active_params(cfg)
        assert 1.2e10 < n < 3.5e10


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from repro.configs import get_config, LoRAConfig, TrainConfig
    from repro.launch.steps import (abstract_state, input_specs, make_train_step)
    from repro.models import build_model
    from repro.sharding import batch_spec, param_spec, tree_shardings, data_axes
    from repro.optim.adamw import AdamWState
    from jax.sharding import NamedSharding, PartitionSpec as P
    import dataclasses

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=256)
    model = build_model(cfg)
    lcfg = LoRAConfig(rank=4)
    params, lora, opt = abstract_state(model, cfg, lcfg)
    import repro.configs.base as base
    shape = base.ShapeConfig(name="t", seq_len=64, global_batch=8, kind="train")
    batch = input_specs(cfg, shape)

    p_sh = tree_shardings(params, mesh, param_spec)
    l_sh = tree_shardings(lora, mesh, param_spec)
    o_sh = AdamWState(step=NamedSharding(mesh, P()),
                      mu=tree_shardings(opt.mu, mesh, param_spec),
                      nu=tree_shardings(opt.nu, mesh, param_spec))
    b_sh = tree_shardings(batch, mesh, batch_spec, data_axes(mesh))
    step = make_train_step(model, lcfg, TrainConfig(total_steps=10), 2)
    with mesh:
        lowered = jax.jit(step, in_shardings=(p_sh, l_sh, o_sh, b_sh,
                                              NamedSharding(mesh, P()))).lower(
            params, lora, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    print(json.dumps({"ok": True, "devices": len(jax.devices())}))
""")


def test_sharded_train_step_compiles_subprocess():
    """End-to-end: 8 host devices, 2D-sharded reduced model, lower+compile."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-2000:]}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["devices"] == 8
