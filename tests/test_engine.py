"""Fused round-close engine: property tests against the jnp ground truth.

Numerics contract under test (see core/engine.py):

* The **uniform full-participation** close — and the kernels' uniform paths in
  interpret mode — are BITWISE identical to the *jitted* composition of
  ``core/aggregation.py``'s operators (same op sequence, same XLA program).
  The historical eager list path differs from any fused program by ≤2 ulp
  where XLA contracts mul+add into FMA, so against *eager* we assert tight
  allclose instead.
* **Weighted and masked/ragged** rounds hold the exact residual identity to
  tight float32 tolerance, including stacked-layer leaves and MoE raw-tensor
  targets, and a ``C_max``-padded stack with zero-weight lanes equals the
  aggregation over the delivered subset.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.divergence import mean_deviation
from repro.core.engine import RoundBuffers, RoundCloseEngine
from repro.kernels import factor_mean, fedex_fold
from repro.kernels import ref
from repro.kernels.fedex_residual import fedex_residual_apply
from repro.kernels.factor_mean import lora_factor_mean
from repro.util.tree import flatten_with_paths


def _mk(rng, sh):
    return jnp.asarray(rng.normal(size=sh), jnp.float32)


def _rand_weights(rng, k):
    w = rng.uniform(0.2, 5.0, size=k)
    return (w / w.sum()).tolist()


def _assert_bitwise(a, b, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"{msg} at {k}")


def _assert_close(a, b, tol=1e-5, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_allclose(np.asarray(fa[k], np.float32),
                                   np.asarray(fb[k], np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{msg} at {k}")


# --------------------------------------------------------------------------
# weighted / masked kernels vs the aggregation operators
# --------------------------------------------------------------------------

class TestWeightedResidualKernel:
    @pytest.mark.parametrize("c", [2, 3, 5])
    @pytest.mark.parametrize("m,n", [(128, 128), (256, 128)])
    def test_uniform_bitwise_vs_jitted_operators(self, c, m, n):
        """Interpret-mode uniform kernel ≡ jit(fedex_aggregate+apply_residual)
        bit for bit — the same op sequence compiled by the same XLA."""
        rng = np.random.default_rng(c * 1000 + m + n)
        r = 4
        w0 = _mk(rng, (m, n))
        loras = [{"w": {"a": _mk(rng, (m, r)), "b": _mk(rng, (r, n))}}
                 for _ in range(c)]

        @jax.jit
        def jitted(w0, loras):
            _, res = agg.fedex_aggregate(loras)
            return agg.apply_residual({"w": {"kernel": w0}}, res,
                                      1.7)["w"]["kernel"]

        a = jnp.stack([l["w"]["a"] for l in loras])
        b = jnp.stack([l["w"]["b"] for l in loras])
        kern = fedex_residual_apply(w0, a, b, scale=1.7, interpret=True)
        np.testing.assert_array_equal(np.asarray(kern),
                                      np.asarray(jitted(w0, loras)))

    def test_uniform_ulp_close_to_eager_operators(self):
        """vs the EAGER list path: ≤ a few ulp (XLA FMA contraction)."""
        rng = np.random.default_rng(0)
        c, m, r, n = 3, 256, 4, 256
        w0 = _mk(rng, (m, n))
        loras = [{"w": {"a": _mk(rng, (m, r)), "b": _mk(rng, (r, n))}}
                 for _ in range(c)]
        _, res = agg.fedex_aggregate(loras)
        host = agg.apply_residual({"w": {"kernel": w0}}, res, 1.7)["w"]["kernel"]
        a = jnp.stack([l["w"]["a"] for l in loras])
        b = jnp.stack([l["w"]["b"] for l in loras])
        kern = fedex_residual_apply(w0, a, b, scale=1.7, interpret=True)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(host),
                                   rtol=1e-6, atol=1e-5)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_weighted_matches_operators(self, seed):
        rng = np.random.default_rng(seed)
        c, m, r, n = 4, 128, 4, 128
        w0 = _mk(rng, (m, n))
        loras = [{"w": {"a": _mk(rng, (m, r)), "b": _mk(rng, (r, n))}}
                 for _ in range(c)]
        w = _rand_weights(rng, c)
        _, res = agg.fedex_aggregate(loras, w)
        host = agg.apply_residual({"w": {"kernel": w0}}, res, 2.0)["w"]["kernel"]
        a = jnp.stack([l["w"]["a"] for l in loras])
        b = jnp.stack([l["w"]["b"] for l in loras])
        kern = fedex_residual_apply(w0, a, b, jnp.asarray(w, jnp.float32),
                                    scale=2.0, interpret=True)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(host),
                                   rtol=1e-5, atol=1e-5)

    def test_masked_lanes_equal_subset_aggregation(self):
        """C_max-padded stack + zero weights on absent lanes ≡ aggregation
        over the delivered subset — the participation-mask contract."""
        rng = np.random.default_rng(7)
        c_max, m, r, n = 6, 128, 4, 128
        w0 = _mk(rng, (m, n))
        a = _mk(rng, (c_max, m, r))
        b = _mk(rng, (c_max, r, n))
        delivered = [0, 2, 5]
        sub = [{"w": {"a": a[i], "b": b[i]}} for i in delivered]
        w_sub = _rand_weights(rng, len(delivered))
        _, res = agg.fedex_aggregate(sub, w_sub)
        host = agg.apply_residual({"w": {"kernel": w0}}, res, 1.0)["w"]["kernel"]
        wvec = np.zeros(c_max, np.float32)
        for i, wi in zip(delivered, w_sub):
            wvec[i] = wi
        kern = fedex_residual_apply(w0, a, b, jnp.asarray(wvec), scale=1.0,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(host),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("m,n", [(300, 280), (130, 257)])
    def test_odd_dims_pad_instead_of_crash(self, m, n):
        """Tile-indivisible dims (whisper/qwen-style) pad + slice exactly."""
        rng = np.random.default_rng(m * n)
        c, r = 3, 4
        w0 = _mk(rng, (m, n))
        a = _mk(rng, (c, m, r))
        b = _mk(rng, (c, r, n))
        out = fedex_residual_apply(w0, a, b, scale=1.0, bm=128, bn=128,
                                   interpret=True)
        outr = ref.fedex_residual_ref(w0, a, b, 1.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                                   rtol=1e-5, atol=1e-4)
        w = jnp.asarray(_rand_weights(rng, c), jnp.float32)
        out = fedex_residual_apply(w0, a, b, w, scale=1.0, bm=128, bn=128,
                                   interpret=True)
        outr = ref.fedex_residual_ref(w0, a, b, 1.0, weights=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                                   rtol=1e-5, atol=1e-4)


class TestFactorMeanKernel:
    def test_uniform_bitwise_vs_jitted_tree_mean(self):
        rng = np.random.default_rng(0)
        c = 4
        stack = _mk(rng, (c, 200, 16))

        @jax.jit
        def jitted(stack):
            return agg.tree_mean([{"x": stack[i]} for i in range(c)])["x"]

        out = lora_factor_mean(stack, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jitted(stack)))

    def test_weighted_and_masked(self):
        rng = np.random.default_rng(1)
        c_max = 5
        stack = _mk(rng, (c_max, 64, 8))
        w = np.zeros(c_max, np.float32)
        w[[1, 3]] = [0.25, 0.75]
        out = lora_factor_mean(stack, jnp.asarray(w), interpret=True)
        expect = 0.25 * stack[1] + 0.75 * stack[3]
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-6, atol=1e-6)

    def test_stacked_layer_leaves(self):
        rng = np.random.default_rng(2)
        stack = _mk(rng, (3, 5, 24, 4))  # (C, L, m, r)
        w = jnp.asarray(_rand_weights(rng, 3), jnp.float32)
        out = factor_mean(stack, w)
        expect = jnp.tensordot(w, stack, axes=(0, 0))
        assert out.shape == (5, 24, 4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-6)


class TestFedexFoldWrapper:
    def test_stacked_layers_weighted(self):
        rng = np.random.default_rng(3)
        c, L, m, r, n = 3, 4, 64, 4, 64
        w0 = _mk(rng, (L, m, n))
        a = _mk(rng, (L, c, m, r))  # layer-leading layout the wrapper expects
        b = _mk(rng, (L, c, r, n))
        w = jnp.asarray(_rand_weights(rng, c), jnp.float32)
        out = fedex_fold(w0, a, b, 1.5, weights=w)
        for l in range(L):
            expect = ref.fedex_residual_ref(w0[l], a[l], b[l], 1.5, weights=w)
            np.testing.assert_allclose(np.asarray(out[l]), np.asarray(expect),
                                       rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------------
# streaming round buffers
# --------------------------------------------------------------------------

class TestRoundBuffers:
    def _template(self, rng):
        return {"blk": {"q": {"a": _mk(rng, (16, 4)), "b": _mk(rng, (4, 12))}}}

    def test_streaming_writes_equal_stack(self):
        rng = np.random.default_rng(0)
        template = self._template(rng)
        c_max = 3
        bufs = RoundBuffers(template, c_max)
        bufs.begin_round({10: 0, 11: 1, 12: 2})
        trees = [self._template(np.random.default_rng(i + 1)) for i in range(c_max)]
        for cid, t in zip((12, 10, 11), (trees[2], trees[0], trees[1])):
            bufs.write(cid, t)  # arbitrary arrival order
        assert bufs.delivered == {12: 2, 10: 0, 11: 1}
        stacks = bufs.take()
        expect = jnp.stack([t["blk"]["q"]["a"] for t in trees])
        np.testing.assert_array_equal(np.asarray(stacks["blk/q/a"]),
                                      np.asarray(expect))

    def test_unwritten_lanes_stay_zero_and_validation(self):
        rng = np.random.default_rng(1)
        template = self._template(rng)
        bufs = RoundBuffers(template, 4)
        bufs.begin_round({0: 0, 1: 1})
        bufs.write(1, self._template(np.random.default_rng(9)))
        stacks = bufs.take()
        assert float(jnp.abs(stacks["blk/q/a"][0]).max()) == 0.0
        assert float(jnp.abs(stacks["blk/q/a"][1]).max()) > 0.0
        with pytest.raises(RuntimeError):
            bufs.take()  # already taken
        with pytest.raises(ValueError):
            bufs.begin_round({i: i for i in range(5)})  # > c_max

    def test_transport_decode_into_matches_decode(self):
        """int8 uplink through decode_into ≡ decode: the sink aggregates
        exactly what was transmitted (dequantized values). The payload's
        round_id selects the ring set, so the round must be open under it."""
        from repro.fedsrv.transport import AdapterCodec

        rng = np.random.default_rng(2)
        template = self._template(rng)
        codec = AdapterCodec("int8")
        bufs = RoundBuffers(template, 2)
        bufs.begin_round({0: 0, 1: 1}, round_id=0)
        tree = self._template(np.random.default_rng(5))
        payload = codec.encode(tree, round_id=0, client_id=1)
        codec.decode_into(payload, bufs)
        decoded = codec.decode(payload)
        stacks = bufs.take()
        np.testing.assert_array_equal(
            np.asarray(stacks["blk/q/a"][1]),
            np.asarray(decoded["blk"]["q"]["a"], dtype=np.float32))


# --------------------------------------------------------------------------
# the fused close program end-to-end
# --------------------------------------------------------------------------

def _make_setting(rng, c, with_moe=False, layers=None):
    lead = () if layers is None else (layers,)
    m, r, n = 48, 4, 32
    params = {"blk": {"q_proj": {"kernel": _mk(rng, lead + (m, n)),
                                 "bias": _mk(rng, (n,))}}}
    lora_t = {"blk": {"q_proj": {"a": _mk(rng, lead + (m, r)),
                                 "b": _mk(rng, lead + (r, n))}}}
    if with_moe:
        params["blk"]["experts"] = {"w_up": _mk(rng, (2, m, n))}
        lora_t["blk"]["experts"] = {"w_up": {"a": _mk(rng, (2, m, r)),
                                             "b": _mk(rng, (2, r, n))}}

    def client(seed):
        crng = np.random.default_rng(seed)
        t = {"blk": {"q_proj": {"a": _mk(crng, lead + (m, r)),
                                "b": _mk(crng, lead + (r, n))}}}
        if with_moe:
            t["blk"]["experts"] = {"w_up": {"a": _mk(crng, (2, m, r)),
                                            "b": _mk(crng, (2, r, n))}}
        return t

    return params, lora_t, [client(100 + i) for i in range(c)]


class TestCloseRoundJit:
    @pytest.mark.parametrize("with_moe,layers", [(False, None), (True, 3)])
    def test_uniform_bitwise_vs_jitted_list_path(self, with_moe, layers):
        """Stacked-layer leaves AND MoE raw-tensor targets: the engine's
        uniform close ≡ jit(fedex_aggregate + apply_residual) bitwise."""
        rng = np.random.default_rng(0)
        c, scale = 4, 1.3
        params, lora_t, loras = _make_setting(rng, c, with_moe, layers)
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               backend="jnp")
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        g_e, p_e, div = eng.close(params, list(range(c)))

        @jax.jit
        def list_path(params, loras):
            g, res = agg.fedex_aggregate(loras)
            return g, agg.apply_residual(params, res, scale)

        g_l, p_l = list_path(params, loras)
        _assert_bitwise(p_e, p_l, "params")
        _assert_bitwise(g_e, g_l, "global")
        assert div > 0

    def test_uniform_ulp_close_to_eager_list_path(self):
        rng = np.random.default_rng(1)
        c, scale = 3, 2.0
        params, lora_t, loras = _make_setting(rng, c)
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               backend="jnp")
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        _, p_e, _ = eng.close(params, list(range(c)))
        g, res = agg.fedex_aggregate(loras)
        p_l = agg.apply_residual(params, res, scale)
        _assert_close(p_e, p_l, tol=1e-5, msg="vs eager")

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_weighted_ragged_matches_subset(self, backend):
        rng = np.random.default_rng(2)
        c_max, scale = 5, 1.1
        params, lora_t, loras = _make_setting(rng, c_max)
        eng = RoundCloseEngine(params, lora_t, c_max=c_max, scale=scale,
                               backend=backend, interpret=True)
        eng.buffers.begin_round({i: i for i in range(c_max)})
        delivered = [0, 2, 3]
        for i in delivered:
            eng.buffers.write(i, loras[i])
        weights = [30.0, 50.0, 20.0]  # unnormalized counts accepted
        g_e, p_e, div = eng.close(params, delivered, weights)

        sub = [loras[i] for i in delivered]
        g_l, res = agg.fedex_aggregate(sub, weights)
        p_l = agg.apply_residual(params, res, scale)
        _assert_close(p_e, p_l, tol=2e-5, msg="params")
        _assert_close(g_e, g_l, tol=2e-5, msg="global")
        assert abs(div - mean_deviation(sub)) < 1e-4

    def test_divergence_matches_mean_deviation(self):
        """The factored-Gram divergence ≡ the dense mean_deviation metric,
        including stacked-layer leaves."""
        rng = np.random.default_rng(3)
        c = 4
        params, lora_t, loras = _make_setting(rng, c, layers=3)
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=1.0,
                               backend="jnp")
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        _, _, div = eng.close(params, list(range(c)))
        expect = mean_deviation(loras)
        np.testing.assert_allclose(div, expect, rtol=1e-4)

    def test_close_requires_written_clients(self):
        rng = np.random.default_rng(4)
        params, lora_t, loras = _make_setting(rng, 2)
        eng = RoundCloseEngine(params, lora_t, c_max=2, scale=1.0,
                               backend="jnp")
        eng.buffers.begin_round({0: 0, 1: 1})
        eng.buffers.write(0, loras[0])
        with pytest.raises(ValueError):
            eng.close(params, [0, 1])  # client 1 never delivered
        with pytest.raises(ValueError):
            eng.close(params, [])


class TestTrainerIntegration:
    def _trainer(self, engine, rounds=2, **fed_kw):
        import dataclasses

        from repro.configs import (FedConfig, LoRAConfig, TrainConfig,
                                   get_config)
        from repro.core import FederatedTrainer
        from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
        from repro.models import build_model

        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=16)
        model = build_model(cfg)
        ds = SyntheticLM(vocab=16, num_tasks=3, seed=0, concentration=0.05)
        seqs, labels = [], []
        for t in range(3):
            s = ds.sample(task=t, num_sequences=40, seq_len=32, seed=t)
            seqs.append(s)
            labels += [t] * 40
        seqs = np.concatenate(seqs)
        parts = dirichlet_partition(np.array(labels), 3, alpha=0.3, seed=0)
        loaders = [ClientLoader(seqs[p], batch_size=16, seed=i)
                   for i, p in enumerate(parts)]
        tr = FederatedTrainer(
            model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=FedConfig(num_clients=3, rounds=rounds, local_steps=2,
                              method=fed_kw.pop("method", "fedex"),
                              engine=engine, **fed_kw),
            train_cfg=TrainConfig(learning_rate=3e-2, schedule="constant"),
            client_loaders=loaders, eval_batches=[], seed=0)
        return tr, tr.run()

    def test_engine_attached_on_hot_path_only(self):
        tr, _ = self._trainer("auto", rounds=1)
        assert tr.engine is not None
        assert tr.coordinator.sink is tr.engine.buffers
        tr_off, _ = self._trainer("off", rounds=1)
        assert tr_off.engine is None
        tr_fedit, _ = self._trainer("auto", rounds=1, method="fedit")
        assert tr_fedit.engine is None  # non-fedex keeps the list path

    def test_engine_matches_legacy_trainer_one_round(self):
        """Single-round parity is the invariant: the engine close differs
        from the eager close by ≤ a few ulp (FMA contraction). Over MULTIPLE
        rounds that ulp feeds back through AdamW local training and amplifies
        chaotically, so cross-round comparisons are necessarily loose."""
        tr_on, h_on = self._trainer("auto", rounds=1)
        tr_off, h_off = self._trainer("off", rounds=1)
        _assert_close(tr_on.params, tr_off.params, tol=1e-5, msg="params")
        _assert_close(tr_on.global_lora, tr_off.global_lora, tol=1e-5,
                      msg="global")
        # the factored-Gram divergence has an absolute noise floor (~1e-6)
        # from cancellation when clients have barely diverged; it is exact
        # at any magnitude that matters for the §6 analysis
        for a, b in zip(h_on, h_off):
            np.testing.assert_allclose(a.divergence_scaled,
                                       b.divergence_scaled, rtol=1e-3,
                                       atol=1e-5)

    def test_engine_tracks_legacy_over_rounds(self):
        tr_on, _ = self._trainer("auto")
        tr_off, _ = self._trainer("off")
        fa = flatten_with_paths(tr_on.params)
        fb = flatten_with_paths(tr_off.params)
        for k in fa:
            np.testing.assert_allclose(np.asarray(fa[k], np.float32),
                                       np.asarray(fb[k], np.float32),
                                       atol=1e-3, rtol=0, err_msg=k)

    def test_engine_weighted_partial_matches_legacy(self):
        kw = dict(participation=0.7, weighting="examples", min_quorum=1,
                  dropout_prob=0.3)
        tr_on, _ = self._trainer("auto", **kw)
        tr_off, _ = self._trainer("off", **kw)
        _assert_close(tr_on.params, tr_off.params, tol=5e-5, msg="params")
