"""Chunked streaming round closes (FedConfig.close_chunk) vs the stacked path.

Contracts under test (core/engine.py, docs/architecture.md "Memory model &
chunking contract"):

* **Slot-ordered fold determinism** — chunks fold in client-slot order, not
  arrival order, so any arrival permutation of the same deliveries closes
  bitwise identical.
* **Bitwise vs stacked on dyadic data** — fedex / reinit / keep_local
  chunked closes equal the stacked close bit-for-bit when every intermediate
  is a small dyadic rational (integer/4 factors, power-of-two client counts
  and weight sums): chunk-boundary sum association is then exact, so the
  only legal difference vanishes.
* **fedex_svd ≤ 2 ulp** — the Gram m-reduction is never chunk-split (the
  assembled Gram is bitwise); only the final projection matmuls re-associate,
  landing within 2 ulp of the stacked program on W0 entries that dominate
  the update.
* **Auto contract** — a round is chunked iff 0 < chunk < len(slots); small
  rounds take the stacked path unchanged.
* **Raw ingest weights** — the close cross-checks normalized ingest weights
  against its weight vector and raises ValueError on disagreement.
* **Memory wall** — the chunked close's analytic peak live device bytes
  (last_peak_bytes) undercut the stacked close at the same C.
* **_ProgramCache LRU** — the compile cache is bounded: inserts past the cap
  evict least-recently-used programs (counted), and an engine with a tiny
  cap still closes correctly through recompiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.engine import RoundCloseEngine, _ProgramCache
from repro.util.tree import flatten_with_paths

M, N, R = 16, 12, 2
SCALE = 0.5  # dyadic


def _assert_bitwise(a, b, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"{msg} at {k}")


def _assert_close(a, b, tol=1e-5, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_allclose(np.asarray(fa[k], np.float32),
                                   np.asarray(fb[k], np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{msg} at {k}")


def _dy(rng, sh):
    """Dyadic-rational tensor: integers/4 — f32 sums/products stay exact."""
    return jnp.asarray(rng.integers(-8, 9, size=sh).astype(np.float32) / 4.0)


def _dyadic_setting(seed, c):
    rng = np.random.default_rng(seed)
    params = {"q_proj": {"kernel": _dy(rng, (M, N))}}
    lora_t = {"q_proj": {"a": _dy(rng, (M, R)), "b": _dy(rng, (R, N))}}
    loras = [{"q_proj": {"a": _dy(rng, (M, R)), "b": _dy(rng, (R, N))}}
             for _ in range(c)]
    return params, lora_t, loras


def _random_setting(seed, c):
    rng = np.random.default_rng(seed)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    params = {"q_proj": {"kernel": mk((M, N))}}
    lora_t = {"q_proj": {"a": mk((M, R)), "b": mk((R, N))}}
    loras = [{"q_proj": {"a": mk((M, R)), "b": mk((R, N))}}
             for _ in range(c)]
    return params, lora_t, loras


def _make(params, lora_t, c_max, chunk, **kw):
    return RoundCloseEngine(params, lora_t, c_max=c_max, scale=SCALE,
                            backend="jnp", chunk=chunk, **kw)


def _stream(eng, loras, *, raw_w=None, delivered=None, round_id=0, order=None):
    c = len(loras)
    eng.buffers.begin_round({i: i for i in range(c)}, round_id=round_id)
    ids = list(range(c)) if delivered is None else list(delivered)
    for cid in (order if order is not None else ids):
        eng.buffers.write(cid, loras[cid], round_id=round_id,
                          weight=1.0 if raw_w is None else raw_w[cid])
    return ids


def _close_pair(method, c, chunk, *, raw_w=None, delivered=None, seed=0,
                setting=_dyadic_setting, rng_key=None, svd_rank=0):
    """Close the same round through a chunked and a stacked engine."""
    params, lora_t, loras = setting(seed, c)
    out = []
    for eng_chunk in (chunk, 0):
        eng = _make(params, lora_t, c, eng_chunk, method=method,
                    svd_rank=svd_rank)
        ids = _stream(eng, loras, raw_w=raw_w, delivered=delivered)
        w = None if raw_w is None else [raw_w[i] for i in ids]
        g, p, div = eng.close(params, ids, w, rng=rng_key)
        out.append((g, p, float(div.resolve()), eng))
    (chunked, stacked) = out
    return chunked, stacked


# --------------------------------------------------------------------------
# bitwise vs stacked on dyadic data
# --------------------------------------------------------------------------

class TestChunkedBitwise:
    def test_fedex_uniform(self):
        chunked, stacked = _close_pair("fedex", c=8, chunk=4)
        _assert_bitwise(chunked[1], stacked[1], "params")
        _assert_bitwise(chunked[0], stacked[0], "global")

    def test_fedex_weighted_dyadic(self):
        # raw weights sum to 16 → normalized weights exactly dyadic
        raw_w = [1.0, 1.0, 2.0, 4.0, 1.0, 1.0, 2.0, 4.0]
        chunked, stacked = _close_pair("fedex", c=8, chunk=4, raw_w=raw_w,
                                       seed=1)
        _assert_bitwise(chunked[1], stacked[1], "params")
        _assert_bitwise(chunked[0], stacked[0], "global")

    def test_fedex_partial_participation(self):
        # 4 of 8 slots delivered (power-of-two count → 1/4 weights exact);
        # chunk 2 of the delivered set spans both chunks of the slot range
        chunked, stacked = _close_pair("fedex", c=8, chunk=4,
                                       delivered=[0, 2, 5, 7], seed=2)
        _assert_bitwise(chunked[1], stacked[1], "params")
        _assert_bitwise(chunked[0], stacked[0], "global")

    def test_reinit(self):
        key = jax.random.PRNGKey(7)
        chunked, stacked = _close_pair("reinit", c=8, chunk=4, seed=3,
                                       rng_key=key)
        _assert_bitwise(chunked[1], stacked[1], "params")
        _assert_bitwise(chunked[0], stacked[0], "redrawn adapters")

    def test_keep_local(self):
        c, chunk = 8, 4
        params, lora_t, loras = _dyadic_setting(4, c)
        client_params = [_dyadic_setting(40 + i, 1)[0] for i in range(c)]
        out = []
        for eng_chunk in (chunk, 0):
            eng = _make(params, lora_t, c, eng_chunk, method="keep_local")
            ids = _stream(eng, loras)
            new_cp, div = eng.close_keep_local(client_params, ids)
            div.resolve()
            out.append(new_cp)
        for i in range(c):
            _assert_bitwise(out[0][i], out[1][i], f"client {i}")

    def test_arrival_order_determinism(self):
        """Slot-ordered folds: shuffled arrival orders of the same round
        close bitwise identical — random (non-dyadic) data, so this would
        fail if folds followed arrival order."""
        c, chunk = 8, 3
        params, lora_t, loras = _random_setting(5, c)
        orders = [list(range(c)), list(range(c))[::-1],
                  [3, 7, 0, 5, 1, 6, 2, 4]]
        results = []
        for order in orders:
            eng = _make(params, lora_t, c, chunk, method="fedex")
            _stream(eng, loras, order=order)
            g, p, div = eng.close(params, list(range(c)))
            div.resolve()
            results.append((g, p))
        for g, p in results[1:]:
            _assert_bitwise(p, results[0][1], "params")
            _assert_bitwise(g, results[0][0], "global")


# --------------------------------------------------------------------------
# fedex_svd: assembled Gram bitwise ⇒ ≤ 2 ulp on dominating W0 entries
# --------------------------------------------------------------------------

def _ulp_dist(x, y):
    def lex(f):
        i = np.asarray(f, np.float32).view(np.int32).astype(np.int64)
        return np.where(i >= 0, i, np.int64(0x80000000) - i)
    return np.abs(lex(x) - lex(y))


class TestChunkedSvd:
    @pytest.mark.parametrize("trial", range(3))
    def test_new_w0_within_2_ulp_of_stacked(self, trial):
        c, chunk = 8, 4
        rng = np.random.default_rng(60 + trial)
        # W0 entries bounded away from 0 and ≥ the update magnitude, so an
        # absolute chunk-association error of ~1 ulp of the update stays
        # ~1 ulp of W0 (ulp distance scales with per-entry exponent)
        w0 = (rng.choice([-1.0, 1.0], size=(M, N))
              * rng.integers(4, 9, size=(M, N))).astype(np.float32)
        params = {"q_proj": {"kernel": jnp.asarray(w0)}}
        lora_t = {"q_proj": {"a": _dy(rng, (M, R)), "b": _dy(rng, (R, N))}}
        loras = [{"q_proj": {"a": _dy(rng, (M, R)), "b": _dy(rng, (R, N))}}
                 for _ in range(c)]
        raw_w = [1.0, 2.0, 1.0, 4.0, 2.0, 2.0, 2.0, 2.0]  # sum 16
        outs = []
        for eng_chunk in (chunk, 0):
            eng = _make(params, lora_t, c, eng_chunk, method="fedex_svd",
                        svd_rank=2)
            ids = _stream(eng, loras, raw_w=raw_w)
            _, p, div = eng.close(params, ids, raw_w)
            div.resolve()
            outs.append(np.asarray(p["q_proj"]["kernel"]))
        worst = int(_ulp_dist(outs[0], outs[1]).max())
        assert worst <= 2, f"chunked svd W0 is {worst} ulp from stacked"


# --------------------------------------------------------------------------
# auto contract + oracle agreement on arbitrary data
# --------------------------------------------------------------------------

class TestChunkedContract:
    def test_auto_small_round_takes_stacked_path(self):
        c = 6
        params, lora_t, loras = _random_setting(8, c)
        for chunk in (0, c, c + 3):  # disabled / equal / larger than slots
            eng = _make(params, lora_t, c, chunk, method="fedex")
            _stream(eng, loras)
            assert eng.buffers.is_chunked(0) is False
        eng = _make(params, lora_t, c, c - 1, method="fedex")
        _stream(eng, loras)
        assert eng.buffers.is_chunked(0) is True

    def test_random_weighted_matches_eager_oracle(self):
        c, chunk = 6, 4
        params, lora_t, loras = _random_setting(9, c)
        raw_w = [40.0, 65.0, 90.0, 115.0, 140.0, 165.0]  # "examples"
        eng = _make(params, lora_t, c, chunk, method="fedex")
        ids = _stream(eng, loras, raw_w=raw_w)
        g, p, div = eng.close(params, ids, raw_w)
        div.resolve()
        g_l, res = agg.fedex_aggregate(loras, raw_w)
        p_l = agg.apply_residual(params, res, SCALE)
        _assert_close(p, p_l, tol=1e-5, msg="params")
        _assert_close(g, g_l, tol=1e-5, msg="global")

    def test_weighted_divergence_convention(self):
        """Chunked divergence = ‖Σwᵢaᵢbᵢ − āb̄‖_F/√(mn) under the SAME
        (ingest-normalized) weights the fold used."""
        c, chunk = 6, 4
        params, lora_t, loras = _random_setting(10, c)
        raw_w = [40.0, 65.0, 90.0, 115.0, 140.0, 165.0]
        eng = _make(params, lora_t, c, chunk, method="fedex")
        ids = _stream(eng, loras, raw_w=raw_w)
        _, _, div = eng.close(params, ids, raw_w)
        w = np.asarray(raw_w, np.float64) / np.sum(raw_w)
        a = np.stack([np.asarray(l["q_proj"]["a"], np.float64) for l in loras])
        b = np.stack([np.asarray(l["q_proj"]["b"], np.float64) for l in loras])
        res = (np.einsum("c,cmr,crn->mn", w, a, b)
               - np.einsum("c,cmr->mr", w, a) @ np.einsum("c,crn->rn", w, b))
        oracle = np.linalg.norm(res) / np.sqrt(M * N)
        np.testing.assert_allclose(float(div.resolve()), oracle, rtol=1e-4)

    def test_ingest_close_weight_mismatch_raises(self):
        c, chunk = 6, 4
        params, lora_t, loras = _random_setting(11, c)
        eng = _make(params, lora_t, c, chunk, method="fedex")
        ids = _stream(eng, loras)  # raw ingest weight 1.0 each
        with pytest.raises(ValueError, match="weight"):
            eng.close(params, ids, [1.0, 1.0, 1.0, 1.0, 1.0, 9.0])

    def test_chunked_peak_bytes_below_stacked(self):
        c, chunk = 16, 4
        params, lora_t, loras = _random_setting(12, c)
        peaks = {}
        for eng_chunk in (chunk, 0):
            eng = _make(params, lora_t, c, eng_chunk, method="fedex")
            ids = _stream(eng, loras)
            _, _, div = eng.close(params, ids)
            div.resolve()
            peaks[eng_chunk] = eng.last_peak_bytes
        assert 0 < peaks[chunk] < peaks[0], peaks


# --------------------------------------------------------------------------
# compile-cache LRU bound (satellite fix regression)
# --------------------------------------------------------------------------

class TestProgramCacheLRU:
    def test_evicts_least_recently_used(self):
        cache = _ProgramCache(cap=3)
        for k in "abcde":
            cache.get(k, lambda k=k: f"prog-{k}")
        assert len(cache) == 3 and cache.evictions == 2
        assert "a" not in cache and "b" not in cache
        # touching an entry protects it from the next eviction
        cache.get("c", lambda: "rebuilt-c")
        cache.get("f", lambda: "prog-f")
        assert "c" in cache and "d" not in cache

    def test_cap_validation(self):
        with pytest.raises(ValueError):
            _ProgramCache(cap=0)

    def test_engine_survives_evictions(self):
        """A chunked fedex close needs ≥ 3 programs (stacked ctor warm-up,
        partial fold, finalize); cap=2 forces evictions mid-close, which
        must only cost a recompile — never correctness."""
        c, chunk = 8, 4
        params, lora_t, loras = _dyadic_setting(13, c)
        eng = _make(params, lora_t, c, chunk, method="fedex",
                    program_cache_cap=2)
        ref_eng = _make(params, lora_t, c, 0, method="fedex")
        for rid in range(2):  # second round re-misses the evicted programs
            ids = _stream(eng, loras, round_id=rid)
            g, p, div = eng.close(params, ids, round_id=rid)
            div.resolve()
        _stream(ref_eng, loras)
        g_r, p_r, div_r = ref_eng.close(params, list(range(c)))
        div_r.resolve()
        assert eng._programs.evictions > 0
        assert len(eng._programs) <= 2
        _assert_bitwise(p, p_r, "params after evictions")
        _assert_bitwise(g, g_r, "global after evictions")
