"""Engine-side heterogeneous-rank closes: ragged-lane masks + oracle parity.

Contracts under test (see core/engine.py + core/hetero.py):

* ``factored_truncated_product`` equals the dense Eckart–Young oracle of the
  UNCENTERED product L @ R, its leading slices nest (the rank-r' slice of the
  rank-r truncation IS the rank-r' truncation), and its jaxpr contains NO
  (m, n)-shaped intermediate.
* The engine ``hetero`` close matches the ``hetero_fedex_aggregate`` eager
  oracle: BITWISE when every delivered rank equals r_max with uniform
  weights and full participation (the oracle composed under jit — the
  engine's documented bitwise contract), and ≤2 ulp on ragged rank vectors,
  arbitrary weights and partial participation (the padded oracle shares
  every decomposition input bitwise; only the final fold's FMA contraction
  may differ).
* Per-client exactness (the paper's §6 scheme): for EVERY delivered lane,
  W0_i + ΔW_i + aᵢ'bᵢ' = W0 + Δ̄ — heterogeneity costs nothing.
* Zero-weight and zero-rank (non-delivered) lanes contribute nothing, even
  when their buffers hold junk; arrival order never changes the close.
* The chunked hetero close (streamed ingest folds + pairwise uncentered
  Grams) matches the stacked close to float32 roundoff, masks ragged lanes
  at ingest, and snapshots its rank vector for crash-safe resume.
* The ``hetero_fold`` Pallas kernel (rank masks via a second scalar-prefetch
  vector) matches the jnp branch in interpret mode, layer-stacked included.

The property suite draws random rank vectors, weights, participation masks
and arrival permutations. It runs through ``hypothesis`` when available and
falls back to seeded deterministic sampling otherwise (the container has no
network installs) — every drawn case asserts the same parity + exactness
invariants.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.engine import (RoundCloseEngine, build_factor_specs,
                               factored_truncated_product, make_close_fn,
                               _mask_factor_stacks, _rank_mask)
from repro.core.hetero import hetero_fedex_aggregate, pad_adapters
from repro.kernels import hetero_fold
from repro.util.tree import flatten_with_paths

try:
    import hypothesis
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container has no hypothesis — seeded fallback below
    HAVE_HYPOTHESIS = False


def _mk(rng, sh):
    return jnp.asarray(rng.normal(size=sh), jnp.float32)


def _assert_bitwise(a, b, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"{msg} at {k}")


def _assert_ulp(a, b, ulps=2.0, msg=""):
    """|a − b| ≤ ulps·spacing(max(|a|, |b|)) elementwise."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    tol = ulps * np.spacing(np.maximum(np.abs(a), np.abs(b)))
    bad = np.abs(a - b) > tol
    assert not bad.any(), (
        f"{msg}: {bad.sum()} elements beyond {ulps} ulp "
        f"(worst {np.abs(a - b)[bad].max():.3e})")


def _walk_avals(jaxpr):
    out = []
    for eqn in jaxpr.eqns:
        out += [(eqn.primitive.name, v.aval) for v in eqn.outvars]
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    out += _walk_avals(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    out += _walk_avals(v)
    return out


M, N, RMAX = 14, 10, 6


def _setting(rng, c, ranks, with_moe=False):
    """(params, lora_template, ragged client loras) at tiny paper shapes."""
    params = {"blk": {"q_proj": {"kernel": _mk(rng, (M, N)),
                                 "bias": _mk(rng, (N,))}}}
    lora_t = {"blk": {"q_proj": {"a": jnp.zeros((M, RMAX)),
                                 "b": jnp.zeros((RMAX, N))}}}
    if with_moe:
        params["blk"]["experts"] = {"w_up": _mk(rng, (2, M, N))}
        lora_t["blk"]["experts"] = {"w_up": {"a": jnp.zeros((2, M, RMAX)),
                                             "b": jnp.zeros((2, RMAX, N))}}
    loras = []
    for r in ranks:
        l = {"blk": {"q_proj": {"a": _mk(rng, (M, r)),
                                "b": _mk(rng, (r, N))}}}
        if with_moe:
            l["blk"]["experts"] = {"w_up": {"a": _mk(rng, (2, M, r)),
                                            "b": _mk(rng, (2, r, N))}}
        loras.append(l)
    return params, lora_t, loras


def _engine(params, lora_t, c, ranks, **kw):
    kw.setdefault("backend", "jnp")
    return RoundCloseEngine(params, lora_t, c_max=c, scale=2.0,
                            method="hetero", client_ranks=list(ranks), **kw)


def _close_round(eng, params, loras, client_ids, ranks, weights=None, *,
                 write_order=None, chunk_weights=False):
    c = eng.c_max
    rid = eng.buffers.begin_round({i: i for i in range(c)})
    order = list(client_ids) if write_order is None else list(write_order)
    for cid in order:
        kw = {"rank": ranks[cid]}
        if chunk_weights:
            kw["weight"] = 1.0 if weights is None else weights[
                list(client_ids).index(cid)]
        eng.buffers.write(cid, pad_adapters(loras[cid], RMAX), round_id=rid,
                          **kw)
    client_params = [params] * c
    return eng.close_hetero(client_params, list(client_ids), weights,
                            round_id=rid)


def _oracle_padded(params, loras, client_ids, ranks, weights, c, scale=2.0):
    """The eager oracle in the engine's C_max-lane padded formulation:
    non-delivered lanes ride as zero adapters with zero weight (their rank
    is irrelevant — zero columns), so the oracle's L/R concatenations are
    elementwise identical to the engine's masked stacks."""
    zero = {"blk": {"q_proj": {"a": jnp.zeros((M, RMAX)),
                               "b": jnp.zeros((RMAX, N))}}}
    if "experts" in loras[0]["blk"]:
        zero["blk"]["experts"] = {"w_up": {"a": jnp.zeros((2, M, RMAX)),
                                           "b": jnp.zeros((2, RMAX, N))}}
    delivered = set(client_ids)
    norm = agg.normalize_weights(weights, len(client_ids))
    if norm is None:
        norm = [1.0 / len(client_ids)] * len(client_ids)
    by_cid = dict(zip(client_ids, norm))
    full_loras = [loras[i] if i in delivered else zero for i in range(c)]
    full_ranks = [ranks[i] if i in delivered else RMAX for i in range(c)]
    full_w = [by_cid.get(i, 0.0) for i in range(c)]
    new_loras, resids = hetero_fedex_aggregate(full_loras, full_ranks,
                                               full_w, r_max=RMAX)
    out_params, out_loras = {}, {}
    for i in client_ids:
        out_params[i] = agg.apply_residual(params, resids[i], scale)
        out_loras[i] = new_loras[i]
    return out_params, out_loras


def _q(tree):
    return tree["blk"]["q_proj"]


# --------------------------------------------------------------------------
# factored_truncated_product vs the dense Eckart–Young oracle
# --------------------------------------------------------------------------

class TestFactoredTruncatedProduct:
    @pytest.mark.parametrize("rank", [1, 3, 6])
    def test_matches_dense_oracle(self, rank):
        rng = np.random.default_rng(rank)
        c, m, r, n = 4, 48, 6, 40
        L = _mk(rng, (m, c * r))
        R = _mk(rng, (c * r, n))
        ap, bp = factored_truncated_product(L, R, rank)
        assert ap.shape == (m, rank) and bp.shape == (rank, n)
        u, s, vt = np.linalg.svd(np.asarray(L @ R), full_matrices=False)
        best = (u[:, :rank] * s[:rank]) @ vt[:rank]
        scale = max(np.abs(best).max(), 1e-6)
        np.testing.assert_allclose(np.asarray(ap @ bp) / scale, best / scale,
                                   atol=1e-4)

    def test_balanced_split(self):
        """a' = U√S, b' = √S Vᵀ: both factors carry √(singular value)."""
        rng = np.random.default_rng(7)
        L, R = _mk(rng, (32, 12)), _mk(rng, (12, 24))
        ap, bp = factored_truncated_product(L, R, 4)
        na = np.linalg.norm(np.asarray(ap), axis=0)
        nb = np.linalg.norm(np.asarray(bp), axis=1)
        np.testing.assert_allclose(na, nb, rtol=1e-4)

    def test_leading_slices_nest(self):
        """The rank-r' leading slice of the rank-r truncation IS the rank-r'
        truncation — the property that lets every hetero client share ONE
        decomposition."""
        rng = np.random.default_rng(11)
        L, R = _mk(rng, (32, 12)), _mk(rng, (12, 24))
        ap, bp = factored_truncated_product(L, R, 6)
        ap2, bp2 = factored_truncated_product(L, R, 2)
        prod_sliced = np.asarray(ap[:, :2] @ bp[:2, :])
        prod_small = np.asarray(ap2 @ bp2)
        np.testing.assert_allclose(prod_sliced, prod_small,
                                   rtol=1e-4, atol=1e-5)

    def test_zero_padded_columns_are_exact(self):
        """Zero-padding L's columns / R's rows (a ragged lane's mask) leaves
        the truncated product unchanged to tolerance: padded directions get
        zero Gram eigenvalues, floored by _safe_inv_sqrt."""
        rng = np.random.default_rng(13)
        L, R = _mk(rng, (32, 8)), _mk(rng, (8, 24))
        Lp = jnp.pad(L, ((0, 0), (0, 4)))
        Rp = jnp.pad(R, ((0, 4), (0, 0)))
        ap, bp = factored_truncated_product(L, R, 4)
        app, bpp = factored_truncated_product(Lp, Rp, 4)
        np.testing.assert_allclose(np.asarray(ap @ bp),
                                   np.asarray(app @ bpp),
                                   rtol=1e-4, atol=1e-5)

    def test_jaxpr_never_forms_dense_product(self):
        """No (m, n) aval anywhere in the truncation's jaxpr — the hetero
        close's decomposition stays on (m, C·r)/(C·r, n)/(C·r)² arrays."""
        m, cr, n = 64, 24, 48
        jaxpr = jax.make_jaxpr(
            functools.partial(factored_truncated_product, rank=4))(
            jnp.zeros((m, cr)), jnp.zeros((cr, n)))
        dense = [(p, a) for p, a in _walk_avals(jaxpr.jaxpr)
                 if getattr(a, "shape", ())[-2:] == (m, n)]
        assert not dense, f"dense m×n intermediates: {dense}"

    def test_batches_over_leading_axes(self):
        rng = np.random.default_rng(17)
        L, R = _mk(rng, (3, 32, 8)), _mk(rng, (3, 8, 24))
        ap, bp = factored_truncated_product(L, R, 4)
        assert ap.shape == (3, 32, 4) and bp.shape == (3, 4, 24)
        for i in range(3):
            api, bpi = factored_truncated_product(L[i], R[i], 4)
            np.testing.assert_allclose(np.asarray(ap[i] @ bp[i]),
                                       np.asarray(api @ bpi),
                                       rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# rank masks
# --------------------------------------------------------------------------

class TestRankMasks:
    def test_mask_semantics(self):
        """0 → all masked, −1 → full rank, r_i → leading r_i columns."""
        mask = np.asarray(_rank_mask(jnp.asarray([0, -1, 2], jnp.int32), 4))
        np.testing.assert_array_equal(mask, [[0, 0, 0, 0], [1, 1, 1, 1],
                                             [1, 1, 0, 0]])

    def test_masking_equals_padding(self):
        """Masking a full-rank stack down to r_i is bitwise identical to
        zero-padding a rank-r_i adapter up to r_max — the core exactness
        argument for ragged lanes."""
        rng = np.random.default_rng(3)
        a, b = _mk(rng, (2, M, RMAX)), _mk(rng, (2, RMAX, N))
        ranks = jnp.asarray([2, 4], jnp.int32)
        am, bm = _mask_factor_stacks(a, b, ranks)
        for i, r in enumerate([2, 4]):
            pa = jnp.pad(a[i, :, :r], ((0, 0), (0, RMAX - r)))
            pb = jnp.pad(b[i, :r, :], ((0, RMAX - r), (0, 0)))
            np.testing.assert_array_equal(np.asarray(am[i]), np.asarray(pa))
            np.testing.assert_array_equal(np.asarray(bm[i]), np.asarray(pb))


# --------------------------------------------------------------------------
# the stacked engine close vs the eager oracle
# --------------------------------------------------------------------------

class TestHeteroStackedClose:
    def test_uniform_bitwise_vs_jitted_oracle(self):
        """Full participation + no weights + every rank = r_max: the engine
        output is BITWISE identical to the jitted oracle composition (the
        engine's documented uniform contract for every method)."""
        rng = np.random.default_rng(0)
        c = 3
        ranks = [RMAX] * c
        params, lora_t, loras = _setting(rng, c, ranks)
        eng = _engine(params, lora_t, c, ranks)
        new_cp, new_loras, glob, _div = _close_round(
            eng, params, loras, range(c), ranks)

        @jax.jit
        def oracle(params, loras):
            new, resids = hetero_fedex_aggregate(loras, ranks)
            return ([agg.apply_residual(params, r, 2.0) for r in resids],
                    new)

        o_params, o_loras = oracle(params, loras)
        for i in range(c):
            _assert_bitwise(new_cp[i], o_params[i], msg=f"params lane {i}")
            _assert_bitwise(new_loras[i], o_loras[i], msg=f"lora lane {i}")

    @pytest.mark.parametrize("weighting", ["explicit", "random"])
    def test_ragged_matches_padded_oracle(self, weighting):
        """Mixed ranks, full participation: ≤2 ulp vs the padded oracle
        (identical decomposition inputs; only the fold's FMA order may
        differ between the jitted engine and the eager oracle)."""
        rng = np.random.default_rng(len(weighting))
        c = 5
        ranks = [2, 4, 6, 3, 5]
        params, lora_t, loras = _setting(rng, c, ranks)
        if weighting == "explicit":
            weights = [1.0] * c
        else:
            weights = rng.uniform(0.2, 5.0, size=c).tolist()
        eng = _engine(params, lora_t, c, ranks)
        new_cp, new_loras, glob, _div = _close_round(
            eng, params, loras, range(c), ranks, weights)
        o_params, o_loras = _oracle_padded(params, loras, list(range(c)),
                                           ranks, weights, c)
        for i in range(c):
            _assert_ulp(_q(new_cp[i])["kernel"], _q(o_params[i])["kernel"],
                        msg=f"params lane {i}")
            _assert_bitwise(new_loras[i], o_loras[i], msg=f"lora lane {i}")

    def test_partial_participation_matches_padded_oracle(self):
        rng = np.random.default_rng(23)
        c = 6
        ranks = [2, 4, 6, 3, 5, 6]
        params, lora_t, loras = _setting(rng, c, ranks)
        sub = [0, 2, 3, 5]
        weights = rng.uniform(0.5, 3.0, size=len(sub)).tolist()
        eng = _engine(params, lora_t, c, ranks)
        new_cp, new_loras, _glob, _div = _close_round(
            eng, params, loras, sub, ranks, weights)
        assert set(new_cp) == set(sub) == set(new_loras)
        o_params, o_loras = _oracle_padded(params, loras, sub, ranks,
                                           weights, c)
        for i in sub:
            _assert_ulp(_q(new_cp[i])["kernel"], _q(o_params[i])["kernel"],
                        msg=f"params lane {i}")
            _assert_bitwise(new_loras[i], o_loras[i], msg=f"lora lane {i}")

    def test_per_client_exactness_identity(self):
        """W0_i + ΔW_i + aᵢ'bᵢ' = W0 + Δ̄ for EVERY delivered lane — the §6
        guarantee, asserted against an independently computed ideal."""
        rng = np.random.default_rng(29)
        c = 4
        ranks = [2, 6, 3, 4]
        params, lora_t, loras = _setting(rng, c, ranks)
        weights = rng.uniform(0.5, 3.0, size=c).tolist()
        eng = _engine(params, lora_t, c, ranks)
        new_cp, new_loras, _glob, _div = _close_round(
            eng, params, loras, range(c), ranks, weights)
        norm = np.asarray(agg.normalize_weights(weights, c), np.float64)
        ideal = sum(
            norm[i] * (np.asarray(_q(loras[i])["a"], np.float64)
                       @ np.asarray(_q(loras[i])["b"], np.float64))
            for i in range(c))
        target = np.asarray(_q(params)["kernel"], np.float64) + 2.0 * ideal
        for i in range(c):
            eff = (np.asarray(_q(new_cp[i])["kernel"], np.float64)
                   + 2.0 * (np.asarray(_q(new_loras[i])["a"], np.float64)
                            @ np.asarray(_q(new_loras[i])["b"], np.float64)))
            np.testing.assert_allclose(eff, target, rtol=2e-5, atol=2e-5,
                                       err_msg=f"lane {i}")

    def test_client_lora_ranks_and_glob_slices(self):
        """Lane i's adapters have rank rᵢ and equal the leading slices of
        the returned shared r_max global."""
        rng = np.random.default_rng(31)
        c = 3
        ranks = [2, 6, 4]
        params, lora_t, loras = _setting(rng, c, ranks)
        eng = _engine(params, lora_t, c, ranks)
        _cp, new_loras, glob, _div = _close_round(
            eng, params, loras, range(c), ranks,
            weights=[1.0, 2.0, 0.5])
        ga, gb = _q(glob)["a"], _q(glob)["b"]
        assert ga.shape == (M, RMAX) and gb.shape == (RMAX, N)
        for i, r in enumerate(ranks):
            assert _q(new_loras[i])["a"].shape == (M, r)
            assert _q(new_loras[i])["b"].shape == (r, N)
            np.testing.assert_array_equal(
                np.asarray(_q(new_loras[i])["a"]), np.asarray(ga[:, :r]))
            np.testing.assert_array_equal(
                np.asarray(_q(new_loras[i])["b"]), np.asarray(gb[:r, :]))

    def test_zero_weight_lane_contributes_nothing(self):
        """A delivered lane with weight 0 leaves every other lane's close
        unchanged to roundoff. (Not bitwise: the zero-weight lane's b rows
        still ride the R-side Gram, so its eigenbasis differs by roundoff
        rotation — only zero-RANK masking removes a payload bitwise, see
        test_junk_in_nondelivered_lane_is_masked.)"""
        rng = np.random.default_rng(37)
        c = 4
        ranks = [3, 6, 2, 5]
        params, lora_t, loras = _setting(rng, c, ranks)
        eng_a = _engine(params, lora_t, c, ranks)
        cp_a, loras_a, _g, _d = _close_round(
            eng_a, params, loras, [0, 1, 2, 3], ranks,
            weights=[1.0, 2.0, 3.0, 0.0])
        eng_b = _engine(params, lora_t, c, ranks)
        cp_b, loras_b, _g, _d = _close_round(
            eng_b, params, loras, [0, 1, 2], ranks,
            weights=[1.0, 2.0, 3.0])
        for i in [0, 1, 2]:
            np.testing.assert_allclose(
                np.asarray(_q(cp_a[i])["kernel"]),
                np.asarray(_q(cp_b[i])["kernel"]),
                rtol=2e-5, atol=2e-5, err_msg=f"lane {i}")
            np.testing.assert_allclose(
                np.asarray(_q(loras_a[i])["a"]) @ np.asarray(
                    _q(loras_a[i])["b"]),
                np.asarray(_q(loras_b[i])["a"]) @ np.asarray(
                    _q(loras_b[i])["b"]),
                rtol=2e-5, atol=2e-5, err_msg=f"lora {i}")

    def test_junk_in_nondelivered_lane_is_masked(self):
        """Garbage written to a lane that is NOT in the delivered set (rank
        0 + weight 0 masks) changes nothing — the crash-twin guarantee when
        a ragged lane is quarantined."""
        rng = np.random.default_rng(41)
        c = 4
        ranks = [3, 6, 2, 5]
        params, lora_t, loras = _setting(rng, c, ranks)
        sub = [0, 1, 3]

        def run(write_junk):
            eng = _engine(params, lora_t, c, ranks)
            rid = eng.buffers.begin_round({i: i for i in range(c)})
            for cid in sub:
                eng.buffers.write(cid, pad_adapters(loras[cid], RMAX),
                                  round_id=rid, rank=ranks[cid])
            if write_junk:  # lane 2 delivers junk but is excluded from close
                junk = {"blk": {"q_proj": {
                    "a": _mk(rng, (M, RMAX)) * 100.0,
                    "b": _mk(rng, (RMAX, N)) * 100.0}}}
                eng.buffers.write(2, junk, round_id=rid, rank=RMAX)
            return eng.close_hetero([params] * c, sub, [1.0, 2.0, 0.5],
                                    round_id=rid)

        cp_a, loras_a, _g, _d = run(False)
        cp_b, loras_b, _g, _d = run(True)
        for i in sub:
            _assert_bitwise(cp_a[i], cp_b[i], msg=f"lane {i}")
            _assert_bitwise(loras_a[i], loras_b[i], msg=f"lora {i}")

    def test_arrival_permutation_invariant(self):
        """Uplink arrival order scatters to fixed lanes — closes bitwise."""
        rng = np.random.default_rng(43)
        c = 5
        ranks = [2, 4, 6, 3, 5]
        params, lora_t, loras = _setting(rng, c, ranks)
        weights = rng.uniform(0.5, 3.0, size=c).tolist()
        eng_a = _engine(params, lora_t, c, ranks)
        cp_a, loras_a, _g, _d = _close_round(
            eng_a, params, loras, range(c), ranks, weights)
        eng_b = _engine(params, lora_t, c, ranks)
        cp_b, loras_b, _g, _d = _close_round(
            eng_b, params, loras, range(c), ranks, weights,
            write_order=[3, 0, 4, 2, 1])
        for i in range(c):
            _assert_bitwise(cp_a[i], cp_b[i], msg=f"lane {i}")
            _assert_bitwise(loras_a[i], loras_b[i], msg=f"lora {i}")

    def test_moe_leading_axes(self):
        """Stacked-expert (lead-axis) leaves close and slice correctly."""
        rng = np.random.default_rng(47)
        c = 3
        ranks = [2, 6, 4]
        params, lora_t, loras = _setting(rng, c, ranks, with_moe=True)
        eng = _engine(params, lora_t, c, ranks)
        new_cp, new_loras, glob, _div = _close_round(
            eng, params, loras, range(c), ranks, weights=[1.0, 2.0, 0.5])
        o_params, o_loras = _oracle_padded(params, loras, list(range(c)),
                                           ranks, [1.0, 2.0, 0.5], c)
        for i in range(c):
            _assert_ulp(new_cp[i]["blk"]["experts"]["w_up"],
                        o_params[i]["blk"]["experts"]["w_up"],
                        msg=f"moe lane {i}")
            ea = new_loras[i]["blk"]["experts"]["w_up"]["a"]
            assert ea.shape == (2, M, ranks[i])
            _assert_bitwise(new_loras[i], o_loras[i], msg=f"moe lora {i}")


# --------------------------------------------------------------------------
# jaxpr contracts: the dense m×n mean is never decomposed
# --------------------------------------------------------------------------

class TestHeteroJaxpr:
    def test_all_decompositions_are_cr_sized(self):
        """Every eig/svd/qr in the FULL ragged hetero close acts on
        C·r_max-sized matrices — the m×n-shaped avals are the W0 fold
        targets (allowed, as in fedex_svd), never decomposition inputs."""
        c, m, r, n = 4, 48, 4, 40
        params = {"l": {"kernel": jnp.zeros((m, n))}}
        lora_t = {"l": {"a": jnp.zeros((m, r)), "b": jnp.zeros((r, n))}}
        specs = build_factor_specs(params, lora_t)
        close = make_close_fn(specs, scale=1.0, c_max=c, method="hetero",
                              backend="jnp", donate=False)
        w0 = {"l": jnp.zeros((c, m, n))}
        stacks = {"l/a": jnp.zeros((c, m, r)), "l/b": jnp.zeros((c, r, n))}
        jaxpr = jax.make_jaxpr(
            functools.partial(close, uniform=False))(
            w0, stacks, jnp.zeros((c,)), jnp.zeros((c,), jnp.int32))
        decomp = [(p, a) for p, a in _walk_avals(jaxpr.jaxpr)
                  if any(t in p for t in ("eig", "svd", "qr"))]
        assert decomp, "expected decomposition primitives in the close"
        for prim, aval in decomp:
            shape = getattr(aval, "shape", ())
            assert max(shape or (0,)) <= c * r, (
                f"{prim} on {shape} exceeds C·r={c * r}")


# --------------------------------------------------------------------------
# engine configuration / validation
# --------------------------------------------------------------------------

class TestHeteroEngineConfig:
    def _mini(self):
        rng = np.random.default_rng(0)
        return _setting(rng, 3, [2, 4, 6])

    def test_close_rejects_hetero_method(self):
        params, lora_t, _ = self._mini()
        eng = _engine(params, lora_t, 3, [2, 4, 6])
        with pytest.raises(ValueError, match="close_hetero"):
            eng.close(params, [0])

    def test_close_hetero_rejects_other_methods(self):
        params, lora_t, _ = self._mini()
        eng = RoundCloseEngine(params, lora_t, c_max=3, scale=1.0,
                               method="fedex", backend="jnp")
        with pytest.raises(ValueError, match="not hetero"):
            eng.close_hetero([params] * 3, [0])

    def test_client_ranks_length_validated(self):
        params, lora_t, _ = self._mini()
        with pytest.raises(ValueError, match="entries"):
            _engine(params, lora_t, 3, [2, 4])

    def test_client_ranks_range_validated(self):
        params, lora_t, _ = self._mini()
        with pytest.raises(ValueError, match="r_max"):
            _engine(params, lora_t, 3, [2, 4, RMAX + 1])
        with pytest.raises(ValueError, match="r_max"):
            _engine(params, lora_t, 3, [0, 4, 6])

    def test_default_ranks_are_full(self):
        params, lora_t, loras = self._mini()
        full = [{"blk": {"q_proj": {"a": _mk(np.random.default_rng(i),
                                            (M, RMAX)),
                                    "b": _mk(np.random.default_rng(i + 9),
                                             (RMAX, N))}}}
                for i in range(3)]
        eng = RoundCloseEngine(params, lora_t, c_max=3, scale=2.0,
                               method="hetero", backend="jnp")
        rid = eng.buffers.begin_round({i: i for i in range(3)})
        for i in range(3):
            eng.buffers.write(i, full[i], round_id=rid)
        new_cp, new_loras, _g, _d = eng.close_hetero([params] * 3,
                                                     [0, 1, 2],
                                                     round_id=rid)
        for i in range(3):
            assert _q(new_loras[i])["a"].shape == (M, RMAX)


# --------------------------------------------------------------------------
# chunked hetero closes: streamed ingest + crash-safe rank vectors
# --------------------------------------------------------------------------

class TestHeteroChunked:
    C = 6
    RANKS = [2, 4, 6, 3, 5, 6]

    def _fixture(self, seed=53):
        rng = np.random.default_rng(seed)
        params, lora_t, loras = _setting(rng, self.C, self.RANKS)
        weights = rng.uniform(0.5, 3.0, size=self.C).tolist()
        return params, lora_t, loras, weights

    def test_chunked_matches_stacked(self):
        params, lora_t, loras, weights = self._fixture()
        eng_s = _engine(params, lora_t, self.C, self.RANKS)
        cp_s, loras_s, glob_s, _ = _close_round(
            eng_s, params, loras, range(self.C), self.RANKS, weights)
        eng_c = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        cp_c, loras_c, glob_c, _ = _close_round(
            eng_c, params, loras, range(self.C), self.RANKS, weights,
            chunk_weights=True)
        for i in range(self.C):
            np.testing.assert_allclose(
                np.asarray(_q(cp_s[i])["kernel"]),
                np.asarray(_q(cp_c[i])["kernel"]), rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(
                np.asarray(_q(loras_s[i])["a"]) @ np.asarray(
                    _q(loras_s[i])["b"]),
                np.asarray(_q(loras_c[i])["a"]) @ np.asarray(
                    _q(loras_c[i])["b"]), rtol=2e-5, atol=2e-5)

    def test_chunked_exactness_identity(self):
        params, lora_t, loras, weights = self._fixture(59)
        eng = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        cp, new_loras, _g, _d = _close_round(
            eng, params, loras, range(self.C), self.RANKS, weights,
            chunk_weights=True)
        norm = np.asarray(agg.normalize_weights(weights, self.C), np.float64)
        ideal = sum(
            norm[i] * (np.asarray(_q(loras[i])["a"], np.float64)
                       @ np.asarray(_q(loras[i])["b"], np.float64))
            for i in range(self.C))
        target = np.asarray(_q(params)["kernel"], np.float64) + 2.0 * ideal
        for i in range(self.C):
            eff = (np.asarray(_q(cp[i])["kernel"], np.float64)
                   + 2.0 * (np.asarray(_q(new_loras[i])["a"], np.float64)
                            @ np.asarray(_q(new_loras[i])["b"], np.float64)))
            np.testing.assert_allclose(eff, target, rtol=2e-5, atol=2e-5,
                                       err_msg=f"lane {i}")

    def test_rank_vector_rides_state_dict(self):
        """Mid-round snapshots carry per-slot ranks; a resumed twin replays
        the remaining ingest + close BITWISE (the crash-twin contract)."""
        params, lora_t, loras, weights = self._fixture(61)
        eng_a = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        rid = eng_a.buffers.begin_round({i: i for i in range(self.C)})
        for cid in range(3):  # half the round, mid-chunk
            eng_a.buffers.write(cid, pad_adapters(loras[cid], RMAX),
                                round_id=rid, weight=weights[cid],
                                rank=self.RANKS[cid])
        meta, arrays = eng_a.buffers.state_dict()
        assert f"ring/{rid}/_ranks" in arrays
        np.testing.assert_array_equal(
            arrays[f"ring/{rid}/_ranks"][:3], self.RANKS[:3])
        # twin B: fresh engine, restore, stream the rest, close
        eng_b = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        eng_b.buffers.load_state(meta, arrays)
        for eng in (eng_a, eng_b):
            for cid in range(3, self.C):
                eng.buffers.write(cid, pad_adapters(loras[cid], RMAX),
                                  round_id=rid, weight=weights[cid],
                                  rank=self.RANKS[cid])
        cp_a, loras_a, _g, _d = eng_a.close_hetero(
            [params] * self.C, list(range(self.C)), weights, round_id=rid)
        cp_b, loras_b, _g, _d = eng_b.close_hetero(
            [params] * self.C, list(range(self.C)), weights, round_id=rid)
        for i in range(self.C):
            _assert_bitwise(cp_a[i], cp_b[i], msg=f"lane {i}")
            _assert_bitwise(loras_a[i], loras_b[i], msg=f"lora {i}")

    def test_legacy_snapshot_without_ranks_loads(self):
        """Pre-hetero snapshots (no _ranks key) default every slot to full
        rank — back-compat for restored non-hetero rounds."""
        params, lora_t, loras, weights = self._fixture(67)
        eng = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        rid = eng.buffers.begin_round({i: i for i in range(self.C)})
        eng.buffers.write(0, pad_adapters(loras[0], RMAX), round_id=rid,
                          weight=1.0, rank=self.RANKS[0])
        meta, arrays = eng.buffers.state_dict()
        arrays.pop(f"ring/{rid}/_ranks")
        eng_b = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        eng_b.buffers.load_state(meta, arrays)
        rk = eng_b.buffers.chunk_ranks(rid, 0)
        np.testing.assert_array_equal(rk, [-1, -1])

    def test_chunk_ranks_accessor(self):
        params, lora_t, loras, _w = self._fixture(71)
        eng = _engine(params, lora_t, self.C, self.RANKS, chunk=2)
        rid = eng.buffers.begin_round({i: i for i in range(self.C)})
        for cid in range(self.C):
            eng.buffers.write(cid, pad_adapters(loras[cid], RMAX),
                              round_id=rid, rank=self.RANKS[cid])
        for k in range(3):
            np.testing.assert_array_equal(
                eng.buffers.chunk_ranks(rid, k),
                self.RANKS[2 * k:2 * k + 2])
        # stacked (non-chunked) rounds answer None
        eng2 = _engine(params, lora_t, self.C, self.RANKS)
        rid2 = eng2.buffers.begin_round({i: i for i in range(self.C)})
        assert eng2.buffers.chunk_ranks(rid2, 0) is None


# --------------------------------------------------------------------------
# the hetero_fold Pallas kernel (interpret mode)
# --------------------------------------------------------------------------

class TestHeteroKernel:
    def _operands(self, rng, c=4, lead=()):
        a = _mk(rng, (c,) + lead + (M, RMAX))
        b = _mk(rng, (c,) + lead + (RMAX, N))
        w0 = _mk(rng, (c,) + lead + (M, N))
        ranks = jnp.asarray([2, RMAX, -1, 0], jnp.int32)
        w = jnp.asarray([0.3, 0.25, 0.45, 0.0], jnp.float32)
        am, bm = _mask_factor_stacks(a, b, ranks)
        L = jnp.concatenate([w[i] * am[i] for i in range(c)], axis=-1)
        R = jnp.concatenate([bm[i] for i in range(c)], axis=-2)
        ap, bp = factored_truncated_product(L, R, RMAX)
        return w0, a, b, w, ranks, ap, bp, L, R

    def _reference(self, w0, w, ranks, ap, bp, L, R, c=4):
        ideal = L @ R
        mask = _rank_mask(ranks, RMAX)
        return jnp.stack([
            w0[i] + 2.0 * (ideal - (ap * mask[i].reshape(
                (1,) * (ap.ndim - 1) + (RMAX,))) @ bp)
            for i in range(c)])

    def test_matches_jnp_branch(self):
        rng = np.random.default_rng(73)
        w0, a, b, w, ranks, ap, bp, L, R = self._operands(rng)
        out = hetero_fold(w0, a, b, w, ranks, ap, bp, 2.0, interpret=True)
        ref = self._reference(w0, w, ranks, ap, bp, L, R)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_layer_stacked(self):
        rng = np.random.default_rng(79)
        w0, a, b, w, ranks, ap, bp, L, R = self._operands(rng, lead=(3,))
        out = hetero_fold(w0, a, b, w, ranks, ap, bp, 2.0, interpret=True)
        ref = self._reference(w0, w, ranks, ap, bp, L, R)
        assert out.shape == w0.shape
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_masked_columns_contribute_exactly_zero(self):
        """Junk in a lane's padded rank columns (and in zero-rank lanes)
        changes NOTHING — the kernel masks before every product."""
        rng = np.random.default_rng(83)
        w0, a, b, w, ranks, ap, bp, L, R = self._operands(rng)
        out_clean = hetero_fold(w0, a, b, w, ranks, ap, bp, 2.0,
                                interpret=True)
        junk_a = a.at[0, :, 2:].set(1e6)  # lane 0 has rank 2
        junk_b = b.at[0, 2:, :].set(-1e6)
        junk_a = junk_a.at[3].set(777.0)  # lane 3 has rank 0
        junk_b = junk_b.at[3].set(-777.0)
        out_junk = hetero_fold(w0, junk_a, junk_b, w, ranks, ap, bp, 2.0,
                               interpret=True)
        np.testing.assert_array_equal(np.asarray(out_clean),
                                      np.asarray(out_junk))


# --------------------------------------------------------------------------
# the property suite: random ranks × weights × participation × arrival order
# --------------------------------------------------------------------------

def _property_case(seed):
    """One drawn case: the full parity + exactness bundle."""
    rng = np.random.default_rng(seed)
    c = int(rng.integers(2, 6))
    ranks = [int(rng.integers(1, RMAX + 1)) for _ in range(c)]
    params, lora_t, loras = _setting(rng, c, ranks)
    n_sub = int(rng.integers(1, c + 1))
    sub = sorted(rng.choice(c, size=n_sub, replace=False).tolist())
    weights = rng.uniform(0.2, 5.0, size=n_sub).tolist()
    order = list(sub)
    rng.shuffle(order)
    eng = _engine(params, lora_t, c, ranks)
    new_cp, new_loras, _glob, _div = _close_round(
        eng, params, loras, sub, ranks, weights, write_order=order)
    o_params, o_loras = _oracle_padded(params, loras, sub, ranks, weights, c)
    norm = agg.normalize_weights(weights, n_sub)
    norm = np.asarray([1.0 / n_sub] * n_sub if norm is None else norm,
                      np.float64)
    ideal = sum(
        norm[j] * (np.asarray(_q(loras[i])["a"], np.float64)
                   @ np.asarray(_q(loras[i])["b"], np.float64))
        for j, i in enumerate(sub))
    target = np.asarray(_q(params)["kernel"], np.float64) + 2.0 * ideal
    for i in sub:
        _assert_ulp(_q(new_cp[i])["kernel"], _q(o_params[i])["kernel"],
                    msg=f"seed {seed} lane {i}")
        _assert_bitwise(new_loras[i], o_loras[i],
                        msg=f"seed {seed} lora {i}")
        eff = (np.asarray(_q(new_cp[i])["kernel"], np.float64)
               + 2.0 * (np.asarray(_q(new_loras[i])["a"], np.float64)
                        @ np.asarray(_q(new_loras[i])["b"], np.float64)))
        np.testing.assert_allclose(eff, target, rtol=5e-5, atol=5e-5,
                                   err_msg=f"seed {seed} identity lane {i}")


class TestHeteroProperty:
    @pytest.mark.parametrize("seed", range(100, 110))
    def test_random_rank_weight_participation_permutation(self, seed):
        """Seeded deterministic sampling: random rank vector, weights,
        participation subset and arrival permutation — engine vs padded
        oracle ≤2 ulp, adapters bitwise, §6 identity on every lane."""
        _property_case(seed)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS,
                        reason="hypothesis not installed in this container")
    def test_hypothesis_property(self):
        """The same invariant bundle under hypothesis' shrinking search,
        where the environment provides it."""
        @hypothesis.settings(max_examples=15, deadline=None)
        @hypothesis.given(st.integers(min_value=0, max_value=2 ** 31))
        def run(seed):
            _property_case(seed)

        run()
